"""VA inference demo: mixed-precision CMUL points on the compiled chip.

    PYTHONPATH=src python examples/va_inference_demo.py

Compares the paper operating point (uniform 8-bit) against the
mixed-precision point (8/4-bit layers) and the dense baseline on the
same trained weights — the flexibility the reconfigurable multiplier
exists for — reporting accuracy, storage, energy, and power from the
chip model, plus a check that the Pallas kernel path agrees bit-for-bit
in argmax with the reference path.
"""

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import va_cnn
from repro.core import compiler, vadetect
from repro.data import iegm
from repro.train import trainer


def train(cfg, steps=200, seed=0):
    params = vadetect.init(jax.random.PRNGKey(seed), cfg)
    opt = optim.adam(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(trainer.make_train_step(
        lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
    ), donate_argnums=(0,))
    stream = iegm.IEGMStream(batch=64, seed=seed)
    for i in range(steps):
        state, _ = step(state, stream.batch_at(i))
    return state["params"]


def main() -> None:
    test = iegm.synth_batch(jax.random.PRNGKey(777), 512)

    for name, cfg in [("paper_8bit", va_cnn.CONFIG),
                      ("mixed_8_4bit", va_cnn.MIXED),
                      ("dense_float", va_cnn.DENSE)]:
        params = train(cfg)
        logits = vadetect.apply(params, test["signal"], cfg, train=False)
        acc = float((jnp.argmax(logits, -1) == test["label"]).mean())
        if cfg.spe is not None:
            program = compiler.compile_model(params, cfg)
            kb = program.weight_hbm_bytes() / 1024
            s = program.report.summary()
            # kernel path agreement on the compiled program
            y_ref = compiler.execute(program, test["signal"][:32], cfg,
                                     path="reference")
            y_ker = compiler.execute(program, test["signal"][:32], cfg,
                                     path="kernel")
            agree = float(
                (jnp.argmax(y_ref, -1) == jnp.argmax(y_ker, -1)).mean()
            )
            print(f"{name:14s} acc={acc:.4f} weights={kb:6.1f}KiB "
                  f"energy/inf={program.report.energy_j*1e9:6.2f}nJ "
                  f"power={s['avg_power_uW']:5.2f}uW "
                  f"kernel_argmax_agree={agree:.2f}")
        else:
            n = vadetect.param_count(params)
            print(f"{name:14s} acc={acc:.4f} weights={n*4/1024:6.1f}KiB "
                  f"(f32 baseline)")


if __name__ == "__main__":
    main()
