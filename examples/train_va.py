"""End-to-end training driver for the paper's VA detector.

    PYTHONPATH=src python examples/train_va.py [--steps 300]

Full production path: deterministic host-sharded data -> co-design QAT
(prune-STE + fake-quant) -> atomic checkpoints (keep-3) -> straggler
watchdog -> compile to the accelerator format -> held-out evaluation
(per-segment accuracy, post-vote diagnostic accuracy, precision/recall)
next to the paper's reported numbers.
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import va_cnn
from repro.core import compiler, vadetect
from repro.data import iegm
from repro.serve.va_service import VAService
from repro.train import fault, trainer


def evaluate(program, cfg, *, patients: int = 256, seed: int = 123):
    svc = VAService(program, cfg)
    batch = iegm.synth_diagnosis_batch(jax.random.PRNGKey(seed), patients)
    out = svc.diagnose_batch(batch["signal"])
    labels = [int(x) for x in batch["label"]]
    preds = [int(d.is_va) for d in out]
    seg_preds = jnp.array([d.segment_preds for d in out])
    seg_labels = jnp.repeat(batch["label"][:, None], 6, 1)
    seg_acc = float((seg_preds == seg_labels).mean())
    tp = sum(p and l for p, l in zip(preds, labels))
    fp = sum(p and not l for p, l in zip(preds, labels))
    fn = sum((not p) and l for p, l in zip(preds, labels))
    acc = sum(p == l for p, l in zip(preds, labels)) / len(labels)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return seg_acc, acc, prec, rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="va_ckpt_")

    cfg = va_cnn.CONFIG
    params = vadetect.init(jax.random.PRNGKey(0), cfg)
    print(f"model: {vadetect.param_count(params)} params, "
          f"8 conv layers, 16:8 sparsity, 8-bit")

    opt = optim.adamw(
        optim.linear_warmup_cosine(3e-3, 30, args.steps), weight_decay=1e-4
    )
    state = trainer.init_state(params, opt)
    step = jax.jit(
        trainer.make_train_step(
            lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
        ),
        donate_argnums=(0,),
    )
    stream = iegm.IEGMStream(batch=args.batch, seed=0)
    watchdog = fault.StragglerWatchdog()
    state, history = fault.run_training(
        step, state, stream.batch_at,
        num_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=100,
        watchdog=watchdog, log_every=50,
    )
    print(f"training done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f}; checkpoints in {ckpt_dir}; "
          f"stragglers flagged: {len(watchdog.flagged)}")

    program = compiler.compile_model(state["params"], cfg)
    seg_acc, acc, prec, rec = evaluate(program, cfg)
    print("\n              segment-acc  diagnostic-acc  precision  recall")
    print(f"this run         {seg_acc:7.4f}        {acc:7.4f}    "
          f"{prec:7.4f}  {rec:7.4f}   (synthetic IEGM)")
    print("paper            0.9235         0.9995     0.9988   0.9984"
          "   (SingularMedical silicon)")
    s = program.report.summary()
    print(f"\nchip model: {s['latency_us']:.1f} us | "
          f"{s['effective_GOPS']:.0f} GOPS | {s['avg_power_uW']:.2f} uW")


if __name__ == "__main__":
    main()
