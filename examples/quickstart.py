"""Quickstart: the paper's pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains the 8-layer 1-D FCN VA detector under the chip's constraints
(50% balanced sparsity + 8-bit weights, co-design QAT), freezes it into
the accelerator program format, runs chip-format inference with
6-segment voting, and prints the modeled silicon numbers.
"""

import jax

from repro import optim
from repro.configs import va_cnn
from repro.core import compiler, vadetect
from repro.data import iegm
from repro.serve.va_service import VAService
from repro.train import trainer


def main() -> None:
    cfg = va_cnn.CONFIG  # paper operating point: 16:8 sparsity, 8-bit

    # 1. co-design QAT training on synthetic IEGM (512 pts @ 250 Hz,
    #    15-55 Hz band-passed — the paper's acquisition spec)
    params = vadetect.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(
        trainer.make_train_step(
            lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
        ),
        donate_argnums=(0,),
    )
    stream = iegm.IEGMStream(batch=64, seed=0)
    for i in range(200):
        state, metrics = step(state, stream.batch_at(i))
        if i % 50 == 0:
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"acc={float(metrics['accuracy']):.4f}")

    # 2. compiler: freeze into the chip's compressed format
    program = compiler.compile_model(state["params"], cfg)
    print(f"\ncompiled: {program.weight_hbm_bytes()/1024:.1f} KiB on-chip "
          f"({program.compression_ratio():.1f}x vs dense f32)")

    # 3. chip-format inference + 6-segment voting diagnosis
    svc = VAService(program, cfg)
    batch = iegm.synth_diagnosis_batch(jax.random.PRNGKey(1), 16)
    diagnoses = svc.diagnose_batch(batch["signal"])
    correct = sum(
        int(d.is_va) == int(batch["label"][i])
        for i, d in enumerate(diagnoses)
    )
    print(f"diagnostic accuracy (synthetic): {correct}/16")

    # 4. the silicon numbers, from the analytic chip model
    s = svc.report.summary()
    print(f"chip model: {s['latency_us']:.1f} us/inference, "
          f"{s['effective_GOPS']:.0f} GOPS, {s['avg_power_uW']:.2f} uW, "
          f"{s['power_density_uW_mm2']:.2f} uW/mm^2")
    print("paper     : 35.0 us, 150 GOPS, 10.60 uW, 0.57 uW/mm^2")


if __name__ == "__main__":
    main()
