"""Batched LM serving demo: slot-engine + weight-only quantized decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b

Submits a burst of variable-length requests to the slot-based engine
(continuous batching), then repeats with int8/int4 weight-only
quantization — the paper's compressed-storage idea applied to the
memory-bound decode regime — and reports the token agreement between
precisions. On a multi-device host (or with
XLA_FLAGS=--xla_force_host_platform_device_count=8) the same requests
also run through the mesh-sharded engine (`repro.serve.sharded`) and
the outputs are compared token-for-token.
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch)
    model = api.build_model(cfg, tp=1, max_seq=96)
    params = model.init(jax.random.PRNGKey(0))

    def make_requests():
        # variable-length prompts; deterministic so the sharded engine
        # below can replay the exact same burst for comparison
        return [
            E.Request(
                uid=i,
                prompt=jax.random.randint(
                    jax.random.PRNGKey(i), (4 + (i % 4) * 3,), 0,
                    cfg.vocab,
                ),
                max_new=args.max_new,
            )
            for i in range(args.requests)
        ]

    # --- slot engine with more requests than slots ----------------------
    eng = E.Engine(model, params, batch_size=args.slots)
    reqs = make_requests()
    for r in reqs:
        eng.submit(r)
    eng.run()
    print(f"engine: {args.requests} requests over {args.slots} slots")
    for r in reqs:
        print(f"  req {r.uid} (prompt {r.prompt.shape[0]:2d} tok): "
              f"{r.output}")

    # --- sharded engine on a data mesh (token-identical) ----------------
    n_dev = jax.device_count()
    if n_dev > 1:
        from repro.launch.mesh import make_smoke_mesh

        pool = max(args.slots, n_dev)
        pool += (-pool) % n_dev  # divisible by the data axis
        seng = SH.ShardedEngine(
            model, params, batch_size=pool, mesh=make_smoke_mesh(n_dev, 1)
        )
        sreqs = make_requests()
        for r in sreqs:
            seng.submit(r)
        seng.run()
        same = all(a.output == b.output for a, b in zip(reqs, sreqs))
        plan = seng.plan
        print(
            f"sharded engine on {n_dev} devices: outputs "
            f"{'identical' if same else 'DIFFER'}; cache "
            f"{plan.cache_bytes_per_device} B/device vs "
            f"{plan.cache_bytes_total} B replicated"
        )

    # --- quantized serving comparison -----------------------------------
    prompts = jax.random.randint(jax.random.PRNGKey(42), (4, 12), 0,
                                 cfg.vocab)
    base = E.generate(model, params, prompts, max_new=args.max_new)
    for bits in (8, 4):
        qp = E.quantize_for_serving(params, bits)
        out = E.generate(model, qp, prompts, max_new=args.max_new)
        agree = float(jnp.mean((out == base).astype(jnp.float32)))
        print(f"int{bits} weight-only decode: token agreement vs bf16 "
              f"= {agree:.2f}")


if __name__ == "__main__":
    main()
