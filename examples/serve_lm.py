"""Batched LM serving demo: batched-prefill admission + quantized decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b

Submits a burst of variable-length requests to the slot engine in one
call: admission groups them by prompt length, runs one batched
`model.prefill` per group, and scatter-seats the resulting cache rows
into the pool (`repro.serve.seating`) — O(prompt) work per request,
independent of the pool size; the demo prints the measured admission
work next to what pool-replay admission would have cost. Then repeats
with int8/int4 weight-only quantization — the paper's
compressed-storage idea applied to the memory-bound decode regime —
and reports the token agreement between precisions. On a multi-device
host (or with XLA_FLAGS=--xla_force_host_platform_device_count=8) the
same burst also runs through the mesh-sharded engine
(`repro.serve.sharded`) and the outputs are compared token-for-token.

`--smoke` (CI: scripts/ci.sh) shrinks the burst and asserts the demo's
claims instead of just printing them.
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH


def submit_burst(eng, reqs):
    """Admit a whole burst in one call: submit everything, then tick —
    the engine batches the admission prefills per prompt length."""
    for r in reqs:
        eng.submit(r)
    eng.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="small burst + assertions (CI entry point)")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="enable telemetry; write PREFIX.jsonl + "
                         "PREFIX.json (Chrome trace) on exit")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots, args.max_new = 4, 2, 4
    if args.trace_out:
        # before engine construction so jit cells register with the probe
        obs.configure(enabled=True)

    cfg = configs.reduced(args.arch)
    model = api.build_model(cfg, tp=1, max_seq=96)
    params = model.init(jax.random.PRNGKey(0))

    def make_requests():
        # pairwise-repeated lengths (4, 4, 7, 7, ...) so co-admitted
        # requests share batched prefill cells; deterministic so the
        # sharded engine below can replay the same burst for comparison
        return [
            E.Request(
                uid=i,
                prompt=jax.random.randint(
                    jax.random.PRNGKey(i), (4 + (i // 2 % 2) * 3,), 0,
                    cfg.vocab,
                ),
                max_new=args.max_new,
            )
            for i in range(args.requests)
        ]

    # --- slot engine: one burst, batched admission ----------------------
    eng = E.Engine(model, params, batch_size=args.slots)
    reqs = make_requests()
    submit_burst(eng, reqs)
    replay_cost = sum(r.prompt.shape[0] for r in reqs) * args.slots
    print(f"engine: {args.requests} requests over {args.slots} slots")
    for r in reqs:
        print(f"  req {r.uid} (prompt {r.prompt.shape[0]:2d} tok): "
              f"{r.output}")
    print(
        f"admission: {args.requests} requests seated through "
        f"{eng.admission_prefills} batched prefill cells, "
        f"{eng.admission_rowsteps} row-tokens of work "
        f"(pool-replay admission would have spent {replay_cost})"
    )
    if args.smoke:
        assert all(r.done for r in reqs)
        # batched: fewer prefill cells than requests, less work than
        # stepping every prompt token through the whole pool
        assert eng.admission_prefills < args.requests
        assert eng.admission_rowsteps < replay_cost

    # --- sharded engine on a data mesh (token-identical) ----------------
    n_dev = jax.device_count()
    if n_dev > 1:
        from repro.launch.mesh import make_smoke_mesh

        pool = max(args.slots, n_dev)
        pool += (-pool) % n_dev  # divisible by the data axis
        seng = SH.ShardedEngine(
            model, params, batch_size=pool, mesh=make_smoke_mesh(n_dev, 1)
        )
        sreqs = make_requests()
        submit_burst(seng, sreqs)
        same = all(a.output == b.output for a, b in zip(reqs, sreqs))
        plan = seng.plan
        print(
            f"sharded engine on {n_dev} devices: outputs "
            f"{'identical' if same else 'DIFFER'}; cache "
            f"{plan.cache_bytes_per_device} B/device vs "
            f"{plan.cache_bytes_total} B replicated; admission "
            f"{seng.admission_rowsteps} row-tokens over "
            f"{seng.admission_prefills} cells"
        )
        if args.smoke:
            assert all(r.done for r in sreqs)
            assert same, "sharded burst diverged from single-device"

    # --- quantized serving comparison -----------------------------------
    prompts = jax.random.randint(jax.random.PRNGKey(42), (4, 12), 0,
                                 cfg.vocab)
    base = E.generate(model, params, prompts, max_new=args.max_new)
    for bits in (8, 4) if not args.smoke else (8,):
        qp = E.quantize_for_serving(params, bits)
        out = E.generate(model, qp, prompts, max_new=args.max_new)
        agree = float(jnp.mean((out == base).astype(jnp.float32)))
        print(f"int{bits} weight-only decode: token agreement vs bf16 "
              f"= {agree:.2f}")

    # --- sampling: per-request folded keys ------------------------------
    sampled = E.generate(
        model, params, prompts, max_new=args.max_new, greedy=False,
        key=jax.random.PRNGKey(7), temperature=0.8, top_k=20,
    )
    again = E.generate(
        model, params, prompts, max_new=args.max_new, greedy=False,
        key=jax.random.PRNGKey(7), temperature=0.8, top_k=20,
    )
    assert (jnp.asarray(sampled) == jnp.asarray(again)).all()
    print(f"sampled (T=0.8, top-k=20, reproducible): "
          f"{jnp.asarray(sampled)[0].tolist()}")

    if args.trace_out:
        tel = obs.get()
        jsonl, chrome = tel.finish(args.trace_out)
        snap = tel.registry.snapshot()
        # the telemetry mirrors of the engine's admission counters must
        # agree with the engine's own accounting (satellite invariant
        # the CI smoke asserts from the telemetry side)
        assert snap["counters"].get("serve.admission_prefills", 0) >= \
            eng.admission_prefills
        print(f"trace written: {jsonl} + {chrome} "
              f"(recompiles: {tel.probe.cache_sizes()})")


if __name__ == "__main__":
    main()
