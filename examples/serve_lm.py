"""Batched LM serving demo: slot-engine + weight-only quantized decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b

Submits a burst of variable-length requests to the slot-based engine
(continuous batching), then repeats with int8/int4 weight-only
quantization — the paper's compressed-storage idea applied to the
memory-bound decode regime — and reports the token agreement between
precisions.
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api
from repro.serve import engine as E


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch)
    model = api.build_model(cfg, tp=1, max_seq=96)
    params = model.init(jax.random.PRNGKey(0))

    # --- slot engine with more requests than slots ----------------------
    eng = E.Engine(model, params, batch_size=args.slots)
    reqs = []
    for i in range(args.requests):
        plen = 4 + (i % 4) * 3  # variable-length prompts
        reqs.append(E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(i), (plen,), 0, cfg.vocab
            ),
            max_new=args.max_new,
        ))
        eng.submit(reqs[-1])
    eng.run()
    print(f"engine: {args.requests} requests over {args.slots} slots")
    for r in reqs:
        print(f"  req {r.uid} (prompt {r.prompt.shape[0]:2d} tok): "
              f"{r.output}")

    # --- quantized serving comparison -----------------------------------
    prompts = jax.random.randint(jax.random.PRNGKey(42), (4, 12), 0,
                                 cfg.vocab)
    base = E.generate(model, params, prompts, max_new=args.max_new)
    for bits in (8, 4):
        qp = E.quantize_for_serving(params, bits)
        out = E.generate(model, qp, prompts, max_new=args.max_new)
        agree = float(jnp.mean((out == base).astype(jnp.float32)))
        print(f"int{bits} weight-only decode: token agreement vs bf16 "
              f"= {agree:.2f}")


if __name__ == "__main__":
    main()
