"""LM training driver over the assigned-architecture substrate.

    # CPU demo (reduced config, a few hundred steps):
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 200

    # ~100M-parameter run (the deliverable-scale driver; slow on CPU):
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300

Uses the same trainer/checkpoint/fault stack as the production launcher;
the paper's technique applies via --spe-bits/--spe-sparse (QAT on every
projection).
"""

import argparse
import dataclasses
import tempfile

import jax

from repro import configs, optim
from repro.configs.base import ArchConfig
from repro.data import lm
from repro.models import api
from repro.train import fault, trainer

HUNDRED_M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab=32768,
    qk_norm=True,
)  # ~100M params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--spe-bits", type=int, default=None)
    ap.add_argument("--spe-sparse", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = HUNDRED_M if args.hundred_m else configs.reduced(args.arch)
    if args.spe_bits or args.spe_sparse:
        cfg = dataclasses.replace(
            cfg, spe_bits=args.spe_bits, spe_sparse=args.spe_sparse
        )
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="lm_ckpt_")

    model = api.build_model(cfg, tp=1, max_seq=args.seq)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M "
          f"spe_bits={cfg.spe_bits} spe_sparse={cfg.spe_sparse}")

    opt = optim.adamw(
        optim.linear_warmup_cosine(args.lr, 20, args.steps),
        weight_decay=0.01,
    )
    state = trainer.init_state(params, opt)
    step = jax.jit(
        trainer.make_train_step(model.loss, opt, clip_norm=1.0),
        donate_argnums=(0,),
    )
    stream = lm.TokenStream(batch=args.batch, seq_len=args.seq,
                            vocab=cfg.vocab, seed=0)

    def batch_at(s):
        b = stream.batch_at(s)
        if cfg.is_enc_dec:
            b["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), s),
                (args.batch, cfg.enc_seq, cfg.d_model),
            )
        return b

    state, history = fault.run_training(
        step, state, batch_at, num_steps=args.steps,
        ckpt_dir=ckpt_dir, ckpt_every=100, log_every=25,
    )
    import math

    uniform = math.log(cfg.vocab)
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f} "
          f"(uniform baseline {uniform:.2f}); ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
