"""Streaming fleet quickstart: 1k synthetic patients on 8 host devices.

    PYTHONPATH=src python examples/stream_fleet.py

Forces 8 host CPU devices (before any jax import), compiles the paper's
VA detector into the chip program, and drives a 1000-patient monitoring
fleet through `repro.stream`: per-patient 250 Hz IEGM streams with
arrival jitter, deadline-aware micro-batching into fixed bucket shapes,
inference sharded over the 8-device data mesh (8 chip twins monitoring
disjoint fleet slices), and batched 6-segment majority voting.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
).strip()

import jax

from repro.core import compiler, vadetect
from repro.launch.stream import make_data_mesh
from repro.stream import FleetConfig, simulate


def main() -> None:
    params = vadetect.init(jax.random.PRNGKey(0))
    program = compiler.compile_model(params)
    mesh = make_data_mesh(8)
    cfg = FleetConfig(
        n_patients=1000,
        segments_per_patient=6,  # one full vote window per patient
        va_fraction=0.05,
        jitter_frac=0.05,
        buckets=(32, 128, 512),
    )
    out = simulate(cfg, program, mesh=mesh)
    m, rt, chip = out["metrics"], out["realtime"], out["chip"]
    print(
        f"fleet: {cfg.n_patients} patients, "
        f"{m['segments_total']} segments in {m['batches_total']} "
        f"batches (pad {m['pad_fraction']:.1%}), dropped="
        f"{m['dropped_total']}"
    )
    print(
        f"throughput: {m['segments_per_s_wall']:.0f} seg/s wall = "
        f"{rt['realtime_factor']:.1f}x real-time; modeled 8-chip fleet "
        f"{chip['modeled_fleet_segments_per_s']:.0f} seg/s"
    )
    sl = m.get("deadline_slack_s")
    if sl:
        print(
            f"deadline slack: p50={sl['p50']*1e3:.0f}ms "
            f"worst-1%={sl['worst_1pct']*1e3:.0f}ms "
            f"violations={sl['violations']}"
        )
    print(
        f"diagnoses: {m['diagnoses_total']} "
        f"(VA={m['va_diagnoses_total']}), synthetic diagnostic "
        f"accuracy {out['accuracy']['diagnostic_accuracy_synthetic']:.3f} "
        f"(untrained weights)"
    )


if __name__ == "__main__":
    main()
