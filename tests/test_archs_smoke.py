"""Per-architecture smoke: every assigned arch (REDUCED config) runs one
forward/train step on CPU with finite outputs and correct shapes."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import api


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_reduced_train_step(name):
    cfg = configs.reduced(name)
    cfg.validate()
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)
        )
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    total = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(total) and total > 0


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_full_config_dims_match_assignment(name):
    """The full configs carry the exact assigned dimensions."""
    cfg = configs.get(name)
    cfg.validate()
    expected = {
        "rwkv6_3b": (32, 2560, 8960, 65536),
        "recurrentgemma_2b": (26, 2560, 7680, 256000),
        "whisper_tiny": (4, 384, 1536, 51865),
        "codeqwen15_7b": (32, 4096, 13440, 92416),
        "qwen3_8b": (36, 4096, 12288, 151936),
        "qwen3_14b": (40, 5120, 17408, 151936),
        "gemma2_9b": (42, 3584, 14336, 256000),
        "llama4_scout_17b_a16e": (48, 5120, 8192, 202048),
        "olmoe_1b_7b": (16, 2048, 1024, 50304),
        "qwen2_vl_72b": (80, 8192, 29568, 152064),
    }[configs.CLI_IDS.get(name, name)]
    assert (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab) == expected


def test_moe_expert_counts():
    assert configs.get("olmoe-1b-7b").moe.num_experts == 64
    assert configs.get("olmoe-1b-7b").moe.top_k == 8
    assert configs.get("llama4-scout-17b-a16e").moe.num_experts == 16
    assert configs.get("llama4-scout-17b-a16e").moe.top_k == 1


def test_gqa_kv_heads():
    for name, kv in [("qwen3-8b", 8), ("qwen3-14b", 8), ("gemma2-9b", 8),
                     ("llama4-scout-17b-a16e", 8), ("qwen2-vl-72b", 8),
                     ("recurrentgemma-2b", 1), ("codeqwen1.5-7b", 32),
                     ("olmoe-1b-7b", 16), ("whisper-tiny", 6)]:
        assert configs.get(name).n_kv_heads == kv, name


def test_applicable_shapes_skip_rules():
    from repro.configs.base import applicable_shapes

    names = lambda cfg: [c.name for c in applicable_shapes(cfg)]
    # long_500k only for ssm/hybrid/chunked-moe
    assert "long_500k" in names(configs.get("rwkv6-3b"))
    assert "long_500k" in names(configs.get("recurrentgemma-2b"))
    assert "long_500k" in names(configs.get("llama4-scout-17b-a16e"))
    for full_attn in ("codeqwen1.5-7b", "qwen3-8b", "qwen3-14b",
                      "gemma2-9b", "qwen2-vl-72b", "whisper-tiny"):
        assert "long_500k" not in names(configs.get(full_attn)), full_attn
    # total cell count across the pool: 10 archs x 4 shapes - 6 skips - but
    # every arch keeps train/prefill/decode = 3 + 3 long cells = 33... the
    # assignment's 40 cells minus documented skips:
    total = sum(len(names(configs.get(a))) for a in configs.CLI_IDS)
    assert total == 33


@pytest.mark.parametrize("tp", [1, 4])
def test_dims_padding(tp):
    from repro.models.transformer import Dims

    cfg = configs.get("qwen3-14b")  # 40 heads, kv 8
    d = Dims.create(cfg, tp)
    assert d.n_heads % tp == 0
    assert d.n_heads % d.n_kv == 0
    assert d.vocab % max(tp, 128) == 0
