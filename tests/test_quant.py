"""core.quant — mixed-bit-width quantization + bit-plane (CMUL) math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant as Q

BITS = [1, 2, 4, 8]


@pytest.mark.parametrize("bits", BITS)
def test_quantize_roundtrip_range(bits):
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    cfg = Q.QuantConfig(bits=bits)
    q, scale = Q.quantize(w, cfg)
    assert q.dtype == jnp.int8
    assert int(q.max()) <= cfg.qmax and int(q.min()) >= cfg.qmin
    deq = Q.dequantize(q, scale)
    # max quantization error bounded by scale/2 per channel (bits>1)
    if bits > 1:
        err = jnp.abs(deq - w)
        assert float((err - scale / 2).max()) < 1e-5


@pytest.mark.parametrize("bits", BITS)
def test_bitplane_roundtrip(bits):
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 16))
    q, _ = Q.quantize(w, Q.QuantConfig(bits=bits))
    planes = Q.to_bitplanes(q, bits)
    assert planes.shape == (bits if bits > 1 else 1, 48, 16)
    back = Q.from_bitplanes(planes, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q, np.int32))


@pytest.mark.parametrize("bits", BITS)
def test_pack_unpack_roundtrip(bits):
    w = jax.random.normal(jax.random.PRNGKey(2), (33, 20))  # odd K
    q, _ = Q.quantize(w, Q.QuantConfig(bits=bits))
    packed = Q.pack_planes(q, bits)
    assert packed.dtype == jnp.uint8
    back = Q.unpack_planes(packed, bits, 33)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@pytest.mark.parametrize("bits", BITS)
def test_bitserial_equals_dense(bits):
    """CMUL shift-accumulate == dequant matmul (the chip's core claim)."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (64, 24))
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    q, scale = Q.quantize(w, Q.QuantConfig(bits=bits))
    y_bits = Q.bitserial_matmul_exact(x, q, bits)
    y_dense = x @ q.astype(jnp.float32)
    np.testing.assert_allclose(y_bits, y_dense, rtol=1e-5, atol=1e-4)


def test_fake_quant_ste_gradient():
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8))
    g = jax.grad(lambda w: jnp.sum(Q.fake_quant(w, 8, True) * 2.0))(w)
    np.testing.assert_allclose(g, jnp.full_like(w, 2.0))


def test_fake_quant_idempotent():
    w = jax.random.normal(jax.random.PRNGKey(6), (32, 8))
    w1 = Q.fake_quant(w, 8, True)
    w2 = Q.fake_quant(w1, 8, True)
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from(BITS),
    k=st.integers(4, 64),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_property(bits, k, n, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    q, _ = Q.quantize(w, Q.QuantConfig(bits=bits))
    back = Q.unpack_planes(Q.pack_planes(q, bits), bits, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_storage_bits():
    assert Q.storage_bits((64, 32), 4) == 64 * 32 * 4
