"""Serving: prefill+decode == teacher-forced; engine; quantized serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models.transformer import forward_train
from repro.serve import engine as E

# one representative per family (full matrix runs in test_archs_smoke)
FAMILIES = ["qwen3_8b", "olmoe_1b_7b", "recurrentgemma_2b", "rwkv6_3b",
            "whisper_tiny", "llama4_scout_17b_a16e"]


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_teacher_forced(name):
    cfg = configs.reduced(name)
    S, B, NEW = 12, 2, 3
    model = api.build_model(cfg, tp=1, max_seq=S + NEW + 1)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    newt = jax.random.randint(jax.random.PRNGKey(2), (B, NEW), 0, cfg.vocab)
    allt = jnp.concatenate([toks, newt], 1)
    if cfg.is_enc_dec:
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.enc_seq, cfg.d_model)
        )
        from repro.models import whisper as W

        last, cache = model.prefill(params, toks, frames)
        enc = W.encode(params, frames, cfg, model.dims)
        full = W.decode_train(params, allt, enc, cfg, model.dims)
    else:
        last, cache = model.prefill(params, toks)
        full, _ = forward_train(params, allt, cfg, model.dims)
    np.testing.assert_allclose(last, full[:, S - 1], rtol=3e-2, atol=3e-2)
    for t in range(NEW):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, cache = model.decode_step(params, cache, allt[:, S + t], pos)
        np.testing.assert_allclose(lg, full[:, S + t], rtol=4e-2, atol=4e-2)


def test_generate_greedy_deterministic():
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=40)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    out1 = E.generate(model, params, prompts, max_new=6)
    out2 = E.generate(model, params, prompts, max_new=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_engine_slots_and_recycling():
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=64)
    params = model.init(jax.random.PRNGKey(0))
    eng = E.Engine(model, params, batch_size=2)
    reqs = [
        E.Request(uid=i,
                  prompt=jax.random.randint(
                      jax.random.PRNGKey(i), (5,), 0, cfg.vocab),
                  max_new=4)
        for i in range(3)  # 3 requests, 2 slots -> forces recycling
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=50)
    for r in reqs:
        assert r.done and len(r.output) == 4


def test_engine_eos_on_first_token_recycles_slot():
    """Regression: a request finishing on the same tick it was admitted
    (EOS as its very first generated token) must not leak its slot —
    later queued requests still get seated and completed."""
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=64)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(9), (5,), 0, cfg.vocab)
    # discover the greedy first token for this prompt
    probe = E.Request(uid=0, prompt=prompt, max_new=2)
    eng = E.Engine(model, params, batch_size=1)
    eng.submit(probe)
    eng.tick()
    first = probe.output[0]

    eng = E.Engine(model, params, batch_size=1)
    eos_reqs = [
        E.Request(uid=i, prompt=prompt, max_new=8, eos=first)
        for i in range(1, 4)
    ]
    tail = E.Request(uid=9, prompt=prompt, max_new=3)
    for r in (*eos_reqs, tail):
        eng.submit(r)
    eng.run(max_ticks=30)
    for r in eos_reqs:
        assert r.done and r.output == [first], r
    assert tail.done and len(tail.output) == 3
    # pool fully recycled: no occupied slots, no active flags
    assert all(s is None for s in eng._slots)
    assert not bool(eng.active.any())


def test_engine_coadmission_does_not_corrupt_seated_slots():
    """Regression: admitting request B while A is seated re-decodes the
    whole pool during B's prefill; A's cache must see an idempotent
    replay of its committed state, not its pending token — A's output
    must match a solo run."""
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=64)
    params = model.init(jax.random.PRNGKey(0))
    prompt_a = jax.random.randint(jax.random.PRNGKey(1), (5,), 0, cfg.vocab)
    prompt_b = jax.random.randint(jax.random.PRNGKey(2), (5,), 0, cfg.vocab)

    solo = E.Engine(model, params, batch_size=2)
    ra = E.Request(uid=0, prompt=prompt_a, max_new=6)
    solo.submit(ra)
    solo.run(max_ticks=20)

    duo = E.Engine(model, params, batch_size=2)
    ra2 = E.Request(uid=1, prompt=prompt_a, max_new=6)
    rb = E.Request(uid=2, prompt=prompt_b, max_new=6)
    duo.submit(ra2)
    duo.submit(rb)
    duo.run(max_ticks=20)
    assert ra2.output == ra.output, (ra2.output, ra.output)


def test_quantized_serving_logits_close():
    """int8 weight-only serving keeps the logit surface close to the
    dense path (argmax agreement on a random-init tiny model is noise —
    the near-uniform logits flip on tiny perturbations — so we assert
    logit correlation, which is what transfers to trained models)."""
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=40)
    params = model.init(jax.random.PRNGKey(0))
    qparams = E.quantize_for_serving(params, bits=8)
    # format check: projections packed, embeddings dense
    blk = qparams["blocks"]["pos0"]
    assert "packed" in blk["mix"]["wq"]
    assert "w" in qparams["embed"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 cfg.vocab)
    ld, _ = jax.jit(model.prefill)(params, prompts)
    lq, _ = jax.jit(model.prefill)(qparams, prompts)
    a = np.asarray(ld, np.float64).ravel()
    b = np.asarray(lq, np.float64).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.98, corr


def test_quantized_params_smaller():
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=16)
    params = model.init(jax.random.PRNGKey(0))
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t)
                   if hasattr(x, "dtype"))
    # projection weights (the quantized targets) shrink ~8x at int4;
    # embeddings/norms stay dense, so compare the blocks subtree.
    q4 = E.quantize_for_serving(params, bits=4)
    q8 = E.quantize_for_serving(params, bits=8)
    assert nbytes(q4["blocks"]) < 0.26 * nbytes(params["blocks"])
    assert nbytes(q8["blocks"]) < 0.45 * nbytes(params["blocks"])
    assert nbytes(q4) < nbytes(q8) < nbytes(params)


def test_va_service_end_to_end():
    from repro.configs import va_cnn
    from repro.core import compiler, vadetect
    from repro.data import iegm
    from repro.serve.va_service import VAService

    params = vadetect.init(jax.random.PRNGKey(0), va_cnn.CONFIG)
    program = compiler.compile_model(params, va_cnn.CONFIG)
    svc = VAService(program, va_cnn.CONFIG)
    batch = iegm.synth_diagnosis_batch(jax.random.PRNGKey(1), 4)
    out = svc.diagnose_batch(batch["signal"])
    assert len(out) == 4
    assert all(len(d.segment_preds) == 6 for d in out)
    assert out[0].chip_latency_us > 0


def test_submit_guards_invalid_and_duplicate_uid():
    """`submit` rejects max_new <= 0 and a uid already in flight with
    actionable errors (a duplicate would clobber the live request's
    TTFT accounting and collide its sampling stream); uid reuse AFTER
    completion stays legal — the frontend and warmup paths rely on it."""
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    eng = E.Engine(model, params, batch_size=2)

    def req(uid, max_new=3):
        return E.Request(
            uid=uid,
            prompt=jax.random.randint(
                jax.random.PRNGKey(uid), (4,), 0, cfg.vocab
            ),
            max_new=max_new,
        )

    with pytest.raises(ValueError, match="max_new must be >= 1"):
        eng.submit(req(0, max_new=0))
    with pytest.raises(ValueError, match="max_new must be >= 1"):
        eng.submit(req(0, max_new=-2))

    r = req(1)
    eng.submit(r)
    with pytest.raises(ValueError, match="uid already in flight"):
        eng.submit(req(1))
    eng.run(max_ticks=50)
    assert r.done and len(r.output) == 3

    r2 = req(1)  # same uid, prior request finished: legal reuse
    eng.submit(r2)
    eng.run(max_ticks=50)
    assert r2.done and len(r2.output) == 3
