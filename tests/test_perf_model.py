"""Chip perf model must land on the paper's measured operating point."""

import pytest

from repro.core import perf_model, vadetect


def _report():
    meta = vadetect.layer_shapes(vadetect.VAConfig())
    wls = [
        perf_model.LayerWorkload(
            name=m["name"], c_in=m["c_in"], c_out=m["c_out"],
            ksize=m["ksize"], t_out=m["t_out"], macs=m["macs"],
            bits=m["bits"], keep_frac=m["keep_frac"], sparse=m["sparse"],
        )
        for m in meta
    ]
    return perf_model.chip_report(wls)


def test_latency_near_paper():
    r = _report()
    # paper: 35 us per inference
    assert r.latency_s * 1e6 == pytest.approx(35.0, rel=0.25)


def test_effective_gops_near_paper():
    r = _report()
    # paper: 150 GOPS effective (dense-equivalent)
    assert r.effective_gops == pytest.approx(150.0, rel=0.25)


def test_power_near_paper():
    r = _report()
    assert r.avg_power_w * 1e6 == pytest.approx(10.60, rel=0.25)


def test_power_density_beats_sota():
    r = _report()
    density = r.power_density_uw_mm2
    assert density == pytest.approx(0.57, rel=0.3)
    worst_sota = min(
        v["density"] for v in perf_model.PRIOR_WORKS.values()
        if v["density"]
    )
    assert worst_sota / density > 10  # paper claims 14.23x


def test_sparsity_halves_cycles():
    meta = vadetect.layer_shapes(vadetect.VAConfig())
    m = meta[2]
    wl = lambda sparse: perf_model.LayerWorkload(
        name="x", c_in=m["c_in"], c_out=m["c_out"], ksize=m["ksize"],
        t_out=m["t_out"], macs=m["macs"], sparse=sparse,
        keep_frac=0.5 if sparse else 1.0,
    )
    dense = perf_model.layer_cycles(wl(False))
    sparse = perf_model.layer_cycles(wl(True))
    # zero-skip halves the contraction cycles; the fixed per-tile
    # overhead (SPad load/bias/writeback) dilutes the end-to-end ratio
    assert 1.5 < dense.cycles / sparse.cycles <= 2.0


def test_low_bits_reduce_energy_not_cycles():
    meta = vadetect.layer_shapes(vadetect.VAConfig())
    wls8 = [perf_model.LayerWorkload(
        name=m["name"], c_in=m["c_in"], c_out=m["c_out"], ksize=m["ksize"],
        t_out=m["t_out"], macs=m["macs"], bits=8) for m in meta]
    wls4 = [perf_model.LayerWorkload(
        name=m["name"], c_in=m["c_in"], c_out=m["c_out"], ksize=m["ksize"],
        t_out=m["t_out"], macs=m["macs"], bits=4) for m in meta]
    r8, r4 = perf_model.chip_report(wls8), perf_model.chip_report(wls4)
    assert r8.total_cycles == r4.total_cycles
    assert r4.energy_j < r8.energy_j
