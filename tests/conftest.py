import os
import sys

# NOTE: deliberately NOT setting --xla_force_host_platform_device_count
# here — the dry-run (and only the dry-run) uses 512 fake devices; tests
# and benchmarks must see the host's real single device.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # container has no hypothesis: deterministic stub
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device decode equivalence tests — CI "
        "(scripts/ci.sh, 8 forced host devices) runs them; skip "
        "locally with -m 'not slow' or scripts/ci.sh --fast",
    )


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """Per-file test-time report: cumulative call-phase seconds by test
    file, slowest first — so a new (especially multidevice) test file
    ballooning the suite is visible in every run, not discovered by
    bisecting a slow CI."""
    times: dict[str, list] = {}
    for reports in terminalreporter.stats.values():
        for rep in reports:
            if getattr(rep, "when", None) != "call":
                continue
            # nodeid, not location[0]: wrapped tests (hypothesis stub)
            # report their wrapper's code location, which would lump
            # every property test under tests/_hypothesis_stub.py
            entry = times.setdefault(
                rep.nodeid.split("::")[0], [0.0, 0]
            )
            entry[0] += rep.duration
            entry[1] += 1
    if not times:
        return
    terminalreporter.write_sep("-", "per-file test time (call phase)")
    for f, (t, n) in sorted(times.items(), key=lambda kv: -kv[1][0]):
        terminalreporter.write_line(f"{t:8.1f}s  {n:4d} tests  {f}")
