import os
import sys

# NOTE: deliberately NOT setting --xla_force_host_platform_device_count
# here — the dry-run (and only the dry-run) uses 512 fake devices; tests
# and benchmarks must see the host's real single device.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # container has no hypothesis: deterministic stub
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device decode equivalence tests — CI "
        "(scripts/ci.sh, 8 forced host devices) runs them; skip "
        "locally with -m 'not slow'",
    )
