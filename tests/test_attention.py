"""Blockwise attention vs naive oracle; decode parity; GQA/softcap."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attention,
    attention_decode,
    attention_reference,
)


def _qkv(key, b, s, h, kv, hd):
    k1, k2, k3 = jax.random.split(key, 3)
    return (
        jax.random.normal(k1, (b, s, h, hd)),
        jax.random.normal(k2, (b, s, kv, hd)),
        jax.random.normal(k3, (b, s, kv, hd)),
    )


@pytest.mark.parametrize("kind,window", [
    ("global", 0), ("local", 16), ("local", 7), ("chunked", 16),
    ("chunked", 24),  # S % chunk != 0 -> padded path
])
def test_blockwise_matches_reference(kind, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 8, 4, 16)
    out = attention(q, k, v, kind=kind, window=window, block_q=16,
                    block_k=16)
    ref = attention_reference(q, k, v, kind=kind, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_softcap_and_bidirectional():
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 32, 4, 4, 8)
    out = attention(q, k, v, cap=30.0, causal=False, block_q=8, block_k=8)
    ref = attention_reference(q, k, v, cap=30.0, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_cross_attention_different_lengths():
    q, _, _ = _qkv(jax.random.PRNGKey(2), 2, 24, 4, 4, 8)
    _, k, v = _qkv(jax.random.PRNGKey(3), 2, 40, 4, 4, 8)
    out = attention(q, k, v, causal=False, block_q=8, block_k=8)
    ref = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_mqa_and_full_heads():
    # kv=1 (MQA) and kv=h (MHA)
    for kv in (1, 8):
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 32, 8, kv, 8)
        out = attention(q, k, v, block_q=8, block_k=8)
        ref = attention_reference(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kind,window", [
    ("global", 0), ("local", 16), ("chunked", 16),
])
def test_decode_matches_full_forward(kind, window):
    b, s, h, kv, hd = 2, 48, 8, 4, 16
    q, k, v = _qkv(jax.random.PRNGKey(5), b, s, h, kv, hd)
    ref = attention_reference(q, k, v, kind=kind, window=window)[:, -1]
    slot_pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    pos = jnp.full((b,), s - 1, jnp.int32)
    dec = attention_decode(q[:, -1], k, v, slot_pos, pos, kind=kind,
                           window=window)
    np.testing.assert_allclose(dec, ref, rtol=2e-3, atol=2e-3)


def test_decode_blocked_path_matches_direct():
    """Caches > block_k use the online-softmax scan — must be identical."""
    b, s, h, kv, hd = 2, 64, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(6), b, s, h, kv, hd)
    slot_pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    pos = jnp.full((b,), s - 1, jnp.int32)
    direct = attention_decode(q[:, -1], k, v, slot_pos, pos, block_k=s)
    blocked = attention_decode(q[:, -1], k, v, slot_pos, pos, block_k=16)
    np.testing.assert_allclose(blocked, direct, rtol=1e-5, atol=1e-5)


def test_decode_ring_buffer_masks_invalid():
    """Empty slots (-1) and out-of-window positions contribute nothing."""
    b, cap, h, kv, hd = 1, 8, 2, 2, 4
    k = jax.random.normal(jax.random.PRNGKey(7), (b, cap, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, cap, kv, hd))
    q = jax.random.normal(jax.random.PRNGKey(9), (b, h, hd))
    slot_pos = jnp.array([[0, 1, 2, -1, -1, -1, -1, -1]], jnp.int32)
    pos = jnp.array([2], jnp.int32)
    out = attention_decode(q, k, v, slot_pos, pos)
    # reference over the 3 valid slots only
    ref = attention_reference(
        q[:, None], k[:, :3], v[:, :3], causal=False
    )[:, 0]
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
