"""Golden tests for the repro.analysis rule pack + scan machinery.

Each rule gets a bad/good twin: a minimal snippet that must fire the
rule (with the exact count and line), and a corrected twin that must
scan clean — so a rule that silently stops firing (or starts
over-firing) fails here, not in review. On top of the goldens:

  * repo-is-clean — `src/` (plus scripts/benchmarks/examples) under the
    committed baseline produces zero live findings, the regression CI
    gates on;
  * suppression — pragma on-line and line-above, wrong-rule pragma
    ignored, baseline match, stale-baseline detection;
  * the cell auditor's pure-text HLO checks and its end-to-end verdicts
    on tiny known-good / known-bad jit cells.
"""

from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import astpass, cellaudit, hloscan
from repro.analysis.rules import RULES

REPO = Path(__file__).resolve().parent.parent

RULE_IDS = {r.rule_id for r in RULES}


def scan_src(tmp_path, src, rules=RULES, baseline=None):
    p = tmp_path / "snippet.py"
    p.write_text(src)
    return astpass.scan_paths([p], rules, baseline=baseline,
                              root=tmp_path)


def findings_for(tmp_path, src, rule_id):
    res = scan_src(tmp_path, src)
    assert not res.stale_baseline
    return [f for f in res.findings if f.rule == rule_id]


# ---------------------------------------------------------------------------
# golden bad/good twins, one pair per rule
# ---------------------------------------------------------------------------

GOLDENS = {
    # (bad source, expected firing count), good twin
    "np-index-dtype": (
        """\
import numpy as np

def flush(urgent, mask, vals):
    u = np.asarray(urgent)
    mask = mask | u
    idx = np.array(vals)
    return mask[idx], np.nonzero(np.asarray(urgent))
""",
        3,
        """\
import numpy as np

def flush(urgent, mask, vals):
    u = np.asarray(urgent, bool)
    mask = mask | u
    idx = np.array(vals, np.intp)
    return mask[idx], np.nonzero(np.asarray(urgent, bool))
""",
    ),
    "prng-key-reuse": (
        """\
import jax

def sample(key, n):
    noise = jax.random.normal(key, (n,))
    mask = jax.random.bernoulli(key, 0.05, (n,))
    return noise, mask
""",
        1,
        """\
import jax

def sample(key, n):
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(k1, (n,))
    mask = jax.random.bernoulli(k2, 0.05, (n,))
    return noise, mask
""",
    ),
    "traced-python-branch": (
        """\
import jax
import jax.numpy as jnp

@jax.jit
def step(x, n):
    if n > 3:
        return x * 2
    return x
""",
        1,
        """\
import functools

import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnames="n")
def step(x, n):
    if n > 3:
        return x * 2
    if x.ndim > 1:
        return x.sum(0)
    return jnp.where(x > 0, x, -x)
""",
    ),
    "jit-donate-pool": (
        """\
import jax

def scatter_slots(pool, rows, idx):
    return pool.at[idx].set(rows)

seat = jax.jit(scatter_slots)
""",
        1,
        """\
import jax

def scatter_slots(pool, rows, idx):
    return pool.at[idx].set(rows)

seat = jax.jit(scatter_slots, donate_argnums=0)
""",
    ),
    "driver-thread-affinity": (
        """\
from repro.concurrency import driver_thread_only

class Engine:
    @driver_thread_only
    def submit(self, req):
        pass

async def handler(eng, req):
    eng.submit(req)
""",
        1,
        """\
from repro.concurrency import driver_thread_only

class Engine:
    @driver_thread_only
    def submit(self, req):
        pass

def drive(eng, req):
    eng.submit(req)

async def handler(inbox, req):
    inbox.put(req)
    batch = []
    batch.extend([req])
""",
    ),
    "telemetry-eager-format": (
        """\
def emit(tel, name, status):
    tel.registry.counter(f"frontend.{name}_{status}_total").inc()
""",
        1,
        """\
def emit(tel, name, status):
    if tel.enabled:
        tel.registry.counter(f"frontend.{name}_{status}_total").inc()
""",
    ),
    "numpy-in-jit": (
        """\
import jax
import numpy as np

@jax.jit
def classify(x):
    return np.argmax(x, axis=-1)
""",
        1,
        """\
import jax
import jax.numpy as jnp

@jax.jit
def classify(x):
    return jnp.argmax(x, axis=-1)
""",
    ),
    "mutable-default": (
        """\
def admit(pairs, tagged={}):
    tagged["n"] = len(pairs)
    return tagged
""",
        1,
        """\
def admit(pairs, tagged=None):
    tagged = {} if tagged is None else tagged
    tagged["n"] = len(pairs)
    return tagged
""",
    ),
    "broad-except-pass": (
        """\
def drain(q):
    try:
        q.get_nowait()
    except Exception:
        pass
""",
        1,
        """\
import queue

def drain(q):
    try:
        q.get_nowait()
    except queue.Empty:
        return None
""",
    ),
    "wallclock-ban": (
        """\
import time

def measure(fn):
    t0 = time.time()
    fn()
    return time.time() - t0
""",
        2,
        """\
import time

def measure(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
""",
    ),
}


def test_every_rule_has_a_golden():
    assert set(GOLDENS) == RULE_IDS


@pytest.mark.parametrize("rule_id", sorted(GOLDENS))
def test_golden_bad_fires(rule_id, tmp_path):
    bad, n_expected, _good = GOLDENS[rule_id]
    hits = findings_for(tmp_path, bad, rule_id)
    assert len(hits) == n_expected, [f.to_dict() for f in hits]
    for f in hits:
        assert f.path == "snippet.py"
        assert f.line >= 1 and f.message and f.snippet


@pytest.mark.parametrize("rule_id", sorted(GOLDENS))
def test_golden_good_twin_clean(rule_id, tmp_path):
    _bad, _n, good = GOLDENS[rule_id]
    res = scan_src(tmp_path, good)
    assert res.findings == [], [f.to_dict() for f in res.findings]


# ---------------------------------------------------------------------------
# calibration edges that bit during rollout (regression-pinned)
# ---------------------------------------------------------------------------


def test_prng_mutually_exclusive_branches_ok(tmp_path):
    src = """\
import jax

def pick(key, mode, n):
    if mode == "a":
        return jax.random.normal(key, (n,))
    return jax.random.bernoulli(key, 0.5, (n,))
"""
    assert findings_for(tmp_path, src, "prng-key-reuse") == []


def test_prng_reassigned_key_ok(tmp_path):
    src = """\
import jax

def walk(key, n):
    x = jax.random.normal(key, (n,))
    key = jax.random.fold_in(key, 1)
    y = jax.random.normal(key, (n,))
    return x + y
"""
    assert findings_for(tmp_path, src, "prng-key-reuse") == []


def test_affinity_container_local_ok(tmp_path):
    src = """\
from repro.concurrency import driver_thread_only

class Sched:
    @driver_thread_only
    def extend(self, rows):
        pass

async def collect(evs):
    out = []
    out.extend(evs)
    return out
"""
    assert findings_for(tmp_path, src, "driver-thread-affinity") == []


def test_traced_branch_safe_shape_checks_ok(tmp_path):
    src = """\
import jax

@jax.jit
def f(x, y):
    if x.shape[0] > 2 and y is None:
        return x
    if len(x.shape) > 1:
        return x.sum()
    return x * 2
"""
    assert findings_for(tmp_path, src, "traced-python-branch") == []


# ---------------------------------------------------------------------------
# suppression: pragma + baseline + staleness
# ---------------------------------------------------------------------------

_WALL = """\
import time

def stamp():
    return time.time()
"""


def test_pragma_on_line_suppresses(tmp_path):
    src = _WALL.replace(
        "return time.time()",
        "return time.time()  # repro: allow[wallclock-ban] metadata",
    )
    res = scan_src(tmp_path, src)
    assert res.findings == []
    assert [f.suppressed_by for f in res.suppressed] == ["pragma"]


def test_pragma_line_above_suppresses(tmp_path):
    src = _WALL.replace(
        "    return time.time()",
        "    # repro: allow[wallclock-ban] metadata\n"
        "    return time.time()",
    )
    res = scan_src(tmp_path, src)
    assert res.findings == []
    assert [f.suppressed_by for f in res.suppressed] == ["pragma"]


def test_pragma_wrong_rule_does_not_suppress(tmp_path):
    src = _WALL.replace(
        "return time.time()",
        "return time.time()  # repro: allow[mutable-default] nope",
    )
    res = scan_src(tmp_path, src)
    assert [f.rule for f in res.findings] == ["wallclock-ban"]


def test_baseline_suppresses_and_matches(tmp_path):
    baseline = [{
        "rule": "wallclock-ban", "path": "snippet.py",
        "snippet": "return time.time()",
    }]
    res = scan_src(tmp_path, _WALL, baseline=baseline)
    assert res.findings == []
    assert [f.suppressed_by for f in res.suppressed] == ["baseline"]
    assert res.stale_baseline == []


def test_stale_baseline_detected(tmp_path):
    baseline = [{
        "rule": "wallclock-ban", "path": "snippet.py",
        "snippet": "return time.time()  # long gone",
    }]
    res = scan_src(tmp_path, _WALL, baseline=baseline)
    assert [f.rule for f in res.findings] == ["wallclock-ban"]
    assert res.stale_baseline == baseline


def test_committed_baseline_loads_and_is_fresh():
    """Every entry in the checked-in baseline must still match a live
    finding (same check the CLI turns into exit 2)."""
    path = REPO / "analysis_baseline.json"
    baseline = astpass.load_baseline(path)
    assert baseline, "committed baseline exists but is empty"
    res = astpass.scan_paths([REPO / "src"], RULES, baseline=baseline,
                             root=REPO)
    assert res.stale_baseline == [], res.stale_baseline


# ---------------------------------------------------------------------------
# the repo itself is clean — the regression CI gates on
# ---------------------------------------------------------------------------


def test_repo_scans_clean():
    baseline = astpass.load_baseline(REPO / "analysis_baseline.json")
    paths = [
        REPO / d for d in ("src", "scripts", "benchmarks", "examples")
        if (REPO / d).exists()
    ]
    res = astpass.scan_paths(paths, RULES, baseline=baseline, root=REPO)
    assert res.findings == [], [f.to_dict() for f in res.findings]
    assert res.files_scanned > 50


def test_report_schema_shape(tmp_path):
    from repro import analysis

    res = scan_src(tmp_path, _WALL)
    rep = res.to_report(analysis.SCHEMA_VERSION, RULES)
    assert rep["report"] == "analysis"
    assert rep["schema_version"] == analysis.SCHEMA_VERSION
    assert {r["id"] for r in rep["rules"]} == RULE_IDS
    assert all(r["incident"] for r in rep["rules"])
    f = rep["findings"][0]
    assert set(f) == {"rule", "path", "line", "message", "snippet"}


# ---------------------------------------------------------------------------
# hloscan: pure-text HLO checks
# ---------------------------------------------------------------------------


def test_hloscan_f64_and_host_ops():
    text = (
        "HloModule m, input_output_alias={ {}: (0, {}, may-alias) }\n"
        "  %x = f64[4]{0} parameter(0)\n"
        "  %cc = f32[] custom-call(), custom_call_target=\"xla_python_cpu_callback\"\n"
        "  %o = f32[] outfeed(%cc)\n"
    )
    assert hloscan.f64_lines(text) == [2]
    ops = [op for _ln, op in hloscan.host_transfer_ops(text)]
    assert any("callback" in op or "outfeed" in op for op in ops)
    assert hloscan.has_input_output_alias(text)
    assert not hloscan.has_input_output_alias("HloModule m\n")


def test_hloscan_budget():
    counts = {"all-reduce": 5, "all-gather": 2}
    assert hloscan.over_budget(counts, {"all-reduce": 5,
                                        "all-gather": 2}) == []
    over = hloscan.over_budget(counts, {"all-reduce": 4})
    ops = {op for op, _n, _cap in over}
    assert ops == {"all-reduce", "all-gather"}  # absent op allowed 0
    assert hloscan.over_budget(counts, {"all-reduce": "*",
                                        "all-gather": -1}) == []


# ---------------------------------------------------------------------------
# cell auditor end-to-end on tiny cells
# ---------------------------------------------------------------------------


def _cell(fn, **meta):
    from repro.obs import jaxprobe

    return jaxprobe.CellInfo(name="t.cell", fn=fn, **meta)


def test_audit_clean_cell():
    info = _cell(jax.jit(lambda x: x * 2))
    info.call_avals = ((jax.ShapeDtypeStruct((4,), jnp.float32),), {})
    audit = cellaudit.audit_cell(info)
    assert audit.violations == [], audit.violations


def test_audit_never_called_cell():
    audit = cellaudit.audit_cell(_cell(jax.jit(lambda x: x)))
    assert len(audit.violations) == 1
    assert "never called" in audit.violations[0]


def test_audit_flags_callback():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1

    info = _cell(jax.jit(f))
    info.call_avals = ((jax.ShapeDtypeStruct((2,), jnp.float32),), {})
    audit = cellaudit.audit_cell(info)
    assert any("callback" in v for v in audit.violations), audit.violations


def test_audit_flags_budget_blowup(monkeypatch):
    info = _cell(jax.jit(lambda x: x + 1), budget={"all-reduce": 0})
    info.call_avals = ((jax.ShapeDtypeStruct((2,), jnp.float32),), {})
    clean = cellaudit.audit_cell(info)
    assert clean.violations == []  # no collectives at all: within budget

    # a single-device host can't lower a real collective, so inject the
    # inventory a sharded lowering would produce and assert the audit
    # turns it into a budget violation (the real path fires in the
    # decode benchmark's 4x2 prefill cell)
    monkeypatch.setattr(
        cellaudit.hloscan, "collective_counts",
        lambda text: {"all-reduce": 3, "all-to-all": 1},
    )
    audit = cellaudit.audit_cell(info)
    assert len(audit.violations) == 2, audit.violations
    assert all("collective budget exceeded" in v
               for v in audit.violations)
    assert audit.collectives == {"all-reduce": 3, "all-to-all": 1}

    # unbudgeted cells record the inventory but never gate on it
    info.budget = None
    audit = cellaudit.audit_cell(info)
    assert audit.violations == []


def test_audit_flags_dropped_donation():
    # donating an argument the output cannot alias (dtype widens) makes
    # XLA warn and drop the donation -> audit violation
    info = _cell(
        jax.jit(lambda x: (x.astype(jnp.float32), 0),
                donate_argnums=(0,)),
        donate=(0,),
    )
    info.call_avals = ((jax.ShapeDtypeStruct((8,), jnp.int8),), {})
    audit = cellaudit.audit_cell(info)
    assert any("donat" in v.lower() for v in audit.violations), (
        audit.violations
    )


def test_audit_section_shape():
    info = _cell(jax.jit(lambda x: x * 2))
    info.call_avals = ((jax.ShapeDtypeStruct((4,), jnp.float32),), {})
    sec = cellaudit.audit_section({"t.cell": info})
    assert sec["n_cells"] == 1
    assert sec["violations_total"] == 0
    assert set(sec["cells"]) == {"t.cell"}
    assert set(sec["cells"]["t.cell"]) == {
        "violations", "collectives", "donation_aliased",
    }


def test_tracked_cell_captures_avals_and_delegates():
    from repro import obs

    obs.configure(enabled=True)
    try:
        tel = obs.get()
        cell = tel.probe.track("t.capture", jax.jit(lambda x: x + 1))
        out = cell(jnp.ones((3,), jnp.float32))
        assert float(out.sum()) == 6.0
        cells = tel.probe.cells()
        assert "t.capture" in cells
        (args, kwargs) = cells["t.capture"].call_avals
        assert kwargs == {}
        assert args[0].shape == (3,) and args[0].dtype == jnp.float32
        audit = cellaudit.audit_cell(cells["t.capture"])
        assert audit.violations == []
    finally:
        obs.reset()
