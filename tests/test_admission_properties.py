"""Cross-admission equivalence property harness.

The engine admits by batched prefill + per-slot cache scatter
(`serve.seating`); `generate` / `sharded_generate` run one prefill +
straight decode steps. These are different code paths over the same
math, so the contract is checkable: under hypothesis-driven random
admit/tick/finish interleavings (variable prompt lengths, co-admission,
EOS cuts, slot recycling), every request's token stream from the engine
must be token-for-token identical to its solo `generate` stream — for
attention *and* recurrent (rg-lru, rwkv) architectures, whose caches
scatter seating made first-class engine tenants.

Single-device properties run in the fast lane; the 8-device data/TP
mesh properties are `slow`-marked and run in CI (`scripts/ci.sh`, 8
forced host devices). The file also pins the satellites that ride on
the same machinery: sampling determinism (per-request folded keys:
reproducible across runs and seat order; greedy untouched), the
`sample_tokens` top-k edge cases, seating scatter/gather inverses, and
the typed enc-dec guard with its actionable message.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import api
from repro.serve import engine as E
from repro.serve import seating
from repro.serve import sharded as SH

# one attention family + both recurrent families: the archs whose
# engine admission the scatter-seat refactor changed most
ARCHS = ("qwen3_8b", "recurrentgemma_2b", "rwkv6_3b")

MAX_SEQ = 24
PROMPT_LENS = (2, 3, 4)  # bounded so prefill cells compile a few shapes


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = configs.reduced(name)
        model = api.build_model(cfg, tp=1, max_seq=MAX_SEQ)
        params = model.init(jax.random.PRNGKey(0))
        # shared jitted cells so hypothesis examples don't retrace
        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step)

        class FastEngine(E.Engine):
            def _compile_decode(self, _decode=decode):
                return _decode

            def _admission_cell(self, rows, _prefill=prefill):
                if not hasattr(self, "_seat_jit"):
                    self._seat_jit = jax.jit(
                        seating.scatter_slots, donate_argnums=0
                    )
                return _prefill, self._seat_jit, lambda p: p

        out[name] = (model, params, FastEngine, prefill, decode)
    return out


def _ref_stream(prefill, decode, params, req: E.Request) -> list:
    """Solo greedy prefill+decode reference for one request — the
    `generate` recipe on shared jitted cells, truncated the way the
    engine truncates (EOS inclusive, max_new cap)."""
    prompt = jnp.asarray(req.prompt, jnp.int32)[None]
    s = prompt.shape[1]
    logits, cache = prefill(params, prompt)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = []
    for t in range(req.max_new):
        out.append(int(tok[0]))
        if req.eos is not None and out[-1] == req.eos:
            break
        if len(out) >= req.max_new:
            break
        pos = jnp.full((1,), s + t, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return out


def _make_requests(cfg, rng, n, *, eos_pool=None):
    reqs = []
    for i in range(n):
        s_len = int(rng.choice(PROMPT_LENS))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1000 + i), (s_len,), 0, cfg.vocab
        )
        eos = None
        if eos_pool is not None and rng.random() < 0.4:
            eos = int(rng.choice(eos_pool))
        reqs.append(
            E.Request(
                uid=i, prompt=prompt,
                max_new=int(rng.integers(1, 5)), eos=eos,
            )
        )
    return reqs


def _drive_random_interleaving(eng, reqs, rng, max_steps=200):
    pending = list(reqs)
    steps = 0
    while (pending or eng._queue
           or any(s is not None for s in eng._slots)) and steps < max_steps:
        steps += 1
        if pending and (rng.random() < 0.6 or not eng._queue):
            for _ in range(int(rng.integers(1, 3))):
                if pending:
                    eng.submit(pending.pop(0))
        eng.tick()
    assert steps < max_steps, "interleaving did not drain"


@pytest.mark.parametrize("name", ARCHS)
@settings(max_examples=5, deadline=None)
@given(
    batch_size=st.sampled_from([2, 3]),
    n_reqs=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_engine_matches_generate_under_random_interleavings(
    built, name, batch_size, n_reqs, seed
):
    """The scatter-seated engine is token-for-token identical to the
    prefill+decode generate path, for every request, under random
    admit/tick interleavings — including recurrent-cache models at
    batch_size > 1 (the lifted PR 3 guard)."""
    model, params, FastEngine, prefill, decode = built[name]
    rng = np.random.default_rng(seed)
    # EOS drawn from the first request's own reference stream, so EOS
    # cuts (including EOS-on-first-token) actually trigger sometimes
    probe = _ref_stream(
        prefill, decode, params,
        E.Request(uid=0, prompt=jax.random.randint(
            jax.random.PRNGKey(1000), (PROMPT_LENS[0],), 0,
            model.cfg.vocab
        ), max_new=4),
    )
    reqs = _make_requests(model.cfg, rng, n_reqs, eos_pool=probe)
    eng = FastEngine(model, params, batch_size=batch_size)
    _drive_random_interleaving(eng, reqs, rng)
    for r in reqs:
        assert r.done, r.uid
        ref = _ref_stream(prefill, decode, params, r)
        assert r.output == ref, (name, r.uid, r.output, ref)


def test_fast_reference_equals_public_generate(built):
    """The shared-jit reference the harness uses IS `generate`: pin the
    two bitwise on one batch so the property above transitively checks
    the public path."""
    model, params, _, prefill, decode = built["qwen3_8b"]
    prompts = jax.random.randint(
        jax.random.PRNGKey(3), (2, 4), 0, model.cfg.vocab
    )
    got = np.asarray(E.generate(model, params, prompts, max_new=5))
    for b in range(2):
        ref = _ref_stream(
            prefill, decode, params,
            E.Request(uid=b, prompt=prompts[b], max_new=5),
        )
        assert got[b].tolist() == ref


@pytest.mark.parametrize("name", ("recurrentgemma_2b", "rwkv6_3b"))
def test_recurrent_batched_engine_decodes_correctly(built, name):
    """Acceptance: recurrent-cache models decode through `Engine` at
    batch_size > 1, token-for-token identical to `generate` — the
    co-admitted pool never corrupts a seated recurrent state."""
    model, params, FastEngine, prefill, decode = built[name]
    # these archs really carry step-advancing caches (the case the
    # lifted PR 3 guard existed for)
    assert api.is_recurrent(model.cfg)
    eng = FastEngine(model, params, batch_size=2)
    reqs = [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(1000 + i), (4,), 0, model.cfg.vocab
            ),
            max_new=5,
        )
        for i in range(3)  # forces recycling through the 2-slot pool
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=40)
    for r in reqs:
        assert r.done
        assert r.output == _ref_stream(prefill, decode, params, r), r.uid


# ---------------------------------------------------------------------------
# Sampling determinism
# ---------------------------------------------------------------------------


def test_greedy_outputs_unaffected_by_sampling_machinery(built):
    """The greedy path stays pure argmax: an engine built with sampling
    parameters but greedy=True produces the same stream as the default
    engine and as `generate`."""
    model, params, FastEngine, prefill, decode = built["qwen3_8b"]
    outs = []
    for key in (None, jax.random.PRNGKey(99)):
        eng = FastEngine(
            model, params, batch_size=2, greedy=True,
            temperature=0.7, top_k=3, key=key,
        )
        reqs = [
            E.Request(uid=i, prompt=jax.random.randint(
                jax.random.PRNGKey(1000 + i), (3,), 0, model.cfg.vocab
            ), max_new=4)
            for i in range(2)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=20)
        outs.append([r.output for r in reqs])
    assert outs[0] == outs[1]
    for r_out, req_uid in zip(outs[0], range(2)):
        ref = _ref_stream(
            prefill, decode, params,
            E.Request(uid=req_uid, prompt=jax.random.randint(
                jax.random.PRNGKey(1000 + req_uid), (3,),
                0, model.cfg.vocab
            ), max_new=4),
        )
        assert r_out == ref


def _sampled_outputs(built_entry, model, params, order, *, key):
    FastEngine = built_entry[2]
    eng = FastEngine(
        model, params, batch_size=2, greedy=False,
        temperature=0.8, top_k=5, key=key,
    )
    reqs = {
        uid: E.Request(uid=uid, prompt=jax.random.randint(
            jax.random.PRNGKey(1000 + uid), (3,), 0, model.cfg.vocab
        ), max_new=4)
        for uid in order
    }
    for uid in order:
        eng.submit(reqs[uid])
    eng.run(max_ticks=30)
    return {uid: r.output for uid, r in reqs.items()}


def test_sampling_reproducible_across_runs_and_seat_order(built):
    """Temperature/top-k streams are a function of (key, uid, t) only:
    identical across runs, and invariant to submission order — which
    reshuffles seats, co-tenants and recycling."""
    entry = built["qwen3_8b"]
    model, params = entry[0], entry[1]
    key = jax.random.PRNGKey(7)
    a = _sampled_outputs(entry, model, params, [0, 1, 2], key=key)
    b = _sampled_outputs(entry, model, params, [0, 1, 2], key=key)
    c = _sampled_outputs(entry, model, params, [2, 0, 1], key=key)
    assert a == b, "sampling not reproducible across runs"
    assert a == c, "sampling depends on seat order"
    # a different engine key gives different streams (the key matters)
    d = _sampled_outputs(
        entry, model, params, [0, 1, 2], key=jax.random.PRNGKey(8)
    )
    assert a != d


def test_engine_sampling_matches_generate_schedule(built):
    """With uid == row index and one co-admitted batch, the engine's
    per-request folded keys reproduce `generate`'s sampled streams
    token-for-token."""
    model, params, FastEngine, _, _ = built["qwen3_8b"]
    key = jax.random.PRNGKey(21)
    prompts = jax.random.randint(
        jax.random.PRNGKey(5), (2, 3), 0, model.cfg.vocab
    )
    ref = np.asarray(E.generate(
        model, params, prompts, max_new=4, greedy=False, key=key,
        temperature=0.8, top_k=5,
    ))
    eng = FastEngine(
        model, params, batch_size=2, greedy=False,
        temperature=0.8, top_k=5, key=key,
    )
    reqs = [
        E.Request(uid=i, prompt=prompts[i], max_new=4) for i in range(2)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=20)
    for i, r in enumerate(reqs):
        assert r.output == ref[i].tolist(), (r.output, ref[i].tolist())


def test_sample_tokens_topk_edge_cases():
    """logits -> sample unit tests: k=1 is argmax; k >= vocab equals
    unmasked sampling; threshold ties stay eligible and deterministic;
    temperature <= 0 is greedy."""
    v = 11
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, v))
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(3)])
    # k=1: the single retained logit must win at any temperature
    got = E.sample_tokens(logits, keys, temperature=2.5, top_k=1)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.argmax(logits, -1))
    )
    # k >= vocab: mask is a no-op — bitwise-identical draws
    full = E.sample_tokens(logits, keys, temperature=0.9, top_k=0)
    for k in (v, v + 7):
        np.testing.assert_array_equal(
            np.asarray(E.sample_tokens(logits, keys, temperature=0.9,
                                       top_k=k)),
            np.asarray(full),
        )
    # temperature <= 0 degenerates to greedy argmax
    got = E.sample_tokens(logits, keys, temperature=0.0, top_k=4)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.argmax(logits, -1))
    )
    # ties at the k-th value: both tied maxima stay eligible, draws are
    # deterministic per key, and across many keys both outcomes occur
    tied = jnp.zeros((1, v)).at[0, 2].set(5.0).at[0, 9].set(5.0)
    draws = set()
    for i in range(64):
        k1 = jax.random.PRNGKey(100 + i)[None]
        t1 = int(E.sample_tokens(tied, k1, temperature=1.0, top_k=1)[0])
        t2 = int(E.sample_tokens(tied, k1, temperature=1.0, top_k=1)[0])
        assert t1 == t2, "tied draw not deterministic for a fixed key"
        assert t1 in (2, 9), t1
        draws.add(t1)
    assert draws == {2, 9}, f"tie never explored both sides: {draws}"


# ---------------------------------------------------------------------------
# Seating: scatter/gather inverses, non-seated rows untouched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("qwen3_8b", "rwkv6_3b"))
def test_scatter_then_gather_roundtrips_and_preserves_others(built, name):
    model, params, _, prefill, _ = built[name]
    pool = model.init_cache(4)
    before = jax.tree.map(np.asarray, pool)
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (2, 3), 0, model.cfg.vocab
    )
    _, rows = prefill(params, prompts)
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([3, 1], jnp.int32)
    seated = seating.scatter_slots(pool, rows, src, dst)
    # gather returns exactly the seated rows, in order
    back = seating.gather_slots(seated, dst)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(rows)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-seated slots (0, 2) are bit-untouched
    untouched = seating.gather_slots(
        seated, jnp.asarray([0, 2], jnp.int32)
    )
    orig = seating.gather_slots(pool, jnp.asarray([0, 2], jnp.int32))
    for a, b in zip(jax.tree.leaves(untouched), jax.tree.leaves(orig)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the input pool itself was not mutated (pure function)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_scatter_slots_rejects_mismatched_trees(built):
    model, params, _, prefill, _ = built["qwen3_8b"]
    pool = model.init_cache(2)
    with pytest.raises(ValueError, match="leaves"):
        seating.scatter_slots(
            pool, {"not": jnp.zeros((1,))},
            jnp.asarray([0], jnp.int32), jnp.asarray([0], jnp.int32),
        )


# ---------------------------------------------------------------------------
# Enc-dec guard: typed error, actionable message
# ---------------------------------------------------------------------------


def test_encdec_guard_raises_typed_actionable_error():
    """`sharded.compile_decode` (and the engine / generate fronts) must
    reject whisper-family models with `EncDecUnsupportedError`, naming
    the model and saying what to do instead — so the open 'frames-aware
    prefill' ROADMAP item fails loudly, not by drifting."""
    cfg = configs.reduced("whisper_tiny")
    model = api.build_model(cfg, tp=1, max_seq=16)
    # avals suffice: the guard must fire before any real work
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    from repro.launch.mesh import make_smoke_mesh

    plan = SH.plan_decode(
        model, params, make_smoke_mesh(1, 1), batch_size=2
    )
    with pytest.raises(E.EncDecUnsupportedError) as ei:
        SH.compile_decode(model, plan)
    msg = str(ei.value)
    assert cfg.name in msg  # names the offending model
    assert "frames-aware prefill" in msg  # names the missing feature
    # actionable: tells the caller the working path to use today
    assert "model.prefill(params, tokens, frames)" in msg
    assert "decode_step" in msg

    with pytest.raises(E.EncDecUnsupportedError):
        E.Engine(model, params, batch_size=2)
    with pytest.raises(E.EncDecUnsupportedError):
        E.generate(model, params, jnp.zeros((1, 4), jnp.int32), max_new=1)


# ---------------------------------------------------------------------------
# Multi-device: the same properties on the 8-device data / TP meshes
# ---------------------------------------------------------------------------

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (scripts/ci.sh forces 8 host devices)",
)

# On a mesh the reference must be mesh-compiled too: an FSDP data axis
# re-gathers parameters, a model axis psums row-parallel contractions —
# either changes the fp reduction surface vs one device, and random-init
# logits are near-uniform enough that a bf16-level wiggle can flip a
# greedy token (test_decode_multidevice pins the cases where it happens
# not to). The engine's contract is against `sharded_generate` on the
# SAME mesh: identical cells, identical placement, zero fp slack.
MESH_CASES = [
    ("qwen3_8b", (8, 1)),
    ("recurrentgemma_2b", (8, 1)),
    ("qwen3_8b", (4, 2)),
]


def _mesh_ref_cells(model, params, mesh):
    """`sharded_generate`'s compiled cells for an 8-row pool on `mesh`:
    refs below broadcast one prompt across all rows and read row 0, so
    the solo stream goes through the exact placement the engine uses."""
    plan = SH.plan_decode(model, params, mesh, batch_size=8)
    prefill, decode = SH.compile_decode(model, plan)
    placed = SH.place_params(params, plan)
    return plan, prefill, decode, placed


def _mesh_ref_stream(cells, req: E.Request) -> list:
    plan, prefill, decode, placed = cells
    s = int(req.prompt.shape[0])
    prompts = jax.device_put(
        jnp.broadcast_to(
            jnp.asarray(req.prompt, jnp.int32)[None], (8, s)
        ),
        plan.prompts,
    )
    logits, cache = prefill(placed, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = []
    for t in range(req.max_new):
        out.append(int(tok[0]))
        if req.eos is not None and out[-1] == req.eos:
            break
        if len(out) >= req.max_new:
            break
        pos = jax.device_put(
            jnp.full((8,), s + t, jnp.int32), plan.token
        )
        logits, cache = decode(
            placed, cache, jax.device_put(tok, plan.token), pos
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return out


@pytest.mark.slow
@multidevice
@pytest.mark.parametrize("name,mesh_shape", MESH_CASES)
@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_sharded_engine_matches_sharded_generate_under_interleavings(
    built, name, mesh_shape, seed
):
    """The mesh-placed engine — batched sharded prefill admission,
    scatter seating under explicit shardings, recurrent caches included
    — stays token-for-token identical to the `sharded_generate` cells
    on the same mesh, under random interleavings."""
    from repro.launch.mesh import make_smoke_mesh

    model, params, _, _, _ = built[name]
    mesh = make_smoke_mesh(*mesh_shape)
    cells = _mesh_ref_cells(model, params, mesh)
    rng = np.random.default_rng(seed)
    reqs = _make_requests(model.cfg, rng, 4)
    eng = SH.ShardedEngine(model, params, batch_size=8, mesh=mesh)
    _drive_random_interleaving(eng, reqs, rng)
    for r in reqs:
        assert r.done, r.uid
        ref = _mesh_ref_stream(cells, r)
        assert r.output == ref, (name, mesh_shape, r.uid, r.output, ref)
    assert all(s is None for s in eng._slots)
    assert not bool(eng.active.any())


@pytest.mark.slow
@multidevice
def test_sharded_engine_batched_recurrent_on_data_mesh(built):
    """Acceptance: recurrent-cache models decode through ShardedEngine
    at batch_size > 1 on the 8-device data mesh, matching
    `sharded_generate` (itself pinned to the single-device path in
    test_decode_multidevice) token-for-token."""
    from repro.launch.mesh import make_smoke_mesh

    model, params, _, _, _ = built["recurrentgemma_2b"]
    mesh = make_smoke_mesh(8, 1)
    prompts = jax.random.randint(
        jax.random.PRNGKey(11), (8, 4), 0, model.cfg.vocab
    )
    ref = np.asarray(SH.sharded_generate(
        model, params, prompts, mesh=mesh, max_new=4
    ))
    eng = SH.ShardedEngine(model, params, batch_size=8, mesh=mesh)
    reqs = [
        E.Request(uid=i, prompt=prompts[i], max_new=4) for i in range(8)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=30)
    for i, r in enumerate(reqs):
        assert r.done
        assert r.output == ref[i].tolist(), (i, r.output, ref[i].tolist())


@pytest.mark.slow
@multidevice
def test_sharded_sampling_reproducible_on_data_mesh(built):
    """Per-request folded keys survive sharding: sampled streams on the
    8-device mesh are reproducible across runs and across seat order."""
    from repro.launch.mesh import make_smoke_mesh

    model, params, _, _, _ = built["qwen3_8b"]
    mesh = make_smoke_mesh(8, 1)
    key = jax.random.PRNGKey(13)

    def run(order):
        eng = SH.ShardedEngine(
            model, params, batch_size=8, mesh=mesh, greedy=False,
            temperature=0.8, top_k=5, key=key,
        )
        reqs = {
            uid: E.Request(uid=uid, prompt=jax.random.randint(
                jax.random.PRNGKey(1000 + uid), (3,), 0, model.cfg.vocab
            ), max_new=3)
            for uid in order
        }
        for uid in order:
            eng.submit(reqs[uid])
        eng.run(max_ticks=20)
        return {uid: r.output for uid, r in reqs.items()}

    a = run([0, 1, 2])
    b = run([0, 1, 2])
    c = run([2, 0, 1])
    assert a == b and a == c
