"""Property tests for the serving engine's slot admission machinery.

Random interleavings of submit / tick (hypothesis; deterministic stub
in CI) must never exceed slot capacity, never starve an admitted
request, and keep the last-fed-(token,pos) shim contract: re-feeding
the pool the state its last decode fed it is a bitwise no-op on
attention caches (k/v writes depend only on (token, pos)). These are
the invariants `serve.sharded.ShardedEngine` inherits wholesale, so
they are pinned here once, on the cheap single-device engine.

Admission itself is batched prefill + per-slot cache scatter
(`serve.seating`), which overwrites a seated slot's entire cache row —
so recurrent-cache models are first-class engine tenants at any batch
size; their token-for-token equivalence with `generate` is pinned in
`tests/test_admission_properties.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import api
from repro.serve import engine as E

CFG = configs.reduced("qwen3_8b")


@pytest.fixture(scope="module")
def built():
    model = api.build_model(CFG, tp=1, max_seq=64)
    params = model.init(jax.random.PRNGKey(0))
    # one shared jitted decode so hypothesis examples don't retrace
    decode = jax.jit(model.decode_step)

    class FastEngine(E.Engine):
        def _compile_decode(self):
            return decode

    return model, params, FastEngine


def _occupied(eng):
    return [i for i, s in enumerate(eng._slots) if s is not None]


def _check_invariants(eng):
    occ = _occupied(eng)
    assert len(occ) <= eng.batch
    active = np.asarray(eng.active)
    # active flags mirror occupancy exactly — a leaked flag would make
    # tick() advance a free slot and corrupt the next tenant's prefill
    assert sorted(np.nonzero(active)[0].tolist()) == occ
    for i in occ:
        req = eng._slots[i]
        assert not req.done
        assert 1 <= len(req.output) < req.max_new


@settings(max_examples=8, deadline=None)
@given(
    batch_size=st.sampled_from([1, 2]),
    n_reqs=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_random_interleavings_keep_slot_invariants(
    built, batch_size, n_reqs, seed
):
    model, params, FastEngine = built
    rng = np.random.default_rng(seed)
    eng = FastEngine(model, params, batch_size=batch_size)
    reqs = [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(seed + i),
                (int(rng.integers(1, 5)),), 0, CFG.vocab,
            ),
            max_new=int(rng.integers(1, 4)),
        )
        for i in range(n_reqs)
    ]
    pending = list(reqs)
    steps = 0
    while (pending or eng._queue or _occupied(eng)) and steps < 200:
        steps += 1
        if pending and (rng.random() < 0.5 or not eng._queue):
            for _ in range(int(rng.integers(1, 3))):
                if pending:
                    eng.submit(pending.pop(0))
        eng.tick()
        _check_invariants(eng)
    # no starvation: every submitted request completed within the
    # interleaving horizon, with a well-formed output
    assert steps < 200
    for r in reqs:
        assert r.done, r.uid
        assert 1 <= len(r.output) <= r.max_new
        if len(r.output) < r.max_new:
            assert r.eos is not None and r.output[-1] == r.eos


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_committed_replay_is_bitwise_noop_on_cache(built, seed):
    """After any ticked state, decoding the pool with its last-fed
    (token, pos) — the retransmission shim — must leave every cache
    leaf bit-identical: attention k/v writes depend only on (token,
    pos), never on cache contents."""
    # precondition of the whole replay contract: this only holds for
    # attention caches (recurrent states advance on every step)
    assert not api.is_recurrent(CFG)
    model, params, FastEngine = built
    rng = np.random.default_rng(seed)
    eng = FastEngine(model, params, batch_size=2)
    for i in range(2):
        eng.submit(E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(seed + i),
                (int(rng.integers(1, 5)),), 0, CFG.vocab,
            ),
            max_new=6,
        ))
    for _ in range(int(rng.integers(1, 4))):
        eng.tick()
    before = jax.tree.map(np.asarray, eng.cache)
    _, replayed = eng._decode(
        eng.params, eng.cache, eng._ctok, eng._cpos
    )
    after = jax.tree.map(np.asarray, replayed)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)


def test_engine_accepts_batched_recurrent_models():
    """Scatter seating overwrites a seated slot's whole cache row, so
    recurrent-cache models (whose hidden state advances every step and
    made pool-replay admission unsound) now decode through the slot
    engine at batch_size > 1 — the PR 3 guard is lifted. Full
    token-for-token equivalence with `generate` is pinned in
    tests/test_admission_properties.py; here: admission, recycling and
    completion all work on a 2-slot recurrent pool."""
    cfg = configs.reduced("recurrentgemma_2b")
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    eng = E.Engine(model, params, batch_size=2)
    reqs = [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(i), (4,), 0, cfg.vocab
            ),
            max_new=3,
        )
        for i in range(3)  # 3 requests over 2 slots forces recycling
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=30)
    for r in reqs:
        assert r.done and len(r.output) == 3
    assert eng.admission_prefills >= 2  # co-admission + recycled seat


def test_replaying_last_fed_state_is_idempotent(built):
    """Re-feeding a slot its last-fed (token, pos) through
    `_step_single` (the retransmission shim) leaves the cache
    bit-identical and does not disturb the slot's pending state."""
    model, params, FastEngine = built
    eng = FastEngine(model, params, batch_size=2)
    prompt = jax.random.randint(jax.random.PRNGKey(7), (5,), 0, CFG.vocab)
    req = E.Request(uid=0, prompt=prompt, max_new=8)
    eng.submit(req)
    eng.tick()  # admit (batched prefill + seat) + first pool tick
    before_cache = jax.tree.map(np.asarray, eng.cache)
    pending = (int(eng.tokens[0]), int(eng.pos[0]))
    # retransmit slot 0's last-fed decode input
    slot_tok = int(eng._ctok[0])
    slot_pos = int(eng._cpos[0])
    eng._step_single(0, slot_tok, slot_pos)
    after_cache = jax.tree.map(np.asarray, eng.cache)
    for a, b in zip(
        jax.tree.leaves(before_cache), jax.tree.leaves(after_cache)
    ):
        np.testing.assert_array_equal(a, b)
    assert (int(eng.tokens[0]), int(eng.pos[0])) == pending
