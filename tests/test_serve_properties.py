"""Property tests for the serving engine's slot admission machinery.

Random interleavings of submit / tick (hypothesis; deterministic stub
in CI) must never exceed slot capacity, never starve an admitted
request, and keep the committed-(token,pos) replay contract: re-feeding
the pool its committed state is a bitwise no-op on the cache. These are
the invariants `serve.sharded.ShardedEngine` inherits wholesale, so
they are pinned here once, on the cheap single-device engine.

The replay no-op holds for attention caches (position-indexed writes
are idempotent); recurrent caches advance state on every step and are
exercised via the generate path instead (`test_decode_multidevice`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import api
from repro.serve import engine as E

CFG = configs.reduced("qwen3_8b")


@pytest.fixture(scope="module")
def built():
    model = api.build_model(CFG, tp=1, max_seq=64)
    params = model.init(jax.random.PRNGKey(0))
    # one shared jitted decode so hypothesis examples don't retrace
    decode = jax.jit(model.decode_step)

    class FastEngine(E.Engine):
        def _compile_decode(self):
            return decode

    return model, params, FastEngine


def _occupied(eng):
    return [i for i, s in enumerate(eng._slots) if s is not None]


def _check_invariants(eng):
    occ = _occupied(eng)
    assert len(occ) <= eng.batch
    active = np.asarray(eng.active)
    # active flags mirror occupancy exactly — a leaked flag would make
    # tick() advance a free slot and corrupt the next tenant's prefill
    assert sorted(np.nonzero(active)[0].tolist()) == occ
    for i in occ:
        req = eng._slots[i]
        assert not req.done
        assert 1 <= len(req.output) < req.max_new


@settings(max_examples=8, deadline=None)
@given(
    batch_size=st.sampled_from([1, 2]),
    n_reqs=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_random_interleavings_keep_slot_invariants(
    built, batch_size, n_reqs, seed
):
    model, params, FastEngine = built
    rng = np.random.default_rng(seed)
    eng = FastEngine(model, params, batch_size=batch_size)
    reqs = [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(seed + i),
                (int(rng.integers(1, 5)),), 0, CFG.vocab,
            ),
            max_new=int(rng.integers(1, 4)),
        )
        for i in range(n_reqs)
    ]
    pending = list(reqs)
    steps = 0
    while (pending or eng._queue or _occupied(eng)) and steps < 200:
        steps += 1
        if pending and (rng.random() < 0.5 or not eng._queue):
            for _ in range(int(rng.integers(1, 3))):
                if pending:
                    eng.submit(pending.pop(0))
        eng.tick()
        _check_invariants(eng)
    # no starvation: every submitted request completed within the
    # interleaving horizon, with a well-formed output
    assert steps < 200
    for r in reqs:
        assert r.done, r.uid
        assert 1 <= len(r.output) <= r.max_new
        if len(r.output) < r.max_new:
            assert r.eos is not None and r.output[-1] == r.eos


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_committed_replay_is_bitwise_noop_on_cache(built, seed):
    """After any admission state, decoding the pool with its committed
    (token, pos) — exactly what co-admission prefill does to seated
    slots — must leave every cache leaf bit-identical."""
    model, params, FastEngine = built
    rng = np.random.default_rng(seed)
    eng = FastEngine(model, params, batch_size=2)
    for i in range(2):
        eng.submit(E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(seed + i),
                (int(rng.integers(1, 5)),), 0, CFG.vocab,
            ),
            max_new=6,
        ))
    for _ in range(int(rng.integers(1, 4))):
        eng.tick()
    before = jax.tree.map(np.asarray, eng.cache)
    _, replayed = eng._decode(
        eng.params, eng.cache, eng._ctok, eng._cpos
    )
    after = jax.tree.map(np.asarray, replayed)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)


def test_engine_rejects_batched_recurrent_models():
    """Recurrent caches advance on every step, so co-admission replay
    would silently corrupt seated slots: the slot engine must refuse
    them at batch_size > 1 (single-slot pools have no co-seated slots
    and stay legal; batched decode goes through `generate`)."""
    cfg = configs.reduced("recurrentgemma_2b")
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent"):
        E.Engine(model, params, batch_size=2)
    eng = E.Engine(model, params, batch_size=1)  # 1-slot pool is fine
    assert eng.batch == 1


def test_replaying_whole_prefill_is_idempotent(built):
    """Replaying an entire committed prompt through `_step_single` (the
    retransmission path: same tokens, same positions) leaves the cache
    bit-identical and does not disturb the slot's pending state."""
    model, params, FastEngine = built
    eng = FastEngine(model, params, batch_size=2)
    prompt = jax.random.randint(jax.random.PRNGKey(7), (5,), 0, CFG.vocab)
    req = E.Request(uid=0, prompt=prompt, max_new=8)
    eng.submit(req)
    eng.tick()  # admit (prefill) + first pool tick
    before_cache = jax.tree.map(np.asarray, eng.cache)
    pending = (int(eng.tokens[0]), int(eng.pos[0]))
    # replay the committed prompt positions for slot 0
    slot_tok = int(eng._ctok[0])
    slot_pos = int(eng._cpos[0])
    eng._step_single(0, slot_tok, slot_pos)
    after_cache = jax.tree.map(np.asarray, eng.cache)
    for a, b in zip(
        jax.tree.leaves(before_cache), jax.tree.leaves(after_cache)
    ):
        np.testing.assert_array_equal(a, b)
    assert (int(eng.tokens[0]), int(eng.pos[0])) == pending
