"""Sharding rules, activation constraints, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import compression as C
from repro.dist import sharding as shd
from repro.models import api


def _mesh22():
    # 1 real device: a (1,1) mesh exercises the rule plumbing
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_param_specs_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=8)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, cfg, mesh)
    blk = specs["blocks"]["pos0"]
    # stacked block params carry a leading (n_groups,) None dim
    assert blk["mix"]["wq"]["w"] == P(None, "data", "model")
    assert blk["mix"]["wo"]["w"] == P(None, "model", "data")
    assert blk["ffn"]["w_down"]["w"] == P(None, "model", "data")
    assert specs["embed"]["w"] == P("model", "data")
    assert specs["lm_head"]["w"] == P("data", "model")
    assert blk["ln1"]["scale"] == P()


def test_divisibility_guard_drops_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # shape 7 not divisible by fake axis size -> but axis size 1 divides
    # everything; test the guard logic directly instead:
    assert shd._dim_ok(8, "model", mesh)
    # construct a pretend mesh dict via spec_for_path on odd dims
    cfg = configs.reduced("qwen3_8b")
    spec = shd.spec_for_path("blocks/pos0/mix/wq/w", (7, 13), cfg, mesh)
    assert spec == P("data", "model")  # axis size 1 divides


def test_pure_dp_profile_replicates_params():
    mesh = _mesh22()
    cfg = configs.reduced("whisper_tiny")  # use_tp=False, fsdp=False
    spec = shd.spec_for_path("dec_blocks/self_attn/wq/w", (48, 48), cfg,
                             mesh)
    assert spec == P(None, None)
    assert shd.data_axes(cfg, mesh) == ("data", "model")


def test_batch_specs_guard():
    mesh = _mesh22()
    cfg = configs.reduced("qwen3_8b")
    tree = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "pos": jax.ShapeDtypeStruct((1,), jnp.int32)}
    specs = shd.batch_specs(tree, cfg, mesh)
    assert specs["tokens"] == P(("data",), None)
    # batch=1 divisible by axis 1 -> still sharded on the (1,1) mesh
    assert specs["pos"] == P(("data",))


def test_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "dp", None)
    assert y is x


def test_constrain_applies_in_context():
    mesh = _mesh22()
    cfg = configs.reduced("qwen3_8b")
    with mesh, shd.activation_context(cfg, mesh):
        out = jax.jit(
            lambda x: shd.constrain(x * 2, "dp", None, "tp")
        )(jnp.ones((2, 4, 8)))
    np.testing.assert_allclose(out, 2.0)


# --- gradient compression ---------------------------------------------------


def test_quantize_dequantize_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = C.quantize_leaf(g)
    err = jnp.abs(C.dequantize_leaf(q, s) - g)
    assert float(err.max()) <= float(s) / 2 + 1e-7


def test_error_feedback_is_lossless_over_time():
    """sum of transmitted dequantized grads + final residual == sum of
    true grads (telescoping error feedback identity)."""
    key = jax.random.PRNGKey(1)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (64,))
             for i in range(20)]
    err = jnp.zeros((64,))
    sent = jnp.zeros((64,))
    for g in grads:
        q, s, err = C.compress_residual(g, err)
        sent = sent + C.dequantize_leaf(q, s)
    total = sum(grads)
    np.testing.assert_allclose(sent + err, total, rtol=1e-4, atol=1e-4)


def test_compressed_sgd_converges():
    """Quadratic descent with int8+error-feedback gradients reaches the
    optimum — compression does not bias convergence."""
    w = jnp.array([3.0, -2.0, 1.5, -0.5] * 16)
    err = jnp.zeros_like(w)
    for _ in range(300):
        g = 2 * w  # grad of ||w||^2
        q, s, err = C.compress_residual(g, err)
        w = w - 0.05 * C.dequantize_leaf(q, s)
    assert float(jnp.abs(w).max()) < 1e-2


def test_compressed_psum_mean_single_device():
    """Under a 1-device shard_map the compressed mean == plain mean."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jnp.arange(8.0)}
    e = {"w": jnp.zeros(8)}
    f = shard_map(
        lambda gg, ee: C.compressed_psum_mean(gg, ee, "pod"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False,
    )
    mean, new_e = f(g, e)
    np.testing.assert_allclose(mean["w"] + new_e["w"], g["w"], rtol=1e-4,
                               atol=1e-4)


def test_two_stage_single_device_telescopes():
    """n=1 degenerates to double quantization of the same leaf; the
    output plus both residuals still reconstructs the input exactly."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (37,))}  # odd size
    e1 = {"w": jnp.zeros(37)}
    e2 = {"w": jnp.zeros(C.two_stage_shard_len(37, 1))}
    f = shard_map(
        lambda a, b, c: C.two_stage_psum_mean(a, b, c, "pod"),
        mesh=mesh, in_specs=(P(), P(), P()), out_specs=(P(), P(), P()),
        check_rep=False,
    )
    mean, n1, n2 = f(g, e1, e2)
    np.testing.assert_allclose(
        np.asarray(mean["w"] + n1["w"] + n2["w"][:37]),
        np.asarray(g["w"]), rtol=1e-4, atol=1e-5,
    )


def test_uncompressed_finite_guard():
    """`compress=False` shares failure semantics with the compressed
    path by default: non-finite entries are zeroed, not propagated;
    `finite_guard=False` is the documented raw-IEEE opt-out."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jnp.array([1.0, jnp.inf, -jnp.inf, jnp.nan, 2.0])}

    def run(**kw):
        return shard_map(
            lambda gg: C.uncompressed_psum_mean(gg, "pod", **kw),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_rep=False,
        )(g)

    guarded = run()
    np.testing.assert_array_equal(
        np.asarray(guarded["w"]), [1.0, 0.0, 0.0, 0.0, 2.0]
    )
    raw = run(finite_guard=False)
    assert not bool(jnp.isfinite(raw["w"]).all())
