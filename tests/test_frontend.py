"""Async serving frontend (`repro.serve.frontend`): wire framing,
deterministic token-bucket admission, the shedding contract as a
property over the in-process transport — every LM request gets exactly
one terminal outcome (completed XOR typed rejection), rejections only
when an admission rate is configured, URGENT segments never shed or
deferred at any load — and a loopback-socket end-to-end run whose
client-minted request ids join lineages across the transport hop.
"""

import asyncio

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs, obs
from repro.models import api
from repro.obs import lineage
from repro.serve import engine as E
from repro.serve.frontend import (
    Frontend,
    FrontendConfig,
    InProcClient,
    SocketClient,
    TokenBucket,
    encode_frame,
    read_frame,
)

PROMPT_LEN = 4
MAX_NEW = 3


@pytest.fixture(scope="module")
def built():
    """Shared model/params: each test gets a fresh engine but the jit
    caches are shared, so per-test warmup is cheap."""
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=PROMPT_LEN + MAX_NEW + 2)
    params = model.init(jax.random.PRNGKey(0))

    def make_engine():
        return E.Engine(model, params, batch_size=2)

    def prompts(n):
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (n, PROMPT_LEN), 0, cfg.vocab
        )
        return [[int(t) for t in toks[i]] for i in range(n)]

    return make_engine, prompts


# -- wire framing -----------------------------------------------------------


def test_frame_roundtrip():
    msg = {"type": "lm", "uid": 3, "prompt": [1, 2],
           "nested": {"a": [1.5, None, "x"]}}

    async def go():
        reader = asyncio.StreamReader()
        reader.feed_data(encode_frame(msg) + encode_frame({"type": "drain"}))
        reader.feed_eof()
        return (await read_frame(reader), await read_frame(reader),
                await read_frame(reader))

    m1, m2, m3 = asyncio.run(go())
    assert m1 == msg
    assert m2 == {"type": "drain"}
    assert m3 is None  # clean EOF at a frame boundary


def test_frame_size_cap():
    with pytest.raises(ValueError, match="exceeds"):
        encode_frame({"x": "a" * 100}, max_frame_bytes=16)

    async def go():
        reader = asyncio.StreamReader()
        reader.feed_data(encode_frame({"x": "a" * 100}))
        return await read_frame(reader, max_frame_bytes=16)

    with pytest.raises(ValueError, match="exceeds"):
        asyncio.run(go())


# -- token bucket -----------------------------------------------------------


def test_token_bucket_burst_exact():
    """Back-to-back offers against a full bucket admit exactly
    floor(burst); refill is rate * elapsed, clamped at burst."""
    t = [0.0]
    b = TokenBucket(2.0, 3.0, clock=lambda: t[0])
    assert [b.try_take() for _ in range(5)] == [True] * 3 + [False] * 2
    t[0] += 1.0  # refills 2 tokens
    assert [b.try_take() for _ in range(3)] == [True, True, False]
    t[0] += 100.0  # clamped at burst depth, not rate * 100
    assert [b.try_take() for _ in range(4)] == [True] * 3 + [False]


def test_token_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(0.0, 4.0)
    with pytest.raises(ValueError):
        TokenBucket(1.0, 0.5)


# -- shedding contract (property, in-process transport) ---------------------


@settings(max_examples=4, deadline=None)
@given(
    n_lm=st.integers(min_value=4, max_value=10),
    burst=st.integers(min_value=1, max_value=4),
    gated=st.booleans(),
)
def test_inproc_shedding_property(built, n_lm, burst, gated):
    """For any offered burst: exactly one terminal outcome per LM
    request; with an admission rate configured (near-zero refill,
    integer burst b) exactly min(n, b) complete and the rest carry the
    typed `admission_rate` rejection; with no rate nothing is ever
    rejected; URGENT segments are enqueued at any load while over-rate
    ROUTINE segments defer (never drop)."""
    make_engine, prompts = built
    fcfg = FrontendConfig(
        admission_rate_rps=(1e-9 if gated else None),
        admission_burst=float(burst),
        stream_rate_rps=(1e-9 if gated else None),
        stream_burst=1.0,
    )

    async def go():
        fe = Frontend(engine=make_engine(), n_patients=4, cfg=fcfg)
        fe.warm(PROMPT_LEN)
        await fe.start(host=None)
        client = InProcClient(fe)
        futs = [
            await client.send_lm(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(prompts(n_lm))
        ]
        ufuts = [
            await client.send_segment(patient=0, seq=s, urgent=True)
            for s in range(3)
        ]
        rfuts = [
            await client.send_segment(patient=p, seq=0)
            for p in (1, 2, 3)
        ]
        res = [await asyncio.wait_for(f, 60.0) for f in futs]
        uacks = [await asyncio.wait_for(f, 60.0) for f in ufuts]
        racks = [await asyncio.wait_for(f, 60.0) for f in rfuts]
        stats = (await client.drain())["stats"]
        await fe.stop()
        return res, uacks, racks, stats

    res, uacks, racks, stats = asyncio.run(go())

    # exactly one terminal outcome: the reply future resolves once,
    # with either tokens (completed) or a typed reason (rejected)
    assert len(res) == n_lm
    completed = [r for r in res if r["status"] == "completed"]
    rejected = [r for r in res if r["status"] == "rejected"]
    assert len(completed) + len(rejected) == n_lm
    for r in completed:
        assert len(r["tokens"]) == MAX_NEW and "reason" not in r
    for r in rejected:
        assert r["reason"] == "admission_rate" and "tokens" not in r
    assert stats.get("lm_completed", 0) == len(completed)
    assert stats.get("lm_rejected", 0) == len(rejected)
    if gated:
        # bucket starts full at depth `burst`, refill ~1e-9/s: a
        # back-to-back burst admits exactly min(n, burst)
        assert len(completed) == min(n_lm, burst)
    else:
        assert not rejected

    # URGENT always lands; ROUTINE past the bucket defers, never drops
    assert all(a["status"] == "enqueued" for a in uacks)
    assert all(a["status"] in ("enqueued", "deferred") for a in racks)
    if gated:
        assert sum(a["status"] == "deferred" for a in racks) == 2
    # drain force-released every deferral into the scheduler and packed
    # the queue dry: nothing lost
    assert stats["deferred_pending"] == 0
    assert stats["sched_enqueued_total"] == stats["sched_packed_total"]
    assert stats["sched_enqueued_total"] == len(uacks) + len(racks)


# -- loopback socket end-to-end ---------------------------------------------


def test_socket_loopback_lineage(built):
    """Client-minted request ids survive the wire: a completed LM
    request and a streamed segment sent over a real loopback socket
    each join a lineage of >= 4 distinct hops including the
    transport's."""
    make_engine, prompts = built
    fe = Frontend(engine=make_engine(), n_patients=2,
                  cfg=FrontendConfig())
    fe.warm(PROMPT_LEN)  # outside the trace: warm uids aren't lineages
    saved = obs.get()
    tel = obs.configure(enabled=True)
    try:
        async def go():
            host, port = await fe.start("127.0.0.1", 0)
            client = await SocketClient.connect(host, port)
            f1 = await client.send_lm(
                uid=0, prompt=prompts(1)[0], max_new=MAX_NEW
            )
            f2 = await client.send_segment(patient=1, seq=0)
            r1 = await asyncio.wait_for(f1, 60.0)
            a1 = await asyncio.wait_for(f2, 60.0)
            await client.drain()
            await client.close()
            await fe.stop()
            return r1, a1

        r1, a1 = asyncio.run(go())
        events = tel.tracer.events()
    finally:
        obs.install(saved)

    assert r1["status"] == "completed" and len(r1["tokens"]) == MAX_NEW
    assert a1["status"] == "enqueued"
    joined = lineage.assert_joined(events, min_hops=4)
    serve_names = {h.name for h in joined["serve:0"]}
    assert {"frontend/ingress", "serve/submit", "serve/finish",
            "frontend/reply"} <= serve_names
    stream_names = {h.name for h in joined["stream:1:0"]}
    assert {"frontend/ingress", "frontend/ack",
            "stream/enqueue"} <= stream_names
    cp = lineage.critical_path(joined["serve:0"])
    assert cp["hop_names"][0] == "frontend/ingress"
    assert cp["hop_names"][-1] == "frontend/reply"
    assert cp["total_s"] > 0
