"""repro.obs: registry/histogram properties, tracer round-trip, jit
recompile guards, and the disabled-no-op / enabled-overhead contracts.

The histogram merge property and the two recompile regression guards
are the ISSUE-mandated satellites: merging per-shard histograms must be
bucket-exact vs the histogram of the concatenated samples, and the
stream classify cells / decode-engine admission cells must show zero
jit cache misses after warmup (the probe's `new_misses` diff).
"""

import gc
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs, obs
from repro.core import compiler, vadetect
from repro.models import api
from repro.obs.registry import PER_DECADE, Histogram
from repro.serve import engine as E
from repro.stream import FleetConfig, FleetRunner, simulate


@pytest.fixture(autouse=True)
def _reset_obs():
    """Every test leaves the process-wide telemetry at the disabled
    default — an enabled registry leaking across tests would skew the
    no-op timing assertions and pin jit caches."""
    yield
    obs.reset()


@pytest.fixture(scope="module")
def program():
    params = vadetect.init(jax.random.PRNGKey(0))
    return compiler.compile_model(params)


# ---------------------------------------------------------------------------
# histogram: merge property + quantile error bound
# ---------------------------------------------------------------------------

# one log-spaced bucket spans a ratio of r; the rank-interpolated
# quantile can land anywhere in the bucket holding the rank, and the
# empirical quantile convention can differ by at most one more bucket
_R = 10.0 ** (1.0 / PER_DECADE)
_QUANTILE_RATIO = _R**2


@settings(max_examples=25, deadline=None)
@given(
    n_shards=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    log_scale=st.floats(min_value=-5.0, max_value=2.0),
    spread=st.floats(min_value=0.1, max_value=2.5),
)
def test_histogram_merge_is_bucket_exact(n_shards, seed, log_scale,
                                         spread):
    """Merging per-shard histograms == histogram of the concatenated
    samples, bit-exact in every bucket; the merged quantile is within
    one bucket ratio of the exact sorted-sample quantile."""
    rng = np.random.RandomState(seed)
    shards = [
        rng.lognormal(mean=log_scale * math.log(10.0), sigma=spread,
                      size=rng.randint(1, 400))
        for _ in range(n_shards)
    ]
    all_samples = np.concatenate(shards)

    per_shard = []
    for s in shards:
        h = Histogram("t", "latency")
        h.observe_array(s)
        per_shard.append(h)
    merged = Histogram.merged(per_shard)

    whole = Histogram("t", "latency")
    whole.observe_array(all_samples)

    # bucket-exact: same counts array, same exact count/sum/min/max
    np.testing.assert_array_equal(merged.counts, whole.counts)
    assert merged.count == whole.count == all_samples.size
    assert merged.sum == pytest.approx(whole.sum)
    assert merged.min == whole.min and merged.max == whole.max

    # quantile error bounded by the (log-spaced) bucket width
    srt = np.sort(all_samples)
    for q in (0.5, 0.9, 0.99):
        exact = float(srt[min(int(math.ceil(q * srt.size)) - 1,
                              srt.size - 1)])
        est = merged.quantile(q)
        assert est == whole.quantile(q)  # merge preserves quantiles
        if exact > 0:
            assert exact / _QUANTILE_RATIO <= est <= \
                exact * _QUANTILE_RATIO, (q, est, exact)


def test_histogram_merge_rejects_layout_mismatch():
    with pytest.raises(ValueError, match="layout mismatch"):
        Histogram("a", "latency").merge(Histogram("b", "signed"))


def test_signed_histogram_exact_zero_split():
    """The signed layout keeps 0 an explicit edge so deadline-slack
    violations (samples <= 0) are counted exactly, not re-bucketed."""
    rng = np.random.RandomState(7)
    xs = np.concatenate([
        rng.uniform(-5e-3, 5e-3, size=500),
        np.zeros(17),  # exactly-on-time segments land at the 0 edge
    ])
    h = Histogram("slack", "signed")
    h.observe_array(xs)
    assert h.count_at_or_below(0.0) == int((xs <= 0).sum())
    assert h.min == xs.min() and h.max == xs.max()


# ---------------------------------------------------------------------------
# tracer: JSONL + Chrome round-trip
# ---------------------------------------------------------------------------


def test_trace_roundtrip_and_virtual_track(tmp_path):
    tel = obs.configure(enabled=True)
    with tel.span("stream/flush", cat="stream", bucket=32,
                  v_ts_s=1.5, v_dur_s=0.25):
        with tel.span("stream/classify", cat="stream"):
            pass
    tel.tracer.instant("fleet/start", cat="stream", patients=4)
    tel.tracer.counter("queue_depth", 3.0, cat="stream")

    jsonl, chrome = tel.finish(str(tmp_path / "t"))
    assert obs.validate_jsonl(jsonl) == 4
    # 4 events + 2 process-name metadata + 1 virtual-time mirror
    assert obs.validate_chrome(chrome) == 7

    doc = json.load(open(chrome))
    virt = [e for e in doc["traceEvents"]
            if e.get("pid") == 1 and e.get("ph") == "X"]
    assert len(virt) == 1
    assert virt[0]["ts"] == pytest.approx(1.5e6)
    assert virt[0]["dur"] == pytest.approx(0.25e6)


def test_span_stack_is_thread_local():
    """Parent/child edges from worker threads: each thread keeps its
    own open-span stack, so a child opened on thread B while thread A
    also has a span open parents to B's outer span — never across
    threads. Lineage joining (repro.obs.lineage) trusts these edges,
    and a process-global stack would interleave them arbitrarily."""
    import threading

    tel = obs.configure(enabled=True)
    barrier = threading.Barrier(2)

    def worker(name: str):
        with tel.span(f"outer/{name}", cat="t"):
            barrier.wait(timeout=10)  # both outers open concurrently
            with tel.span(f"inner/{name}", cat="t"):
                barrier.wait(timeout=10)  # both inners overlap too

    threads = [
        threading.Thread(target=worker, args=(n,)) for n in ("a", "b")
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    ev = {e["name"]: e for e in tel.tracer.events()}
    assert len(ev) == 4
    ids = {e["span_id"] for e in ev.values()}
    assert len(ids) == 4 and 0 not in ids  # process-unique, nonzero
    for n in ("a", "b"):
        outer, inner = ev[f"outer/{n}"], ev[f"inner/{n}"]
        assert outer["parent_id"] == 0  # roots
        assert inner["parent_id"] == outer["span_id"]
        assert inner["tid"] == outer["tid"]


def test_validate_event_rejects_malformed():
    ok = {"type": "span", "name": "x", "cat": "c", "ts_us": 1.0,
          "dur_us": 2.0, "tid": 3, "attrs": {}}
    obs.validate_event(ok)
    for bad in (
        {**ok, "type": "nope"},
        {**ok, "ts_us": -1.0},
        {k: v for k, v in ok.items() if k != "attrs"},
    ):
        with pytest.raises(ValueError):
            obs.validate_event(bad)


def test_telemetry_section_schema():
    tel = obs.configure(enabled=True)
    tel.registry.counter("x.total").inc(3)
    tel.registry.gauge("x.depth").set(2.0)
    tel.registry.histogram("x.lat_s").observe(1e-3)
    tel.probe.track("x.cell", jax.jit(lambda v: v + 1))
    keep = jnp.ones((8,))  # a live array so the memory gauge is > 0

    sec = obs.telemetry_section()
    assert sec["schema_version"] == obs.SCHEMA_VERSION and sec["enabled"]
    assert sec["counters"]["x.total"] == 3
    assert sec["gauges"]["x.depth"]["value"] == 2.0
    h = sec["histograms"]["x.lat_s"]
    assert h["count"] == 1 and h["p50"] is not None
    assert "x.cell" in sec["recompiles"]
    assert sec["peak_device_memory_bytes"] >= keep.nbytes


# ---------------------------------------------------------------------------
# jit recompile regression guards (generalized via obs.jaxprobe)
# ---------------------------------------------------------------------------


def test_recompile_guard_stream_buckets(program):
    """Stream classify over the declared buckets: after one warmup pass
    per bucket, further traffic causes zero jit cache misses."""
    obs.configure(enabled=True)
    buckets = (8, 16)
    runner = FleetRunner(program, path="twin")
    for b in buckets:
        runner.classify(jnp.zeros((b, vadetect.RECORD_LEN)))

    probe = obs.get().probe
    snap = probe.snapshot()
    assert snap.get("stream.classify.twin") == len(buckets)
    for _ in range(3):
        for b in buckets:
            runner.classify(jnp.zeros((b, vadetect.RECORD_LEN)))
    assert probe.new_misses(snap) == {}


def test_recompile_guard_decode_admission_widths():
    """Decode engine over its admission widths: after one warmup round
    covering each (group rows, prompt len) shape, re-serving the same
    shapes causes zero cache misses in the decode step, the prefill
    cell, or the seating cell."""
    obs.configure(enabled=True)
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=48)
    params = model.init(jax.random.PRNGKey(0))
    eng = E.Engine(model, params, batch_size=2)

    def serve_round(uid0):
        # two widths: a 2-row group (len 5) then a 1-row group (len 9)
        for uid, n_tok in ((uid0, 5), (uid0 + 1, 5), (uid0 + 2, 9)):
            eng.submit(E.Request(
                uid=uid,
                prompt=jax.random.randint(
                    jax.random.PRNGKey(uid), (n_tok,), 0, cfg.vocab),
                max_new=3,
            ))
        eng.run(max_ticks=40)

    serve_round(0)  # warmup: compiles decode + admission cells
    probe = obs.get().probe
    snap = probe.snapshot()
    for cell in ("serve.decode_step", "serve.prefill", "serve.seat"):
        assert snap.get(cell), (cell, snap)
    serve_round(10)
    assert probe.new_misses(snap) == {}


# ---------------------------------------------------------------------------
# disabled no-op + enabled overhead contracts
# ---------------------------------------------------------------------------


def test_disabled_telemetry_is_noop():
    """The disabled default costs nanoseconds per emission — hot paths
    emit unconditionally, so this bound is what makes that free."""
    obs.reset()
    tel = obs.get()
    assert not tel.enabled
    # null instruments are shared singletons, nothing accumulates
    assert tel.registry.counter("a") is tel.registry.counter("b")
    assert tel.registry.snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}
    }

    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        tel.registry.counter("stream.enqueued_total").inc()
        tel.registry.histogram("serve.ttft_s").observe(1e-3)
        with tel.span("serve/tick", cat="serve"):
            pass
    per_emission_ns = (time.perf_counter() - t0) / (3 * n) * 1e9
    # ~200-450 ns each measured; 2 us leaves CI-noise headroom while
    # still catching an accidental allocation/lock on the no-op path
    assert per_emission_ns < 2_000, per_emission_ns


def test_enabled_emission_cost_bounded():
    """Enabled-path per-emission budget — the noise-immune half of the
    overhead contract. The wall-clock A/B below can only be asserted
    on a quiet host; this tight CPU-bound micro-loop is stable
    anywhere and catches a catastrophic regression (an O(events) scan,
    a blocking call, a lock convoy) on the enabled hot path."""
    obs.configure(enabled=True)
    try:
        tel = obs.get()
        n = 5_000
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for i in range(n):
                tel.registry.counter("stream.enqueued_total").inc()
                tel.registry.histogram("serve.ttft_s").observe(1e-3)
                tel.tracer.instant(
                    "stream/enqueue", cat="stream",
                    request_id="stream:0:1", v_ts_s=0.5,
                )
                with tel.span("serve/tick", cat="serve",
                              request_ids=["serve:0"]):
                    pass
            best = min(best, (time.perf_counter() - t0) / (4 * n))
        # ~1-3 us each measured; 25 us leaves heavy CI-noise headroom
        # while still catching anything super-linear
        assert best * 1e6 < 25.0, best * 1e6
    finally:
        obs.reset()


def test_enabled_overhead_under_three_percent(program):
    """Enabled telemetry stays under the 3% wall budget on the stream
    fleet loop — measured on a pre-warmed runner with interleaved
    disabled/enabled reps (min-of-N), the same protocol
    `benchmarks/stream_throughput.py` records in its BENCH telemetry
    `overhead` sub-record. The strict assert is gated on the
    measurement's own noise floor: when the disabled-side walls spread
    more than 3% (shared-VM steal time), a 3% A/B difference is below
    the measurement resolution and the assert would be a coin flip —
    skip with the evidence instead (the per-emission budget test above
    still enforces the enabled-path cost unconditionally)."""
    cfg = FleetConfig(
        n_patients=128, segments_per_patient=5, va_fraction=0.05,
        jitter_frac=0.02, buckets=(16, 64), path="twin",
    )
    runner = FleetRunner(program, path="twin")
    simulate(cfg, runner=runner)  # untimed: compile both bucket cells
    walls = {"disabled": [], "enabled": []}
    for rep in range(10):
        # alternate which mode runs first: VM scheduling noise arrives
        # in multi-second bursts, and a fixed order would let a burst
        # systematically land on one mode's phase across several reps
        order = ("disabled", "enabled") if rep % 2 == 0 else (
            "enabled", "disabled")
        for mode in order:
            if mode == "enabled":
                obs.configure(enabled=True)
            else:
                obs.reset()
            gc.disable()
            try:
                t0 = time.perf_counter()
                simulate(cfg, runner=runner)
                walls[mode].append(time.perf_counter() - t0)
            finally:
                gc.enable()
    # min-of-reps on both sides: noise (OS scheduling, GC) only ever
    # adds time, so the mins are the comparable noise floors
    ratio = min(walls["enabled"]) / min(walls["disabled"])
    dis = sorted(walls["disabled"])
    spread = dis[len(dis) // 2] / dis[0] - 1.0
    if spread > 0.03:
        pytest.skip(
            f"host too noisy to resolve a 3% A/B: disabled-side "
            f"median/min spread {spread:.1%} (ratio measured "
            f"{ratio:.3f}, recorded for reference)"
        )
    assert ratio < 1.03, (ratio, walls)
