"""Weighted HLO analyzer vs closed-form costs (loop-aware counting)."""

import jax
import jax.numpy as jnp
import pytest

from repro._compat import cost_analysis_dict
from repro.launch.hlo_count import weighted_cost


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_plain_matmul_flops():
    M, K, N = 128, 256, 512
    c = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32),
    )
    wc = weighted_cost(c.as_text())
    assert wc.flops == 2 * M * K * N
    assert wc.flops == cost_analysis_dict(c)["flops"]  # loop-free: agree


def test_scan_flops_multiplied_by_trip():
    T, B, D = 7, 8, 64

    def g(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, params)
        return c.sum()

    c = _compile(
        g,
        jax.ShapeDtypeStruct((T, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
    )
    wc = weighted_cost(c.as_text())
    assert wc.flops == T * 2 * B * D * D
    assert dict(wc.loops)  # at least one loop with trip T
    assert max(t for _, t in wc.loops) == T


def test_grad_of_scan_triples_flops():
    T, B, D = 5, 4, 32

    def g(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, params)
        return c.sum()

    c = _compile(
        jax.grad(g),
        jax.ShapeDtypeStruct((T, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
    )
    wc = weighted_cost(c.as_text())
    assert wc.flops == pytest.approx(3 * T * 2 * B * D * D, rel=0.05)


def test_nested_scan():
    T, inner, B, D = 6, 3, 4, 16

    def h(params, x):
        def outer(c, w):
            def in_body(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(in_body, c, None, length=inner)
            return ci, None
        c, _ = jax.lax.scan(outer, x, params)
        return c.sum()

    c = _compile(
        h,
        jax.ShapeDtypeStruct((T, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
    )
    wc = weighted_cost(c.as_text())
    assert wc.flops == T * inner * 2 * B * D * D


def test_bytes_scale_with_trip():
    T, B, D = 9, 8, 32

    def g(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, params)
        return c.sum()

    def g1(params, x):  # single iteration for comparison
        return jnp.tanh(x @ params[0]).sum()

    cT = _compile(g, jax.ShapeDtypeStruct((T, D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32))
    c1 = _compile(g1, jax.ShapeDtypeStruct((T, D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32))
    bT = weighted_cost(cT.as_text()).bytes_accessed
    b1 = weighted_cost(c1.as_text()).bytes_accessed
    assert bT > 0.7 * T * b1  # body bytes scale ~linearly with trips
