"""core.spe — the three compute paths of a compiled SPE layer agree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spe


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_paths_agree(bits):
    cfg = spe.SPEConfig(bits=bits, sparse=True, quantized=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 24))
    layer = spe.compile_layer(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 64))
    y_dense = spe.spe_matmul(x, layer, path="dense")
    y_ref = spe.spe_matmul(x, layer, path="reference")
    y_kernel = spe.spe_matmul(x, layer, path="kernel")
    np.testing.assert_allclose(y_ref, y_dense, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_kernel, y_dense, rtol=1e-4, atol=1e-4)


def test_train_weight_matches_compiled():
    """QAT forward (prune-STE + fake-quant) == compiled program numerics."""
    cfg = spe.SPEConfig(bits=8, sparse=True, quantized=True)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    wt = spe.spe_train_weight(w, cfg)
    layer = spe.compile_layer(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 64))
    np.testing.assert_allclose(
        x @ wt, spe.spe_matmul(x, layer, path="dense"), rtol=1e-4, atol=1e-4
    )


def test_hbm_bytes_compression():
    cfg = spe.SPEConfig(bits=8, sparse=True, quantized=True)
    w = jax.random.normal(jax.random.PRNGKey(4), (128, 32))
    layer = spe.compile_layer(w, cfg)
    dense_bytes = 128 * 32 * 4
    # 50% sparsity + int8 + 4-bit selects ~ 5.3x smaller than f32 dense
    assert layer.hbm_bytes() < dense_bytes / 4.5


def test_conv1d_as_matmul_matches_conv():
    from repro.core.spe import conv1d_apply, conv1d_as_matmul, conv1d_init

    for ks, stride in [(3, 1), (5, 2), (7, 2), (1, 1)]:
        p = conv1d_init(jax.random.PRNGKey(5), 8, 12, ks)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 8))
        y1 = conv1d_apply(p, x, None, stride=stride)
        y2 = conv1d_as_matmul(p, x, stride=stride)
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
