"""End-to-end system test: the paper's full pipeline.

synthetic IEGM -> co-design QAT training (50% balanced sparsity + 8-bit)
-> compiler freeze -> chip-format execution (reference AND Pallas kernel
paths) -> 6-segment voting diagnosis -> chip perf model at the paper's
operating point.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import va_cnn
from repro.core import compiler, sparsity, vadetect
from repro.data import iegm
from repro.serve.va_service import VAService
from repro.train import trainer


@pytest.fixture(scope="module")
def trained():
    cfg = va_cnn.CONFIG
    params = vadetect.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(trainer.make_train_step(
        lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
    ), donate_argnums=(0,))
    stream = iegm.IEGMStream(batch=64, seed=0)
    for i in range(150):
        state, m = step(state, stream.batch_at(i))
    return state["params"], cfg


def test_end_to_end_diagnosis(trained):
    params, cfg = trained
    program = compiler.compile_model(params, cfg)
    svc = VAService(program, cfg)
    batch = iegm.synth_diagnosis_batch(jax.random.PRNGKey(99), 32)
    out = svc.diagnose_batch(batch["signal"])
    correct = sum(
        int(d.is_va) == int(batch["label"][i]) for i, d in enumerate(out)
    )
    # post-vote diagnostic accuracy on synthetic data must be near-perfect
    assert correct / len(out) >= 0.95, f"{correct}/{len(out)}"


def test_compiled_balance_invariant(trained):
    """Every sparse layer of the compiled program is exactly balanced —
    the property that makes the chip's synchronous zero-skip legal."""
    params, cfg = trained
    program = compiler.compile_model(params, cfg)
    for i, m in enumerate(program.layer_meta):
        layer = program.layers[m["name"]]
        if not layer.sparse:
            continue
        scfg = sparsity.SparsityConfig(layer.group_size, layer.keep)
        dense = sparsity.decompress(
            layer.values_q.astype(jnp.float32), layer.select, scfg,
            layer.k_dense,
        )
        mask = dense != 0
        counts = mask.reshape(-1, scfg.group_size, mask.shape[-1]).sum(1)
        assert int(counts.max()) <= scfg.keep


def test_kernel_path_agrees_after_training(trained):
    params, cfg = trained
    program = compiler.compile_model(params, cfg)
    x = iegm.synth_batch(jax.random.PRNGKey(5), 8)["signal"]
    y_ref = compiler.execute(program, x, cfg, path="reference")
    y_kernel = compiler.execute(program, x, cfg, path="kernel")
    np.testing.assert_allclose(y_kernel, y_ref, rtol=2e-3, atol=2e-3)


def test_chip_report_matches_paper_point(trained):
    params, cfg = trained
    program = compiler.compile_model(params, cfg)
    s = program.report.summary()
    assert s["latency_us"] == pytest.approx(35.0, rel=0.3)
    assert s["effective_GOPS"] == pytest.approx(150.0, rel=0.3)
    assert s["avg_power_uW"] == pytest.approx(10.60, rel=0.3)


def test_mixed_precision_point_trains(trained):
    """The CMUL's mixed 8/4-bit demo point still reaches high accuracy."""
    cfg = va_cnn.MIXED
    params = vadetect.init(jax.random.PRNGKey(1), cfg)
    opt = optim.adam(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(trainer.make_train_step(
        lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
    ), donate_argnums=(0,))
    stream = iegm.IEGMStream(batch=64, seed=1)
    accs = []
    for i in range(150):
        state, m = step(state, stream.batch_at(i))
        accs.append(float(m["accuracy"]))
    assert np.mean(accs[-10:]) > 0.93
