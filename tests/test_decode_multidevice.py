"""Sharded decode on a real >1-device mesh: token-for-token equivalence
with the single-device engine, engine semantics preserved under
sharding, and per-device cache accounting verified against the real
placement.

These need the 8 forced host devices `scripts/ci.sh` provides
(`--xla_force_host_platform_device_count=8`); on smaller hosts they
skip. They are also `slow`-marked: CI runs them, local loops can
`pytest -m "not slow"`.

Equivalence contract (see `serve.sharded`): on a data-only mesh every
device computes whole pool rows in the same reduction order as one
device, so greedy decode is token-for-token identical. With a model
axis, row-parallel contractions psum partial products — logits agree to
fp tolerance only, which on qwen3-reduced still leaves greedy argmax
identical (pinned here), but is not guaranteed for every family (e.g.
rwkv6's fp surface flips ties even on the data mesh under FSDP
re-gather).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(
        jax.device_count() < 8,
        reason="needs 8 devices (scripts/ci.sh forces 8 host devices)",
    ),
]

# token-for-token archs: attention KV cache + recurrent (rg-lru) cache
EXACT_ARCHS = ("qwen3_8b", "recurrentgemma_2b")

B, S, NEW = 8, 6, 6


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in EXACT_ARCHS:
        cfg = configs.reduced(name)
        model = api.build_model(cfg, tp=1, max_seq=S + NEW + 2)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, S), 0, cfg.vocab
        )
        out[name] = (model, params, prompts)
    return out


@pytest.mark.parametrize("name", EXACT_ARCHS)
def test_sharded_generate_token_identical_on_data_mesh(built, name):
    model, params, prompts = built[name]
    ref = np.asarray(E.generate(model, params, prompts, max_new=NEW))
    mesh = make_smoke_mesh(8, 1)
    got = np.asarray(
        SH.sharded_generate(model, params, prompts, mesh=mesh,
                            max_new=NEW)
    )
    np.testing.assert_array_equal(got, ref)


def test_sharded_generate_token_identical_on_tp_mesh(built):
    """data=4 x model=2: KV heads and projection columns split over the
    model axis; qwen3-reduced's greedy path stays token-identical."""
    model, params, prompts = built["qwen3_8b"]
    ref = np.asarray(E.generate(model, params, prompts, max_new=NEW))
    mesh = make_smoke_mesh(4, 2)
    got = np.asarray(
        SH.sharded_generate(model, params, prompts, mesh=mesh,
                            max_new=NEW)
    )
    np.testing.assert_array_equal(got, ref)


def _requests(cfg, n, max_new=5):
    return [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(100 + i), (4,), 0, cfg.vocab
            ),
            max_new=max_new,
        )
        for i in range(n)
    ]


def test_sharded_engine_matches_single_device_engine(built):
    """Slot admission (batched prefill + scatter seating) and recycling
    produce the same per-request outputs on the 8-device mesh as on one
    device — including the 3-requests-into-2-slots recycling path."""
    model, params, _ = built["qwen3_8b"]
    cfg = model.cfg

    plain = E.Engine(model, params, batch_size=2)
    for r in (reqs_plain := _requests(cfg, 3)):
        plain.submit(r)
    plain.run(max_ticks=50)

    mesh = make_smoke_mesh(8, 1)
    shard = SH.ShardedEngine(model, params, batch_size=8, mesh=mesh)
    # pool width differs (8 slots vs 2) but greedy outputs must not:
    # decode is per-slot and idle slots re-feed their last-fed state
    for r in (reqs_shard := _requests(cfg, 3)):
        shard.submit(r)
    shard.run(max_ticks=50)

    for a, b in zip(reqs_plain, reqs_shard):
        assert a.done and b.done
        assert a.output == b.output, (a.uid, a.output, b.output)
    assert all(s is None for s in shard._slots)
    assert not bool(shard.active.any())


def test_sharded_engine_eos_on_first_token_semantics(built):
    """The EOS-on-first-token admission guard (PR 2) survives sharding:
    a request finishing at admission never occupies a mesh-placed slot,
    and later requests still complete."""
    model, params, _ = built["qwen3_8b"]
    cfg = model.cfg
    mesh = make_smoke_mesh(8, 1)
    prompt = jax.random.randint(jax.random.PRNGKey(9), (4,), 0, cfg.vocab)

    probe = E.Request(uid=0, prompt=prompt, max_new=2)
    eng = SH.ShardedEngine(model, params, batch_size=8, mesh=mesh)
    eng.submit(probe)
    eng.tick()
    first = probe.output[0]

    eng = SH.ShardedEngine(model, params, batch_size=8, mesh=mesh)
    eos_req = E.Request(uid=1, prompt=prompt, max_new=8, eos=first)
    tail = E.Request(uid=2, prompt=prompt, max_new=3)
    eng.submit(eos_req)
    eng.submit(tail)
    eng.run(max_ticks=30)
    assert eos_req.done and eos_req.output == [first]
    assert tail.done and len(tail.output) == 3
    assert all(s is None for s in eng._slots)


def test_cache_bytes_accounting_matches_real_placement(built):
    """`DecodePlan`'s aval-accounted per-device cache bytes equal the
    bytes actually resident on one device after placement, and beat the
    replicated baseline by ~the data-axis factor."""
    model, params, _ = built["qwen3_8b"]
    mesh = make_smoke_mesh(8, 1)
    plan = SH.plan_decode(model, params, mesh, batch_size=8)
    cache = jax.device_put(model.init_cache(8), plan.cache)
    dev0 = jax.devices()[0]
    placed = 0
    for leaf in jax.tree.leaves(cache):
        for shard in leaf.addressable_shards:
            if shard.device == dev0:
                placed += shard.data.size * shard.data.dtype.itemsize
    assert placed == plan.cache_bytes_per_device
    assert plan.cache_bytes_per_device * 8 == plan.cache_bytes_total
    assert plan.cache_replication_factor == pytest.approx(1.0)

    # the TP mesh shards KV heads too; accounting still matches
    mesh2 = make_smoke_mesh(4, 2)
    plan2 = SH.plan_decode(model, params, mesh2, batch_size=8)
    assert plan2.cache_bytes_per_device < plan2.cache_bytes_total
    cache2 = jax.device_put(model.init_cache(8), plan2.cache)
    placed2 = 0
    for leaf in jax.tree.leaves(cache2):
        for shard in leaf.addressable_shards:
            if shard.device == dev0:
                placed2 += shard.data.size * shard.data.dtype.itemsize
    assert placed2 == plan2.cache_bytes_per_device


def test_plan_strict_guard_rejects_indivisible_pool(built):
    model, params, _ = built["qwen3_8b"]
    mesh = make_smoke_mesh(8, 1)
    with pytest.raises(SH.shd.ShardingGuardError):
        SH.plan_decode(model, params, mesh, batch_size=6)
