"""core.sparsity — co-design balanced pruning + select-index format."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sparsity as S


def test_mask_is_balanced():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 24))
    cfg = S.SparsityConfig(16, 8)
    mask = S.balanced_prune_mask(w, cfg)
    assert S.verify_balance(mask, cfg)
    assert float(mask.mean()) == pytest.approx(0.5)


def test_mask_keeps_topk_magnitude():
    cfg = S.SparsityConfig(4, 2)
    w = jnp.array([[0.1], [3.0], [-2.0], [0.5]])
    mask = S.balanced_prune_mask(w, cfg)
    np.testing.assert_array_equal(
        np.asarray(mask[:, 0]), [False, True, True, False]
    )


def test_compress_decompress_roundtrip():
    cfg = S.SparsityConfig(16, 8)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 12))
    wp = S.apply_prune(w, cfg)
    values, select = S.compress(wp, cfg)
    assert values.shape == (32, 12) and select.dtype == jnp.uint8
    back = S.decompress(values, select, cfg, 64)
    np.testing.assert_allclose(back, wp, rtol=1e-6, atol=1e-6)


def test_select_indices_ascending_in_group():
    cfg = S.SparsityConfig(16, 8)
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 6))
    _, select = S.compress(S.apply_prune(w, cfg), cfg)
    sel = np.asarray(select).reshape(2, 8, 6)
    assert (np.diff(sel, axis=1) > 0).all()  # strict ascend inside group


def test_sparse_matmul_ref_equals_dense():
    cfg = S.SparsityConfig(16, 8)
    w = jax.random.normal(jax.random.PRNGKey(3), (48, 10))
    wp = S.apply_prune(w, cfg)
    values, select = S.compress(wp, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 48))
    y = S.sparse_matmul_ref(x, values, select, cfg)
    np.testing.assert_allclose(y, x @ wp, rtol=1e-5, atol=1e-5)


def test_prune_ste_gradient():
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 4))
    g = jax.grad(lambda w: jnp.sum(S.prune_ste(w, 16, 8)))(w)
    np.testing.assert_allclose(g, jnp.ones_like(w))


def test_sparsity_schedule_monotone():
    ks = [int(S.sparsity_schedule(s, start=10, end=110, final_keep=8,
                                  group_size=16)) for s in range(0, 130, 10)]
    assert ks[0] == 16 and ks[-1] == 8
    assert all(a >= b for a, b in zip(ks, ks[1:]))


@settings(max_examples=25, deadline=None)
@given(
    groups=st.integers(1, 6),
    keep=st.integers(1, 16),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_balance_property(groups, keep, n, seed):
    g = 16
    keep = min(keep, g)
    cfg = S.SparsityConfig(g, keep)
    w = jax.random.normal(jax.random.PRNGKey(seed), (groups * g, n))
    mask = S.balanced_prune_mask(w, cfg)
    assert S.verify_balance(mask, cfg)
    values, select = S.compress(S.apply_prune(w, cfg), cfg)
    back = S.decompress(values, select, cfg, groups * g)
    np.testing.assert_allclose(back, S.apply_prune(w, cfg), atol=1e-6)
