"""Beyond-paper optimization knobs (§Perf): exactness/closeness checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api


def _params_and_batch(cfg, S=16, B=2):
    model = api.build_model(cfg, tp=1, max_seq=2 * S + 8)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(
            jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
    }
    return model, params, batch


@pytest.mark.parametrize("chunk", [4, 5, 16])
def test_chunked_ce_exact(chunk):
    cfg = configs.reduced("qwen3_8b")
    m1, params, batch = _params_and_batch(cfg)
    m2 = api.build_model(
        dataclasses.replace(cfg, loss_chunk=chunk), tp=1, max_seq=40
    )
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)


def test_chunked_ce_grads_match():
    cfg = configs.reduced("qwen3_8b")
    m1, params, batch = _params_and_batch(cfg)
    m2 = api.build_model(
        dataclasses.replace(cfg, loss_chunk=8), tp=1, max_seq=40
    )
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    num = sum(float(jnp.abs(a - b).sum())
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    den = sum(float(jnp.abs(a).sum()) for a in jax.tree.leaves(g1))
    assert num / den < 0.01


def test_int8_kv_cache_decode_close():
    cfg = configs.reduced("qwen3_8b")
    m1, params, _ = _params_and_batch(cfg)
    mk = api.build_model(
        dataclasses.replace(cfg, kv_quant_bits=8), tp=1, max_seq=40
    )
    S, B = 12, 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    _, cd = m1.prefill(params, toks)
    _, ck = mk.prefill(params, toks)
    assert ck["blocks"]["pos0"]["attn"]["k"].dtype == jnp.int8
    newt = jax.random.randint(jax.random.PRNGKey(4), (B, 3), 0, cfg.vocab)
    for t in range(3):
        pos = jnp.full((B,), S + t, jnp.int32)
        ld, cd = m1.decode_step(params, cd, newt[:, t], pos)
        lk, ck = mk.decode_step(params, ck, newt[:, t], pos)
        rel = float(jnp.abs(ld - lk).max()) / (
            float(jnp.abs(ld).std()) + 1e-9
        )
        assert rel < 0.3, rel


def test_int8_kv_cache_bytes_halved():
    cfg = configs.reduced("qwen3_8b")
    mk = api.build_model(
        dataclasses.replace(cfg, kv_quant_bits=8), tp=1, max_seq=64
    )
    m1 = api.build_model(cfg, tp=1, max_seq=64)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    c1 = jax.eval_shape(lambda: m1.init_cache(4))
    ck = jax.eval_shape(lambda: mk.init_cache(4))
    r = nbytes(ck) / nbytes(c1)
    # int8 halves the k/v payload; per-slot f32 scales add 4/hd (25% at
    # the reduced hd=16, ~3% at production hd=128)
    hd = cfg.hd
    expected_kv = (1 + 4 / hd) / 2
    assert r < expected_kv + 0.15, (r, expected_kv)


def test_moe_tp_only_sharding_rule():
    from jax.sharding import PartitionSpec as P

    from repro.dist import sharding as shd

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.reduced("olmoe_1b_7b")
    cfg_tp = dataclasses.replace(cfg, moe_shard="tp_only")
    s1 = shd.spec_for_path("blocks/pos0/moe/w_gate", (2, 8, 64, 64),
                           cfg, mesh)
    s2 = shd.spec_for_path("blocks/pos0/moe/w_gate", (2, 8, 64, 64),
                           cfg_tp, mesh)
    assert s1 == P(None, None, "data", "model")
    assert s2 == P(None, None, None, "model")


def test_moe_tp_only_trains_identically():
    """moe_shard is a sharding-only knob: numerics must be unchanged."""
    cfg = configs.reduced("olmoe_1b_7b")
    m1, params, batch = _params_and_batch(cfg)
    m2 = api.build_model(
        dataclasses.replace(cfg, moe_shard="tp_only"), tp=1, max_seq=40
    )
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)
