"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant as Q
from repro.core import sparsity as S
from repro.kernels import ops, ref

G, KEEP = 16, 8
CFG = S.SparsityConfig(G, KEEP)


def _compressed(key, k, n, bits=8):
    w = jax.random.normal(key, (k, n))
    values, select = S.compress(S.apply_prune(w, CFG), CFG)
    q, scale = Q.quantize(values, Q.QuantConfig(bits=bits))
    return q, select, scale.reshape(1, -1)


@pytest.mark.parametrize("m,k,n", [(4, 32, 8), (16, 64, 24), (130, 256, 130),
                                   (1, 16, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nm_spmm_sweep(m, k, n, dtype):
    q, select, scale = _compressed(jax.random.PRNGKey(m * 7 + n), k, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), dtype)
    y = ops.nm_spmm(x, q, select, scale, group_size=G, keep=KEEP)
    y_ref = ref.nm_spmm_ref(x, q, select, scale, group_size=G, keep=KEEP)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)


def test_nm_spmm_batched_input():
    q, select, scale = _compressed(jax.random.PRNGKey(0), 64, 12)
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 64))
    y = ops.nm_spmm(x, q, select, scale, group_size=G, keep=KEEP)
    assert y.shape == (3, 5, 12)


@pytest.mark.parametrize("bits", [8, 4, 2, 1])
@pytest.mark.parametrize("m,k,n", [(8, 64, 16), (33, 128, 40)])
def test_bitserial_and_quant_matmul_sweep(bits, m, k, n):
    w = jax.random.normal(jax.random.PRNGKey(bits), (k, n))
    q, scale = Q.quantize(w, Q.QuantConfig(bits=bits))
    packed = Q.pack_planes(q, bits)
    x = jax.random.normal(jax.random.PRNGKey(9), (m, k))
    y_ref = ref.bitserial_matmul_ref(
        x, packed, scale.reshape(1, -1), bits=bits, k=k
    )
    y_b = ops.bitserial_matmul(x, packed, scale.reshape(1, -1), bits=bits)
    y_q = ops.quant_matmul(x, packed, scale.reshape(1, -1), bits=bits)
    np.testing.assert_allclose(y_b, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_q, y_ref, rtol=1e-4, atol=1e-4)
    # and against plain dequant matmul (independent oracle)
    np.testing.assert_allclose(
        y_q, x @ Q.dequantize(q, scale), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("ks,stride,c,n,t", [
    (7, 2, 4, 16, 512),   # VA layer 0
    (5, 2, 24, 32, 256),  # VA layer 1-ish
    (3, 1, 32, 48, 128),
    (1, 1, 96, 2, 16),    # 1x1 head
])
def test_sparse_conv1d_sweep(ks, stride, c, n, t):
    k_dense = -(-(ks * c) // G) * G
    q, select, scale = _compressed(jax.random.PRNGKey(ks), k_dense, n)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, t, c))
    y = ops.sparse_conv1d(
        x, q, select, scale, ksize=ks, stride=stride, group_size=G,
        keep=KEEP,
    )
    y_ref = ref.sparse_conv1d_ref(
        x, q, select, scale, ksize=ks, stride=stride, group_size=G,
        keep=KEEP,
    )
    assert y.shape == ((2, (t - 1) // stride + 1, n))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    groups=st.integers(1, 4),
    n=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_nm_spmm_property(m, groups, n, seed):
    k = groups * G
    q, select, scale = _compressed(jax.random.PRNGKey(seed), k, n)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k))
    y = ops.nm_spmm(x, q, select, scale, group_size=G, keep=KEEP)
    y_ref = ref.nm_spmm_ref(x, q, select, scale, group_size=G, keep=KEEP)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
