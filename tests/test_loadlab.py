"""repro.obs.lineage + repro.obs.loadlab: arrival-process properties,
knee/SLO/coordinated-omission units, lineage joining across real
engine hops, and the end-to-end virtual-time stream sweep.

The hypothesis property (an ISSUE-mandated satellite) pins the arrival
generator's contract: bitwise deterministic under `fold_in(key, uid)`
— same (key, uid, rate, n, process) always yields byte-identical gap
arrays — and empirically rate-correct (mean interarrival ~ 1/rate)
across seeds and rates for both the Poisson and trace-driven
processes.
"""

import json
import xml.etree.ElementTree as ET

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.obs import lineage, loadlab
from repro.obs.loadlab import SLO, co_guard, locate_knee
from repro.stream.sources import SegmentRef, check_refs

_RATES = (0.5, 2.0, 50.0, 1000.0)


@pytest.fixture(autouse=True)
def _reset_obs():
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# arrival processes: determinism + rate correctness (satellite)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    uid=st.integers(0, 100_000),
    rate=st.sampled_from(_RATES),
    process=st.sampled_from(loadlab.ARRIVAL_PROCESSES),
)
def test_arrivals_bitwise_deterministic(seed, uid, rate, process):
    key = jax.random.PRNGKey(seed)
    a = loadlab.interarrival_gaps(
        key, uid, rate_hz=rate, n=64, process=process
    )
    b = loadlab.interarrival_gaps(
        key, uid, rate_hz=rate, n=64, process=process
    )
    assert a.tobytes() == b.tobytes()  # bitwise, not approx
    assert np.all(a > 0)
    # independent streams per uid: poisson gaps must differ (fold_in
    # decorrelates); the trace process shifts phase, which can collide
    # for two uids, so only the poisson side asserts inequality
    if process == "poisson":
        c = loadlab.interarrival_gaps(
            key, uid + 1, rate_hz=rate, n=64, process=process
        )
        assert a.tobytes() != c.tobytes()


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rate=st.sampled_from(_RATES),
    process=st.sampled_from(loadlab.ARRIVAL_PROCESSES),
)
def test_arrivals_rate_correct(seed, rate, process):
    # poisson: std of the mean gap over n draws is (1/rate)/sqrt(n)
    # (~1.6% at n=4096), so 10% is > 6 sigma; trace: the cyclic
    # template replay deviates from mean 1/rate only by the partial
    # last cycle, bounded well under 10% at this n
    n = 4096
    gaps = loadlab.interarrival_gaps(
        jax.random.PRNGKey(seed), 7, rate_hz=rate, n=n, process=process
    )
    assert gaps.mean() == pytest.approx(1.0 / rate, rel=0.10)
    t = loadlab.arrival_times(
        jax.random.PRNGKey(seed), 7, rate_hz=rate, n=64, process=process
    )
    assert np.all(np.diff(t) > 0) and t[0] > 0


def test_arrivals_reject_bad_args():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        loadlab.interarrival_gaps(key, 0, rate_hz=0.0, n=4)
    with pytest.raises(ValueError):
        loadlab.interarrival_gaps(key, 0, rate_hz=1.0, n=0)
    with pytest.raises(ValueError):
        loadlab.interarrival_gaps(
            key, 0, rate_hz=1.0, n=4, process="uniform"
        )
    with pytest.raises(ValueError):
        loadlab.interarrival_gaps(
            key, 0, rate_hz=1.0, n=4, process="trace",
            template=(1.0, -0.5),
        )


# ---------------------------------------------------------------------------
# knee / SLO / CO-guard units
# ---------------------------------------------------------------------------


def _pts(p99s):
    return [
        {"offered_load": 10.0 * (i + 1), "p99_s": v}
        for i, v in enumerate(p99s)
    ]


def test_locate_knee_detects_growth():
    k = locate_knee(_pts([0.010, 0.011, 0.012, 0.090]))
    assert k["detected"]
    assert k["knee_rate"] == 30.0
    assert k["first_post_knee_rate"] == 40.0
    assert k["post_knee_growth"] == pytest.approx(9.0)
    assert k["n_sub_saturated"] == 3 and k["n_post_knee"] == 1


def test_locate_knee_needs_both_sides():
    assert not locate_knee(_pts([0.010, 0.011, 0.012]))["detected"]
    assert not locate_knee(_pts([0.010]))["detected"]


def test_locate_knee_baseline_is_fastest_point():
    # a host hiccup on the lowest-rate point must not fake a knee:
    # baseline comes from the fastest point, not points[0]
    k = locate_knee(_pts([0.050, 0.010, 0.011, 0.090]))
    assert k["baseline_s"] == pytest.approx(0.010)
    assert k["detected"] and k["n_post_knee"] == 1


def test_slo_burn_accounting():
    slo = SLO(name="x", metric="m", bound=0.1, target=0.99)
    perfect = slo.evaluate(100, 100)
    assert perfect["met"] and perfect["burn_rate"] == 0.0
    at_budget = slo.evaluate(99, 100)
    assert at_budget["met"] and at_budget["burn_rate"] == pytest.approx(1.0)
    over = slo.evaluate(97, 100)
    assert not over["met"] and over["burn_rate"] == pytest.approx(3.0)
    assert slo.evaluate(0, 0)["met"] is None


def test_co_guard_contract():
    ok = co_guard([2.0, 3.0], [1.0, 1.5], saturated=True)
    assert ok["intended_ge_dequeue"]
    assert ok["strictly_greater_at_overload"]
    assert ok["mean_queue_excess_s"] == pytest.approx(1.25)
    # intended below dequeue => the schedule wasn't open-loop
    with pytest.raises(AssertionError):
        co_guard([1.0], [2.0], saturated=False)
    # no queueing excess at overload => closed-loop in disguise
    with pytest.raises(AssertionError):
        co_guard([1.0, 2.0], [1.0, 2.0], saturated=True)
    # unsaturated equality is fine
    assert co_guard([1.0], [1.0], saturated=False)[
        "strictly_greater_at_overload"
    ] is None


# ---------------------------------------------------------------------------
# explicit arrival schedules (stream side)
# ---------------------------------------------------------------------------


def test_poisson_segment_refs_deterministic_and_valid():
    kw = dict(
        n_patients=6, rate_segments_per_s=100.0, horizon_s=0.5,
        deadline_s=0.05, seed=3,
    )
    a = loadlab.poisson_segment_refs(**kw)
    b = loadlab.poisson_segment_refs(**kw)
    assert a == b  # frozen dataclasses compare by value
    assert len(a) > 0
    check_refs(a, 6)  # sorted, unique, in-range, deadline > arrival
    assert all(r.deadline_s == pytest.approx(r.arrival_s + 0.05)
               for r in a)
    assert all(r.arrival_s <= 0.5 for r in a)


def test_check_refs_rejects_malformed():
    good = SegmentRef(patient=0, seq=0, arrival_s=0.1, deadline_s=0.2)
    check_refs([good], 1)
    with pytest.raises(ValueError):  # patient out of range
        check_refs([good], 0)
    with pytest.raises(ValueError):  # duplicate identity
        check_refs([good, good], 1)
    with pytest.raises(ValueError):  # deadline before arrival
        check_refs(
            [SegmentRef(patient=0, seq=0, arrival_s=0.2,
                        deadline_s=0.1)], 1,
        )
    with pytest.raises(ValueError):  # unsorted
        check_refs(
            [
                SegmentRef(patient=0, seq=1, arrival_s=0.5,
                           deadline_s=0.6),
                SegmentRef(patient=0, seq=0, arrival_s=0.1,
                           deadline_s=0.2),
            ],
            1,
        )


# ---------------------------------------------------------------------------
# lineage join + critical path (synthetic events)
# ---------------------------------------------------------------------------


def _ev(name, ts_us, dur_us, span_id, parent_id=0, **attrs):
    return {"name": name, "ts_us": ts_us, "dur_us": dur_us,
            "span_id": span_id, "parent_id": parent_id, "attrs": attrs}


def test_join_and_critical_path():
    events = [
        _ev("serve/submit", 0.0, 0.0, 1, request_id="serve:1"),
        _ev("serve/admit", 10.0, 8.0, 2,
            request_ids=["serve:1", "serve:2"]),
        _ev("serve/prefill", 11.0, 3.0, 3, parent_id=2,
            request_ids=["serve:1", "serve:2"]),
        _ev("serve/seat", 15.0, 2.0, 4, parent_id=2,
            request_ids=["serve:1", "serve:2"]),
        _ev("serve/decode", 20.0, 6.0, 5, request_ids=["serve:1"]),
        _ev("serve/finish", 30.0, 0.0, 6, request_id="serve:1"),
    ]
    joined = lineage.join(events)
    assert set(joined) == {"serve:1", "serve:2"}
    assert [h.name for h in joined["serve:1"]] == [
        "serve/submit", "serve/admit", "serve/prefill", "serve/seat",
        "serve/decode", "serve/finish",
    ]
    cp = lineage.critical_path(joined["serve:1"])
    # queue wait: submit (0) until the first working span (prefill @11)
    assert cp["queue_wait_s"] == pytest.approx(11e-6)
    assert cp["phases_s"] == pytest.approx(
        {"prefill": 3e-6, "seat": 2e-6, "decode": 6e-6}
    )
    assert cp["total_s"] == pytest.approx(30e-6)  # until the finish
    # serve:2 has no finish instant: entry falls back to its first
    # hop (admit @10) and total runs to the last span end — the admit
    # span's own end (10+8), which outlives its seat child (15+2)
    cp2 = lineage.critical_path(joined["serve:2"])
    assert cp2["total_s"] == pytest.approx(8e-6)

    s = lineage.summarize(events)
    assert s["requests"] == 2
    assert s["min_distinct_hops"] == 3 and s["max_distinct_hops"] == 6

    lineage.assert_joined(events, min_hops=3)
    with pytest.raises(AssertionError):
        lineage.assert_joined(events, min_hops=4)  # serve:2 has 3
    with pytest.raises(AssertionError):
        lineage.assert_joined([], min_hops=1)  # dark tagging


def test_critical_path_virtual_track():
    hops = [
        lineage.Hop("stream/enqueue", 0.0, 0.0, 1, 0, v_ts_s=1.0),
        lineage.Hop("stream/classify", 5e-6, 2e-6, 2, 0,
                    v_ts_s=1.25, v_dur_s=0.05),
    ]
    cp = lineage.critical_path(hops)
    assert cp["v_total_s"] == pytest.approx(0.30)


# ---------------------------------------------------------------------------
# end-to-end: stream lineage through real hops + the virtual-time sweep
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def runner():
    from repro.core import compiler, vadetect
    from repro.stream.runner import FleetRunner

    params = vadetect.init(jax.random.PRNGKey(0))
    return FleetRunner(compiler.compile_model(params))


def test_stream_lineage_joins_all_hops(runner):
    from repro.stream.fleet import FleetConfig, simulate

    tel = obs.configure(enabled=True)
    cfg = FleetConfig(n_patients=6, segments_per_patient=2, seed=0,
                      buckets=(8,), va_fraction=0.0)
    simulate(cfg, runner=runner)
    joined = lineage.assert_joined(
        tel.tracer.events(), min_hops=3, expect_prefix="stream:"
    )
    mine = {r: h for r, h in joined.items() if r.startswith("stream:")}
    assert len(mine) == 12  # every segment, no drops
    for hops in mine.values():
        assert {h.name for h in hops} == {
            "stream/enqueue", "stream/pack", "stream/flush",
            "stream/classify", "stream/vote",
        }
        cp = lineage.critical_path(hops)
        assert cp["v_total_s"] >= 0.0
        assert set(cp["phases_s"]) == {"classify", "vote"}


def test_sweep_stream_end_to_end(runner, tmp_path):
    out = loadlab.sweep_stream(
        n_patients=8,
        buckets=(8, 16),
        load_fractions=(0.25, 0.5, 1.0, 2.0, 3.0),
        segments_at_capacity=192,
        seed=0,
        runner=runner,
    )
    assert len(out["points"]) == 5
    for p in out["points"]:
        assert p["dropped"] == 0
        for k in ("p50_s", "p99_s", "p999_s"):
            assert p[k] is not None and p[k] > 0
    # deterministic virtual time: the knee and verdicts are exact,
    # not flaky-wall-clock properties
    assert out["knee"]["detected"], out["knee"]
    g = out["coordinated_omission_guard"]
    assert g["intended_ge_dequeue"] and g["strictly_greater_at_overload"]
    assert out["slo"]["urgent_overload"]["met"]
    assert out["overload"]["verdict"] == "graceful_degradation"

    # identical inputs reproduce bitwise (virtual time, fold_in keys)
    again = loadlab.sweep_stream(
        n_patients=8,
        buckets=(8, 16),
        load_fractions=(0.25, 0.5, 1.0, 2.0, 3.0),
        segments_at_capacity=192,
        seed=0,
        runner=runner,
    )
    assert json.dumps(out, sort_keys=True, default=float) == json.dumps(
        again, sort_keys=True, default=float
    )

    # the report renders this record standalone: well-formed SVG,
    # percentile curves + knee marker + data table
    path = loadlab_report(out, tmp_path)
    doc = open(path).read()
    assert "<svg" in doc and "<table>" in doc
    import re

    for svg in re.findall(r"<svg.*?</svg>", doc, flags=16):
        ET.fromstring(svg)


def loadlab_report(stream_out, tmp_path):
    from repro.obs import report

    rec = {"stream": stream_out, "smoke": True}
    return report.render_report(rec, str(tmp_path / "report.html"))
