"""repro.stream: sources, scheduler (property tests), runner, vote, fleet."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compiler, vadetect
from repro.data import iegm
from repro.stream import (
    FleetConfig,
    FleetRunner,
    MicroBatchScheduler,
    RingBuffer,
    SchedulerConfig,
    SegmentRef,
    simulate,
)
from repro.stream import vote as V
from repro.stream.scheduler import PRIORITY_ROUTINE, PRIORITY_URGENT


@pytest.fixture(scope="module")
def program():
    params = vadetect.init(jax.random.PRNGKey(0))
    return compiler.compile_model(params)


# ---------------------------------------------------------------------------
# sources / data.iegm per-patient streams
# ---------------------------------------------------------------------------


def test_ring_buffer_segments():
    rb = RingBuffer(segments=2, record_len=8)
    assert rb.push(np.arange(5)) == []
    (seg,) = rb.push(np.arange(5, 11))
    np.testing.assert_array_equal(seg, np.arange(8, dtype=np.float32))
    assert rb.fill == 3
    segs = rb.push(np.arange(11, 24))
    assert len(segs) == 2
    np.testing.assert_array_equal(segs[0], np.arange(8, 16))


def test_stream_segments_same_patient_agree():
    """Two iterators for the same (seed, patient) yield identical
    segments — the fold_in determinism contract."""
    it_a = iegm.stream_segments(7, seed=3)
    it_b = iegm.stream_segments(7, seed=3)
    for _ in range(3):
        a, b = next(it_a), next(it_b)
        assert a["seq"] == b["seq"] and a["label"] == b["label"]
        np.testing.assert_array_equal(
            np.asarray(a["signal"]), np.asarray(b["signal"])
        )
    # different patient: different telemetry
    c = next(iegm.stream_segments(8, seed=3))
    assert not np.array_equal(
        np.asarray(c["signal"]),
        np.asarray(next(iegm.stream_segments(7, seed=3))["signal"]),
    )


def test_stream_segments_restart_mid_stream():
    it = iegm.stream_segments(5, seed=1)
    next(it)
    second = next(it)
    restarted = next(iegm.stream_segments(5, seed=1, start=1))
    np.testing.assert_array_equal(
        np.asarray(second["signal"]), np.asarray(restarted["signal"])
    )


def test_segment_batch_composition_invariant():
    """A (patient, seq) row is bit-identical regardless of which batch
    it is generated in — what makes fleet tests reproducible."""
    a = iegm.segment_batch(0, np.array([3, 9, 4]), np.array([2, 0, 7]))
    b = iegm.segment_batch(0, np.array([9]), np.array([0]))
    np.testing.assert_array_equal(
        np.asarray(a["signal"][1]), np.asarray(b["signal"][0])
    )
    assert int(a["label"][1]) == int(b["label"][0])
    # labels are persistent per patient across seqs
    c = iegm.segment_batch(0, np.array([9]), np.array([5]))
    assert int(c["label"][0]) == int(b["label"][0])


# ---------------------------------------------------------------------------
# scheduler properties (hypothesis-style, deterministic stub in CI)
# ---------------------------------------------------------------------------

_BUCKETS = (4, 8, 16)


def _refs(n_patients, n_segments, seed):
    rng = np.random.default_rng(seed)
    refs = []
    for k in range(n_segments):
        p = int(rng.integers(n_patients))
        t = float(rng.uniform(0, 10))
        refs.append(
            SegmentRef(patient=p, seq=k, arrival_s=t, deadline_s=t + 2.048)
        )
    return refs


@settings(max_examples=25, deadline=None)
@given(
    n_patients=st.integers(2, 12),
    n_segments=st.integers(1, 60),
    n_urgent=st.integers(0, 3),
    seed=st.integers(0, 10_000),
)
def test_scheduler_no_drop_and_declared_buckets(
    n_patients, n_segments, n_urgent, seed
):
    """Every enqueued segment is packed exactly once (no drops), and
    every emitted batch hits a declared bucket shape with a correct
    padding mask."""
    cfg = SchedulerConfig(buckets=_BUCKETS)
    sched = MicroBatchScheduler(cfg, n_patients)
    refs = _refs(n_patients, n_segments, seed)
    rng = np.random.default_rng(seed + 1)
    urgent = np.zeros(n_patients, bool)
    urgent[rng.choice(n_patients, size=min(n_urgent, n_patients),
                      replace=False)] = True
    sched.set_urgent(urgent)

    packed = []
    i = 0
    while i < len(refs) or sched.ready():
        # interleave admission and packing in random chunk sizes
        take = int(rng.integers(1, 9))
        for r in refs[i : i + take]:
            sched.enqueue(r)
        i = min(i + take, len(refs))
        if sched.ready() and (rng.random() < 0.6 or i >= len(refs)):
            b = sched.next_batch(now_s=float(rng.uniform(0, 20)))
            assert b.bucket in _BUCKETS
            assert b.patients.shape == (b.bucket,)
            assert b.valid.sum() == b.n_valid
            assert not b.valid[b.n_valid :].any()
            packed.append(b)
    seen = sorted(
        (int(p), int(s))
        for b in packed
        for p, s, v in zip(b.patients, b.seqs, b.valid)
        if v
    )
    expected = sorted((r.patient, r.seq) for r in refs)
    assert seen == expected  # nothing dropped, nothing duplicated
    assert sched.enqueued_total == sched.packed_total == len(refs)


@settings(max_examples=25, deadline=None)
@given(
    n_patients=st.integers(2, 10),
    n_segments=st.integers(2, 40),
    seed=st.integers(0, 10_000),
)
def test_scheduler_deadline_monotone_within_class(
    n_patients, n_segments, seed
):
    """Within one packed batch, deadlines are non-decreasing within each
    priority class, and every urgent row precedes every routine row."""
    cfg = SchedulerConfig(buckets=_BUCKETS)
    sched = MicroBatchScheduler(cfg, n_patients)
    rng = np.random.default_rng(seed)
    urgent = rng.random(n_patients) < 0.3
    sched.set_urgent(urgent)
    for r in _refs(n_patients, n_segments, seed):
        sched.enqueue(r)
    while sched.ready():
        b = sched.next_batch(now_s=0.0)
        prio = b.priorities[b.valid]
        dl = b.deadlines[b.valid]
        assert (np.diff(prio) >= 0).all()  # urgent block first
        for cls in (PRIORITY_URGENT, PRIORITY_ROUTINE):
            d = dl[prio == cls]
            assert (np.diff(d) >= 0).all()
        # class assignment matches the urgency bitmap at pack time
        for p, c in zip(b.patients[b.valid], prio):
            assert c == (
                PRIORITY_URGENT if urgent[p] else PRIORITY_ROUTINE
            )


def test_scheduler_duplicate_ref_object_both_copies_packed():
    """Enqueueing the same SegmentRef *object* twice (a retransmission
    path reusing the ref) counts as two segments: the bucket cap may
    split them across batches but both copies must be packed."""
    sched = MicroBatchScheduler(SchedulerConfig(buckets=(1,)), n_patients=2)
    ref = SegmentRef(patient=0, seq=0, arrival_s=0.0, deadline_s=2.0)
    sched.enqueue(ref)
    sched.enqueue(ref)
    a = sched.next_batch(now_s=0.0)
    b = sched.next_batch(now_s=0.0)
    assert a is not None and b is not None
    assert a.n_valid == b.n_valid == 1
    assert sched.ready() == 0
    assert sched.enqueued_total == sched.packed_total == 2


def test_scheduler_urgent_preempts_routine():
    """An urgent patient's late-arriving segment jumps ahead of earlier
    routine segments when a batch can't take everyone."""
    cfg = SchedulerConfig(buckets=(4,))
    sched = MicroBatchScheduler(cfg, n_patients=8)
    for k in range(6):  # 6 routine segments, arrivals 0..5
        sched.enqueue(
            SegmentRef(patient=k, seq=0, arrival_s=float(k),
                       deadline_s=10.0 + k)
        )
    sched.enqueue(
        SegmentRef(patient=7, seq=0, arrival_s=9.0, deadline_s=99.0)
    )
    sched.mark_urgent([7])
    b = sched.next_batch(now_s=9.0)
    assert b.bucket == 4
    assert b.patients[0] == 7 and b.priorities[0] == PRIORITY_URGENT


def test_scheduler_caps_rows_per_patient_and_vote_stays_exact():
    """A patient 14 segments behind drains at most VOTE_SEGMENTS rows
    per batch (the vote scatter must never wrap its ring within one
    update), nothing is dropped, and the vote layer emits one diagnosis
    per completed 6-segment window — two for 14 segments."""
    cfg = SchedulerConfig(buckets=(16,))
    sched = MicroBatchScheduler(cfg, n_patients=2)
    for k in range(14):
        sched.enqueue(
            SegmentRef(patient=0, seq=k, arrival_s=float(k) * 0.01,
                       deadline_s=2.048 + k * 0.01)
        )
    state = V.init(2)
    emitted = 0
    batches = 0
    while sched.ready():
        b = sched.next_batch(now_s=1.0)
        batches += 1
        assert np.bincount(
            b.patients[b.valid], minlength=2
        ).max() <= V.VOTE_SEGMENTS
        # alternating preds so windows vote on what was written
        preds = (b.seqs % 2).astype(np.int32)
        state, emit, diag, _ = V.update(
            state,
            jnp.asarray(b.patients),
            jnp.asarray(preds),
            jnp.asarray(b.valid),
        )
        emitted += int(np.asarray(emit).sum())
    assert batches == 3  # 6 + 6 + 2
    assert sched.enqueued_total == sched.packed_total == 14
    assert int(state.count[0]) == 14
    assert emitted == 2  # windows at count 6 and 12


def test_scheduler_aligns_batches_to_vote_windows():
    """Regression: a batch must not straddle a patient's 6-segment vote
    boundary — the post-boundary row would overwrite ring slot 0 before
    the end-of-batch vote and flip the emitted diagnosis. Patient at
    count 5 with window preds [1,1,1,0,0]: segment 6 (pred 0) completes
    the window as a 3/6 tie -> VA; segment 7 must wait for the next
    batch."""
    cfg = SchedulerConfig(buckets=(4,))
    sched = MicroBatchScheduler(cfg, n_patients=1)
    state = V.init(1)
    window_preds = [1, 1, 1, 0, 0]
    for k, y in enumerate(window_preds):
        sched.enqueue(
            SegmentRef(patient=0, seq=k, arrival_s=0.0, deadline_s=2.0)
        )
        b = sched.next_batch(now_s=0.0)
        assert b.n_valid == 1
        state, emit, diag, _ = V.update(
            state,
            jnp.asarray(b.patients),
            jnp.full((b.bucket,), y, jnp.int32),
            jnp.asarray(b.valid),
        )
        assert not bool(emit[0])
    # segments 6 and 7 queued together: the batch may only take seg 6
    sched.enqueue(
        SegmentRef(patient=0, seq=5, arrival_s=0.1, deadline_s=2.1)
    )
    sched.enqueue(
        SegmentRef(patient=0, seq=6, arrival_s=0.2, deadline_s=2.2)
    )
    b = sched.next_batch(now_s=0.2)
    assert b.n_valid == 1 and int(b.seqs[0]) == 5
    state, emit, diag, _ = V.update(
        state,
        jnp.asarray(b.patients),
        jnp.zeros((b.bucket,), jnp.int32),
        jnp.asarray(b.valid),
    )
    assert bool(emit[0])
    assert int(diag[0]) == 1  # 3/6 tie breaks toward VA, not overwritten
    # segment 7 drains in the next batch, opening the new window
    b = sched.next_batch(now_s=0.3)
    assert b.n_valid == 1 and int(b.seqs[0]) == 6
    assert sched.enqueued_total == sched.packed_total == 7


# ---------------------------------------------------------------------------
# runner: twin path numerics, sharding, no silent recompiles
# ---------------------------------------------------------------------------


def test_twin_path_matches_reference(program):
    """The decompressed conv twin contracts the same weights the chip
    stores: logits match the program's reference execution."""
    runner_twin = FleetRunner(program, path="twin")
    x = iegm.synth_batch(jax.random.PRNGKey(5), 64)["signal"]
    from repro.stream.runner import _twin_logits, twin_weights

    lt = _twin_logits(twin_weights(program), program.layer_meta, x)
    lr = compiler.execute(program, x, path="reference")
    np.testing.assert_allclose(
        np.asarray(lt), np.asarray(lr), rtol=2e-4, atol=2e-4
    )
    preds = runner_twin.classify(x)
    assert preds.shape == (64,) and preds.dtype == jnp.int32
    agree = float((preds == jnp.argmax(lr, -1)).mean())
    assert agree >= 0.98, agree


def test_runner_no_silent_recompiles(program):
    """Only declared bucket shapes ever reach the jit: cache misses ==
    number of distinct shapes == len(buckets)."""
    runner = FleetRunner(program, path="twin")
    for b in (8, 16):
        for _ in range(3):
            runner.classify(jnp.zeros((b, vadetect.RECORD_LEN)))
    assert runner.jit_cache_misses() == 2


def test_runner_batch_service_accounting(program):
    runner = FleetRunner(program, path="twin")
    lat = runner.chip_latency_s
    assert lat == pytest.approx(35e-6, rel=0.1)  # paper's 35 us point
    assert runner.batch_service_s(64) == pytest.approx(64 * lat)
    assert runner.modeled_segments_per_s() == pytest.approx(1 / lat)


multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (scripts/ci.sh forces 8 host devices)",
)


@multidevice
def test_runner_sharded_matches_unsharded(program):
    from repro.launch.stream import make_data_mesh

    n = min(8, jax.device_count())
    mesh = make_data_mesh(n)
    sharded = FleetRunner(program, path="twin", mesh=mesh)
    plain = FleetRunner(program, path="twin")
    x = iegm.synth_batch(jax.random.PRNGKey(6), 32)["signal"]
    np.testing.assert_array_equal(
        np.asarray(sharded.classify(x)), np.asarray(plain.classify(x))
    )
    assert sharded.n_devices == n
    assert sharded.modeled_segments_per_s() == pytest.approx(
        n * plain.modeled_segments_per_s()
    )
    # modeled linear chip-fleet scaling: the benchmark's scaling claim
    assert sharded.batch_service_s(32) == pytest.approx(
        plain.batch_service_s(32) / n
    )


# ---------------------------------------------------------------------------
# vote: vectorized state machines vs python reference
# ---------------------------------------------------------------------------


def _vote_reference(n_patients, batches):
    """Per-patient python state machines (the thing vote.py vectorizes)."""
    ring = np.zeros((n_patients, V.VOTE_SEGMENTS), np.int64)
    count = np.zeros(n_patients, np.int64)
    last_pos = np.full(n_patients, -(10**9), np.int64)
    emitted = []
    for patients, preds, valid in batches:
        emit_now = set()
        for p, y, ok in zip(patients, preds, valid):
            if not ok:
                continue
            ring[p, count[p] % V.VOTE_SEGMENTS] = y
            count[p] += 1
            if y:
                last_pos[p] = count[p]
            if count[p] % V.VOTE_SEGMENTS == 0:
                emit_now.add(p)
        emitted.append(
            {
                p: int(2 * ring[p].sum() >= V.VOTE_SEGMENTS)
                for p in emit_now
            }
        )
    urgent = (count - last_pos) < V.URGENT_WINDOW
    return count, urgent, emitted


@settings(max_examples=15, deadline=None)
@given(
    n_patients=st.integers(2, 9),
    n_batches=st.integers(1, 6),
    bucket=st.sampled_from([4, 8]),
    seed=st.integers(0, 10_000),
)
def test_vote_matches_python_reference(n_patients, n_batches, bucket, seed):
    """Batches honor vote.update's documented precondition (the
    scheduler's window alignment: a patient's rows in one batch never
    cross a 6-segment boundary); within it, the vectorized machines
    must match the sequential reference exactly."""
    rng = np.random.default_rng(seed)
    count = np.zeros(n_patients, np.int64)
    batches = []
    for _ in range(n_batches):
        patients = rng.integers(0, n_patients, bucket)
        preds = rng.integers(0, 2, bucket)
        n_valid = int(rng.integers(1, bucket + 1))
        valid = np.arange(bucket) < n_valid
        in_batch = np.zeros(n_patients, np.int64)
        for i in range(bucket):
            if not valid[i]:
                continue
            p = patients[i]
            if in_batch[p] >= V.VOTE_SEGMENTS - count[p] % V.VOTE_SEGMENTS:
                valid[i] = False  # would straddle: scheduler defers it
            else:
                in_batch[p] += 1
        count += in_batch
        batches.append((patients, preds, valid))
    state = V.init(n_patients)
    for patients, preds, valid in batches:
        state, emit, diag, urgent = V.update(
            state,
            jnp.asarray(patients, jnp.int32),
            jnp.asarray(preds, jnp.int32),
            jnp.asarray(valid),
        )
    ref_count, ref_urgent, ref_emitted = _vote_reference(
        n_patients, batches
    )
    np.testing.assert_array_equal(np.asarray(state.count), ref_count)
    np.testing.assert_array_equal(np.asarray(urgent), ref_urgent)
    # re-run tracking emissions batch-by-batch
    state = V.init(n_patients)
    for (patients, preds, valid), ref_emit in zip(batches, ref_emitted):
        state, emit, diag, _ = V.update(
            state,
            jnp.asarray(patients, jnp.int32),
            jnp.asarray(preds, jnp.int32),
            jnp.asarray(valid),
        )
        got = {
            int(p): int(np.asarray(diag)[p])
            for p in np.nonzero(np.asarray(emit))[0]
        }
        assert got == ref_emit


def test_vote_duplicate_patient_rows_fill_consecutive_slots():
    state = V.init(2)
    patients = jnp.array([0, 0, 0, 1], jnp.int32)
    preds = jnp.array([1, 0, 1, 1], jnp.int32)
    valid = jnp.array([True, True, True, True])
    state, emit, diag, urgent = V.update(state, patients, preds, valid)
    np.testing.assert_array_equal(
        np.asarray(state.ring[0, :3]), [1, 0, 1]
    )
    assert int(state.count[0]) == 3 and int(state.count[1]) == 1
    assert bool(urgent[0]) and bool(urgent[1])
    assert not bool(emit[0])


# ---------------------------------------------------------------------------
# fleet: end-to-end virtual-time simulation
# ---------------------------------------------------------------------------


def test_fleet_simulation_deterministic_no_drops(program):
    cfg = FleetConfig(
        n_patients=12,
        segments_per_patient=6,
        buckets=(4, 16),
        va_fraction=0.4,
        jitter_frac=0.05,
        seed=11,
    )
    a = simulate(cfg, program)
    b = simulate(cfg, program)
    assert a["metrics"]["dropped_total"] == 0
    assert a["metrics"]["segments_total"] == 12 * 6
    # every patient completes exactly one 6-segment vote
    assert a["metrics"]["diagnoses_total"] == 12
    assert a["accuracy"]["patients_diagnosed"] == 12
    for k in ("segments_total", "batches_total", "diagnoses_total",
              "va_diagnoses_total", "dropped_total"):
        assert a["metrics"][k] == b["metrics"][k], k
    # no silent recompiles across the whole run
    assert a["jit_cache_misses"] == len(cfg.buckets)
    # virtual-time deadline slack is host-independent and recorded
    assert a["metrics"]["deadline_slack_s"]["violations"] == \
        b["metrics"]["deadline_slack_s"]["violations"]


def test_should_flush_fp_boundary_at_large_virtual_times():
    """Regression: the flush predicate must hold at now == oldest +
    max_wait even when fp cancellation rounds the recovered wait below
    max_wait. At large virtual times the rounding error is an ulp of
    the *magnitude* — adversarial bases make it dwarf the old fixed
    1e-9 epsilon, which livelocked the event loop (time could not
    advance past a trigger the predicate refused to fire on)."""
    cfg = SchedulerConfig(buckets=(8,), max_wait_s=0.256)
    for base in (0.0, 1.0, 2.0**30, 2.0**40, 1e15):
        sched = MicroBatchScheduler(cfg, n_patients=1)
        sched.enqueue(
            SegmentRef(patient=0, seq=0, arrival_s=base,
                       deadline_s=base + 2.048)
        )
        trigger = base + cfg.max_wait_s  # what the event loop advances to
        assert sched.should_flush(trigger), (
            base, trigger - base - cfg.max_wait_s
        )
        # and never fires meaningfully early: strictly before the
        # trigger's fp neighborhood the predicate stays False
        if base <= 2.0**30:
            assert not sched.should_flush(base + cfg.max_wait_s * 0.5)


def test_advance_virtual_time_forces_progress():
    from repro.stream import advance_virtual_time

    # normal advance: target wins
    assert advance_virtual_time(1.0, 2.5) == 2.5
    # fp-stalled advance: target rounds to now (service below one ulp)
    big = 2.0**50
    assert big + 1e-6 == big  # the adversarial premise
    assert advance_virtual_time(big, big + 1e-6) > big
    # equal-time trigger cannot stall either
    assert advance_virtual_time(big, big) > big


def test_fleet_simulation_survives_adversarial_virtual_times(program):
    """End-to-end livelock regression: a fleet whose virtual clock sits
    at adversarially large magnitudes (huge segment period pushing
    arrivals to ~1e12 s, where one ulp exceeds the chip service time
    and rivals max_wait rounding) must still terminate, pack every
    segment exactly once, and keep completions finite and ordered."""
    cfg = FleetConfig(
        n_patients=6,
        segments_per_patient=6,
        buckets=(4, 16),
        jitter_frac=0.3,  # adversarial jitter at huge period magnitudes
        seed=3,
        period_s=2.0**40,  # ~1.1e12 s: ulp ~2.4e-4 s >> 35 us service
    )
    out = simulate(cfg, program)
    assert out["metrics"]["segments_total"] == 6 * 6
    assert out["metrics"]["dropped_total"] == 0
    assert out["metrics"]["diagnoses_total"] == 6
    assert np.isfinite(out["metrics"]["virtual_horizon_s"])
    # completions advanced past the last arrival: time really moved
    assert out["metrics"]["virtual_horizon_s"] > 6 * cfg.period_s


def test_fleet_simulation_with_dropout_counts_source_gaps(program):
    cfg = FleetConfig(
        n_patients=10,
        segments_per_patient=6,
        buckets=(4, 16),
        dropout=0.2,
        seed=5,
    )
    out = simulate(cfg, program)
    # source gaps reduce the segment count; the scheduler still drops 0
    assert out["metrics"]["segments_total"] < 60
    assert out["metrics"]["dropped_total"] == 0


def test_mark_urgent_empty_update_is_noop():
    """Regression: `mark_urgent([])` crashed — `np.asarray([])`
    defaults to float64, and float-array indexing raises even with
    zero elements. An empty urgency update (e.g. a flush with no
    newly-urgent patients) must be a no-op, for both an empty list and
    an empty ndarray."""
    sched = MicroBatchScheduler(
        SchedulerConfig(buckets=(4,)), n_patients=4
    )
    before = sched._urgent.copy()
    sched.mark_urgent([])                       # empty list
    sched.mark_urgent(np.array([]))             # empty float64 ndarray
    sched.mark_urgent(np.array([], np.int64))   # empty int ndarray
    np.testing.assert_array_equal(sched._urgent, before)
    sched.mark_urgent([2])
    assert sched._urgent[2] and sched._urgent.sum() == 1
    sched.mark_urgent(np.array([]))  # still a no-op after a real mark
    assert sched._urgent[2] and sched._urgent.sum() == 1


@settings(max_examples=25, deadline=None)
@given(
    n_patients=st.integers(2, 8),
    n_segments=st.integers(1, 50),
    seed=st.integers(0, 10_000),
)
def test_oldest_arrival_cache_matches_naive_min(
    n_patients, n_segments, seed
):
    """The incrementally-cached `oldest_arrival` (seeded at enqueue,
    invalidated by `_pack`, recomputed at most once per pack) must
    equal the naive min over the live queue across randomized
    enqueue/pack interleavings — including repeated polls against an
    unchanged queue, the `should_flush` hot path."""
    sched = MicroBatchScheduler(
        SchedulerConfig(buckets=(1, 4)), n_patients
    )
    rng = np.random.default_rng(seed)
    refs = _refs(n_patients, n_segments, seed)

    def naive():
        return min(
            (r.arrival_s for _, r in sched._queue), default=float("inf")
        )

    i = 0
    while i < len(refs) or sched.ready():
        take = int(rng.integers(1, 6))
        for r in refs[i : i + take]:
            sched.enqueue(r)
            assert sched.oldest_arrival() == naive()
        i = min(i + take, len(refs))
        assert sched.oldest_arrival() == naive()  # cached re-poll
        if sched.ready() and (rng.random() < 0.5 or i >= len(refs)):
            sched.next_batch(now_s=float(rng.uniform(0, 20)))
            assert sched.oldest_arrival() == naive()
    assert sched.oldest_arrival() == float("inf")  # drained queue
