"""MoE: dispatch vs per-token loop, capacity drops, aux loss, shared."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoESpec
from repro.models import moe


def _per_token_reference(params, x, spec):
    logits = x.astype(jnp.float32) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, spec.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    B, S, D = x.shape
    out = np.zeros((B, S, D), np.float32)
    for b in range(B):
        for s in range(S):
            for i in range(spec.top_k):
                e = int(eidx[b, s, i])
                t = x[b, s]
                h = jax.nn.silu(t @ params["w_gate"][e]) * (
                    t @ params["w_up"][e]
                )
                out[b, s] += float(gates[b, s, i]) * np.asarray(
                    h @ params["w_down"][e]
                )
    return out


@pytest.mark.parametrize("topk", [1, 2])
def test_moe_matches_per_token_loop(topk):
    spec = MoESpec(num_experts=4, top_k=topk, d_ff_expert=16)
    D = 24
    params = moe.moe_init(jax.random.PRNGKey(0), D, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, D))
    y, aux = moe.moe_apply(params, x, spec, dtype=jnp.float32,
                           capacity=12 * topk)
    ref = _per_token_reference(params, x, spec)
    np.testing.assert_allclose(y, ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_positions_within_expert():
    eidx = jnp.array([[0, 1, 0, 0, 1, 2]], jnp.int32)
    pos = moe._positions_within_expert(eidx, 3)
    np.testing.assert_array_equal(np.asarray(pos[0]), [0, 0, 1, 2, 1, 0])


def test_capacity_drops_tokens():
    """With capacity 1 per expert, later duplicate-expert tokens drop."""
    spec = MoESpec(num_experts=2, top_k=1, d_ff_expert=8,
                   capacity_factor=0.01)
    D = 8
    params = moe.moe_init(jax.random.PRNGKey(2), D, spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, D))
    y, _ = moe.moe_apply(params, x, spec, dtype=jnp.float32, capacity=1)
    # at most 2 tokens (1 per expert) can be nonzero
    nonzero = int(jnp.sum(jnp.any(jnp.abs(y[0]) > 1e-7, axis=-1)))
    assert nonzero <= 2


def test_shared_expert_added():
    spec = MoESpec(num_experts=2, top_k=1, d_ff_expert=8,
                   shared_expert_ff=8)
    D = 8
    params = moe.moe_init(jax.random.PRNGKey(4), D, spec)
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, D))
    y, _ = moe.moe_apply(params, x, spec, dtype=jnp.float32)
    # zeroing shared-expert weights changes the output
    params2 = dict(params)
    params2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y2, _ = moe.moe_apply(params2, x, spec, dtype=jnp.float32)
    assert float(jnp.abs(y - y2).max()) > 1e-6


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    spec = MoESpec(num_experts=4, top_k=1, d_ff_expert=8)
    D = 8
    params = moe.moe_init(jax.random.PRNGKey(6), D, spec)
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 64, D))
    _, aux = moe.moe_apply(params, x, spec, dtype=jnp.float32)
    # uniform probs: prob_mass=1/E; token frac depends on top_k ties
    assert 0.9 < float(aux) < 1.5


def test_moe_grads_flow():
    spec = MoESpec(num_experts=4, top_k=2, d_ff_expert=8)
    D = 8
    params = moe.moe_init(jax.random.PRNGKey(8), D, spec)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, D))

    def loss(p):
        y, aux = moe.moe_apply(p, x, spec, dtype=jnp.float32)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
