"""Sharding/compression on a real >1-device mesh.

These need at least 2 devices: `scripts/ci.sh` forces 8 host CPU
devices (`--xla_force_host_platform_device_count=8`) so they run in CI;
on a plain single-device host they skip.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist import compression as C
from repro.dist import sharding as shd

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (scripts/ci.sh forces 8 host devices)",
)


def _mesh(shape, names):
    n = math.prod(shape)
    return jax.make_mesh(
        shape, names, devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(names),
    )


@multidevice
def test_param_specs_divisibility_fallback_on_real_axis():
    """Odd dims on a true 2-way model axis: the non-dividing axis is
    dropped, the dividing one kept."""
    mesh = _mesh((1, 2), ("data", "model"))
    cfg = configs.reduced("qwen3_8b")
    assert shd._dim_ok(14, "model", mesh)
    assert not shd._dim_ok(7, "model", mesh)
    # spec_for_path: out_features 7 not divisible by 2 -> model dropped
    assert shd.spec_for_path(
        "blocks/pos0/mix/wq/w", (8, 7), cfg, mesh
    ) == P("data", None)
    assert shd.spec_for_path(
        "blocks/pos0/mix/wq/w", (8, 14), cfg, mesh
    ) == P("data", "model")
    # same guard through the tree-walking entry point
    shapes = {
        "blocks": {"pos0": {"mix": {"wq": {
            "w": jax.ShapeDtypeStruct((2, 8, 7), jnp.float32)
        }}}},
        "embed": {"w": jax.ShapeDtypeStruct((9, 8), jnp.float32)},
    }
    specs = shd.param_specs(shapes, cfg, mesh)
    assert specs["blocks"]["pos0"]["mix"]["wq"]["w"] == P(
        None, "data", None
    )
    # vocab 9 not divisible by model=2 -> embed row axis dropped
    assert specs["embed"]["w"] == P(None, "data")


@multidevice
def test_batch_specs_guard_on_real_axis():
    mesh = _mesh((2, 1), ("data", "model"))
    cfg = configs.reduced("qwen3_8b")
    tree = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "odd": jax.ShapeDtypeStruct((3,), jnp.int32),
    }
    specs = shd.batch_specs(tree, cfg, mesh)
    assert specs["tokens"] == P(("data",), None)
    assert specs["odd"] == P(None)  # 3 not divisible by 2 -> replicated


@multidevice
def test_constrain_shards_across_devices():
    mesh = _mesh((2, 1), ("data", "model"))
    cfg = configs.reduced("qwen3_8b")
    with mesh, shd.activation_context(cfg, mesh):
        out = jax.jit(
            lambda x: shd.constrain(x + 1, "dp", None)
        )(jnp.zeros((4, 8)))
    np.testing.assert_allclose(out, 1.0)
    assert out.sharding.is_equivalent_to(
        NamedSharding(mesh, P(("data",), None)), out.ndim
    )


@multidevice
def test_compressed_psum_mean_matches_uncompressed():
    """int8+error-feedback mean across real devices stays within one
    quantization step of the f32 pmean, and mean + mean-of-residuals
    recovers it exactly (telescoping)."""
    from jax.experimental.shard_map import shard_map

    n = jax.device_count()
    mesh = _mesh((n,), ("pod",))
    k = 256
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (n * k,))}
    e = {"w": jnp.zeros((n * k,))}

    comp = shard_map(
        lambda gg, ee: C.compressed_psum_mean(gg, ee, "pod"),
        mesh=mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P("pod")), check_rep=False,
    )
    unc = shard_map(
        lambda gg: C.uncompressed_psum_mean(gg, "pod"),
        mesh=mesh, in_specs=(P("pod"),), out_specs=P(),
        check_rep=False,
    )
    mean_c, err = comp(g, e)
    mean_u = unc(g)

    amax = float(jnp.abs(g["w"]).max())
    np.testing.assert_allclose(
        np.asarray(mean_c["w"]), np.asarray(mean_u["w"]),
        atol=amax / 127.0,
    )
    residual_mean = np.asarray(err["w"]).reshape(n, k).mean(axis=0)
    np.testing.assert_allclose(
        np.asarray(mean_c["w"]) + residual_mean,
        np.asarray(mean_u["w"]), rtol=1e-5, atol=1e-6,
    )
