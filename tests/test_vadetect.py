"""The paper's VA detector: shapes, voting, QAT training, chip compile."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, vadetect
from repro.data import iegm


def test_forward_shapes():
    params = vadetect.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
    logits = vadetect.apply(params, x)
    assert logits.shape == (4, 2)
    assert bool(jnp.isfinite(logits).all())


def test_param_count_small():
    params = vadetect.init(jax.random.PRNGKey(0))
    n = vadetect.param_count(params)
    assert 10_000 < n < 100_000  # implantable-class model size


def test_vote_majority_and_tiebreak():
    assert int(vadetect.vote(jnp.array([1, 1, 1, 0, 0, 0]))) == 1  # tie->VA
    assert int(vadetect.vote(jnp.array([0, 0, 0, 0, 1, 1]))) == 0
    assert int(vadetect.vote(jnp.array([1, 1, 1, 1, 0, 1]))) == 1


def test_qat_training_learns():
    """A few hundred QAT steps must reach high accuracy on synthetic IEGM
    (sparse 16:8 + 8-bit constraints active the whole time)."""
    from repro import optim
    from repro.train import trainer

    cfg = vadetect.VAConfig()
    params = vadetect.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(trainer.make_train_step(
        lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
    ), donate_argnums=(0,))
    stream = iegm.IEGMStream(batch=64, seed=0)
    accs = []
    for i in range(120):
        state, m = step(state, stream.batch_at(i))
        accs.append(float(m["accuracy"]))
    assert np.mean(accs[-10:]) > 0.95, np.mean(accs[-10:])


def test_compile_and_execute_matches_eval():
    cfg = vadetect.VAConfig()
    params = vadetect.init(jax.random.PRNGKey(2), cfg)
    program = compiler.compile_model(params, cfg)
    x = iegm.synth_batch(jax.random.PRNGKey(3), 8)["signal"]
    y_train_path = vadetect.apply(params, x, cfg, train=False)
    y_chip = compiler.execute(program, x, cfg, path="reference")
    np.testing.assert_allclose(y_chip, y_train_path, rtol=2e-2, atol=2e-2)
    # predictions identical
    np.testing.assert_array_equal(
        np.argmax(np.asarray(y_chip), -1),
        np.argmax(np.asarray(y_train_path), -1),
    )


def test_compile_execute_kernel_path():
    cfg = vadetect.VAConfig()
    params = vadetect.init(jax.random.PRNGKey(4), cfg)
    program = compiler.compile_model(params, cfg)
    x = iegm.synth_batch(jax.random.PRNGKey(5), 4)["signal"]
    y_ref = compiler.execute(program, x, cfg, path="reference")
    y_k = compiler.execute(program, x, cfg, path="kernel")
    np.testing.assert_allclose(y_k, y_ref, rtol=1e-3, atol=1e-3)


def test_compression_ratio():
    cfg = vadetect.VAConfig()
    params = vadetect.init(jax.random.PRNGKey(6), cfg)
    program = compiler.compile_model(params, cfg)
    # 50% sparsity + 8-bit + 4-bit selects vs dense f32: > 4x
    assert program.compression_ratio() > 4.0


def test_diagnose_shapes():
    params = vadetect.init(jax.random.PRNGKey(7))
    recs = iegm.synth_diagnosis_batch(jax.random.PRNGKey(8), 3)
    out = vadetect.diagnose(params, recs["signal"])
    assert out.shape == (3,)
