"""Deterministic stand-in for `hypothesis` (not installed in the CI
container). Registered as `sys.modules["hypothesis"]` by conftest.py
only when the real package is missing.

Covers the subset the suite uses — `@settings(max_examples=...,
deadline=...)` over `@given(**strategies)` with `st.integers` /
`st.sampled_from` — by running the test body over a seeded pseudo-random
sample of the strategy space. No shrinking, no database; failures
reproduce exactly because the draw sequence is fixed.
"""

from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    del deadline

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # NOTE: no functools.wraps — copying __wrapped__ would make
        # pytest read fn's full signature and demand fixtures named
        # after the strategy kwargs. Instead the wrapper advertises
        # only fn's NON-strategy parameters (via __signature__), so
        # pytest still injects real fixtures (matching hypothesis'
        # fixtures-plus-strategies behavior) while the strategy kwargs
        # come from the drawn examples.
        import inspect

        fixture_params = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strats
        ]

        def wrapper(**fixtures):
            rng = random.Random(_SEED)
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            ran = 0
            for _ in range(n * 4):
                if ran >= n:
                    break
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(**fixtures, **drawn)
                except _Assumption:
                    continue  # assume() rejected the example: resample
                ran += 1

        wrapper.__signature__ = inspect.Signature(fixture_params)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


class _Assumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Assumption("assumption not satisfied")
    return True
