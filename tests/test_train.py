"""Trainer, checkpointing (atomic/elastic), fault tolerance, accumulation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data import lm
from repro.models import api
from repro.train import checkpoint as ckpt
from repro.train import fault, trainer


def _tiny_setup(seed=0):
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(seed))
    opt = optim.adamw(3e-3)
    state = trainer.init_state(params, opt)
    step = jax.jit(trainer.make_train_step(model.loss, opt, clip_norm=1.0))
    stream = lm.TokenStream(batch=8, seq_len=16, vocab=cfg.vocab, seed=seed)
    return cfg, model, opt, state, step, stream


def test_loss_decreases():
    _, _, _, state, step, stream = _tiny_setup()
    losses = []
    for i in range(60):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3


def test_accumulation_matches_full_batch():
    """n_micro=4 must produce the same *gradients* as the full batch
    (compared pre-optimizer: Adam's first-step normalization amplifies
    bf16 reduction-order noise on near-zero grads into +/-lr flips)."""
    cfg, model, opt, state, _, stream = _tiny_setup()
    batch = stream.batch_at(0)
    from repro.dist.accumulate import accumulate_grads

    def gf(p, mb):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, mb)
        return g, m

    g1, _ = jax.jit(lambda p, b: accumulate_grads(gf, p, b, 1))(
        state["params"], batch
    )
    g4, _ = jax.jit(lambda p, b: accumulate_grads(gf, p, b, 4))(
        state["params"], batch
    )
    num = sum(float(jnp.sum(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)))
    den = sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(g1))
    assert num / den < 0.02, num / den


def test_checkpoint_roundtrip(tmp_path):
    _, _, _, state, step, stream = _tiny_setup()
    state, _ = step(state, stream.batch_at(0))
    path = ckpt.save(state, str(tmp_path), 1)
    assert os.path.isdir(path)
    restored, s = ckpt.restore(str(tmp_path), state)
    assert s == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k(tmp_path):
    _, _, _, state, _, _ = _tiny_setup()
    for s in range(5):
        ckpt.save(state, str(tmp_path), s, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_interrupted_save_is_invisible(tmp_path):
    """A crash mid-save (simulated tmp dir) never corrupts LATEST."""
    _, _, _, state, _, _ = _tiny_setup()
    ckpt.save(state, str(tmp_path), 1)
    # simulate a torn save: orphan .tmp directory
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, s = ckpt.restore(str(tmp_path), state)
    assert s == 1


def test_gc_out_of_order_save_never_dangles_latest(tmp_path):
    """Fault recovery re-saves LOWER step numbers into a dir holding
    higher ones (rollback + replay). Keep-k GC must never prune the
    just-saved step — the old oldest-step-number policy deleted it and
    left LATEST dangling, so the fallback resumed from a FUTURE
    checkpoint the rolled-back training state never reached. Steps
    beyond the rollback point are the abandoned lineage (deterministic
    replay regenerates them) and are pruned outright, so the fallback
    cannot jump forward even if LATEST is later lost."""
    state = {"w": np.arange(4.0)}
    for s in (10, 20, 30, 40):
        ckpt.save(state, str(tmp_path), s, keep=3)
    # rollback: training restarted from an earlier checkpoint and
    # reached its next ckpt_every boundary below the stale maximum
    rolled = {"w": np.arange(4.0) * 2}
    ckpt.save(rolled, str(tmp_path), 15, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 15
    restored, s = ckpt.restore(str(tmp_path), state)
    assert s == 15
    np.testing.assert_array_equal(restored["w"], rolled["w"])
    # every dead future dir is gone (10 already fell to plain keep-3)
    assert ckpt.all_steps(str(tmp_path)) == [15]
    # even with LATEST lost, the fallback can only see the live lineage
    os.remove(os.path.join(str(tmp_path), "LATEST"))
    assert ckpt.latest_step(str(tmp_path)) == 15
    # ...and resuming again keeps honoring the rollback point
    ckpt.save(rolled, str(tmp_path), 16, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 16
    assert ckpt.all_steps(str(tmp_path)) == [15, 16]


def test_gc_interrupted_prune_leaves_no_unloadable_step(tmp_path):
    """GC deletes meta.json before the dir: a prune interrupted
    mid-rmtree (or a deletion swallowed by ignore_errors) leaves a dir
    `all_steps` cannot see, so the LATEST-lost fallback can never
    select a checkpoint whose arrays are half-deleted."""
    state = {"w": np.arange(4.0)}
    for s in (1, 2, 3):
        ckpt.save(state, str(tmp_path), s, keep=10)
    # simulate the partial prune: meta gone, arrays still on disk
    os.remove(os.path.join(str(tmp_path), "step_00000002", "meta.json"))
    assert ckpt.all_steps(str(tmp_path)) == [1, 3]
    # LATEST lost -> fallback must pick a complete checkpoint
    os.remove(os.path.join(str(tmp_path), "LATEST"))
    assert ckpt.latest_step(str(tmp_path)) == 3
    _, s = ckpt.restore(str(tmp_path), state)
    assert s == 3


def test_elastic_restore_with_shardings(tmp_path):
    """Restore device_puts under explicitly provided shardings (the mesh
    may differ from the saving job's)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    _, _, _, state, _, _ = _tiny_setup()
    ckpt.save(state, str(tmp_path), 3)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, s = ckpt.restore(str(tmp_path), state, shardings=sh)
    assert s == 3
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == NamedSharding(mesh, P())


def test_run_training_with_fault_injection(tmp_path):
    """Injected failures trigger checkpoint-restart; the run completes and
    the replayed steps are deterministic."""
    _, _, _, state, step, stream = _tiny_setup()
    injector = fault.FaultInjector(fail_at={7, 13})
    final, history = fault.run_training(
        step, state, stream.batch_at,
        num_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5,
        fault_hook=injector, log_every=0,
    )
    assert injector.failures == 2
    assert int(final["step"]) == 20
    assert [h["step"] for h in history][-1] == 19


def test_run_training_resumes_from_checkpoint(tmp_path):
    _, _, _, state, step, stream = _tiny_setup()
    fault.run_training(step, state, stream.batch_at, num_steps=10,
                       ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0)
    # second call resumes at 10, runs to 15
    final, history = fault.run_training(
        step, state, stream.batch_at, num_steps=15,
        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0,
    )
    assert history[0]["step"] == 10
    assert int(final["step"]) == 15


def test_straggler_watchdog_flags():
    w = fault.StragglerWatchdog(threshold=2.0)
    for i in range(10):
        w.record(i, 0.1)
    assert w.record(10, 0.5) is True
    assert len(w.flagged) == 1


def test_schedules():
    s = optim.linear_warmup_cosine(1.0, 10, 110)
    assert float(s(0)) < 0.2
    assert float(s(9)) == pytest.approx(1.0, abs=0.01)
    assert float(s(109)) < float(s(50))
