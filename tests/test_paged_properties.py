"""Paged KV-cache property harness: paged-vs-dense equivalence,
allocator fuzz, and chunked-prefill starvation regressions.

The paged pool replaces dense per-tenant `max_len` cache rows with
fixed-size pages behind a host-side slot->page indirection table
(`serve.paging` + the paged branches of `models.transformer` /
`serve.seating` / `serve.engine`). None of that machinery is allowed to
change a single emitted token: under hypothesis-driven random
admit/tick/finish interleavings, every request's stream from a paged
engine must be token-for-token identical to the dense-pool engine AND
to the solo prefill+decode reference — for attention and recurrent
architectures, prompts shorter than one page and prompts crossing page
boundaries, on one device (fast lane) and on the 8-device data mesh
(slow-marked, scripts/ci.sh).

The allocator is fuzzed directly: random reserve/alloc/free(shed)
sequences must never double-allocate or leak a page (`check_invariants`
audits the full partition after every op), must raise *typed*
exhaustion errors, and must lay out pages deterministically (identical
op sequences -> identical physical layouts — what makes paged runs
reproducible).

Chunked prefill (`chunk_tokens`) is pinned by a starvation regression:
a max-length prompt co-submitted with shorts must not delay the shorts'
first tokens at all — they admit on the first tick while the long
prompt's prefill proceeds in chunks — and the chunked path must be
bitwise identical between the dense and paged pools.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import api
from repro.serve import engine as E
from repro.serve import seating
from repro.serve.paging import (
    PageAllocator,
    PagesExhaustedError,
    PagingConfig,
    pages_for_position,
    validate_page_size,
)

ARCHS = ("qwen3_8b", "recurrentgemma_2b", "rwkv6_3b")

MAX_SEQ = 24
PAGE = 4  # divides qwen3's max_seq cap AND recurrentgemma's window (8)
N_PAGES = 16
# sub-page prompts (2, 3), one exact page (4), page-crossing (5, 9)
PROMPT_LENS = (2, 3, 5, 9)
PAGING = PagingConfig(page_size=PAGE, n_pages=N_PAGES)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = configs.reduced(name)
        model = api.build_model(cfg, tp=1, max_seq=MAX_SEQ)
        params = model.init(jax.random.PRNGKey(0))
        span = validate_page_size(PAGE, model.attn_capacities())
        # shared jitted cells so hypothesis examples don't retrace
        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step)
        seat_dense = jax.jit(seating.scatter_slots, donate_argnums=0)
        chunk = jax.jit(E._chunk_prefill_fn(model))
        if span:
            decode_paged = jax.jit(
                lambda p, c, t, pos, tbl, _m=model:
                _m.decode_step_paged(p, c, t, pos, tbl, PAGE)
            )
            seat_paged = jax.jit(
                functools.partial(
                    seating.scatter_pages,
                    layouts=model.page_layouts(PAGE),
                ),
                donate_argnums=0,
            )
        else:  # pure recurrent: paging degenerates to the dense pool
            decode_paged, seat_paged = None, None

        class FastEngine(E.Engine):
            def _compile_decode(self, _dense=decode, _paged=decode_paged):
                if self._pg is None:
                    return _dense

                def step(params, cache, tok, pos):
                    return _paged(
                        params, cache, tok, pos, self._tbl_device()
                    )

                return step

            def _admission_cell(
                self, rows, _p=prefill, _sd=seat_dense, _sp=seat_paged
            ):
                seat = _sp if self._pg is not None else _sd
                return _p, seat, lambda p: p

            def _chunk_cell(self, c, rows, _chunk=chunk, _m=model):
                return (
                    _chunk,
                    lambda: _m.init_cache(rows),
                    lambda x: jnp.asarray(x, jnp.int32),
                )

        out[name] = (model, params, FastEngine, prefill, decode)
    return out


def _ref_stream(prefill, decode, params, req: E.Request) -> list:
    """Solo greedy prefill+decode reference (the `generate` recipe),
    truncated the way the engine truncates."""
    prompt = jnp.asarray(req.prompt, jnp.int32)[None]
    s = prompt.shape[1]
    logits, cache = prefill(params, prompt)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = []
    for t in range(req.max_new):
        out.append(int(tok[0]))
        if req.eos is not None and out[-1] == req.eos:
            break
        if len(out) >= req.max_new:
            break
        pos = jnp.full((1,), s + t, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return out


def _make_requests(cfg, rng, n, *, eos_pool=None):
    reqs = []
    for i in range(n):
        s_len = int(rng.choice(PROMPT_LENS))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1000 + i), (s_len,), 0, cfg.vocab
        )
        eos = None
        if eos_pool is not None and rng.random() < 0.4:
            eos = int(rng.choice(eos_pool))
        reqs.append(
            E.Request(
                uid=i, prompt=prompt,
                max_new=int(rng.integers(1, 5)), eos=eos,
            )
        )
    return reqs


def _drive_random_interleaving(eng, reqs, rng, max_steps=200):
    pending = list(reqs)
    steps = 0
    while (pending or eng._queue or eng._chunks or eng._chunk_wait
           or any(s is not None for s in eng._slots)) and steps < max_steps:
        steps += 1
        if pending and (rng.random() < 0.6 or not eng._queue):
            for _ in range(int(rng.integers(1, 3))):
                if pending:
                    eng.submit(pending.pop(0))
        eng.tick()
    assert steps < max_steps, "interleaving did not drain"


# ---------------------------------------------------------------------------
# Paged vs dense vs reference equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ARCHS)
@settings(max_examples=4, deadline=None)
@given(
    batch_size=st.sampled_from([2, 3]),
    n_reqs=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_paged_engine_matches_dense_and_generate(
    built, name, batch_size, n_reqs, seed
):
    """Token-for-token: paged engine == dense engine == solo reference,
    for every request, under the same random admit/tick interleaving —
    with prompts both shorter than a page and crossing page
    boundaries, EOS cuts, and slot/page recycling."""
    model, params, FastEngine, prefill, decode = built[name]
    probe = _ref_stream(
        prefill, decode, params,
        E.Request(uid=0, prompt=jax.random.randint(
            jax.random.PRNGKey(1000), (PROMPT_LENS[0],), 0,
            model.cfg.vocab
        ), max_new=4),
    )
    streams = {}
    for label, kw in (
        ("dense", {}), ("paged", {"paging": PAGING}),
    ):
        rng = np.random.default_rng(seed)
        reqs = _make_requests(model.cfg, rng, n_reqs, eos_pool=probe)
        eng = FastEngine(model, params, batch_size=batch_size, **kw)
        _drive_random_interleaving(eng, reqs, rng)
        if eng._pg is not None:
            eng._pg.check_invariants()
            assert eng._pg.allocated_pages() == 0, "pages leaked"
        streams[label] = {r.uid: r.output for r in reqs}
        for r in reqs:
            assert r.done, (label, r.uid)
    assert streams["paged"] == streams["dense"]
    rng = np.random.default_rng(seed)
    for r in _make_requests(model.cfg, rng, n_reqs, eos_pool=probe):
        ref = _ref_stream(prefill, decode, params, r)
        assert streams["paged"][r.uid] == ref, (name, r.uid)


@pytest.mark.parametrize("name", ("qwen3_8b", "recurrentgemma_2b"))
def test_page_boundary_prompt_lengths(built, name):
    """Deterministic pin of the layout edge cases: prompts of one
    sub-page, exactly one page, and page-crossing lengths all decode
    to the reference stream through the paged pool."""
    model, params, FastEngine, prefill, decode = built[name]
    eng = FastEngine(model, params, batch_size=2, paging=PAGING)
    reqs = [
        E.Request(uid=i, prompt=jax.random.randint(
            jax.random.PRNGKey(40 + i), (s_len,), 0, model.cfg.vocab
        ), max_new=5)
        for i, s_len in enumerate((2, PAGE, PAGE + 1, 2 * PAGE + 1))
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=60)
    for r in reqs:
        assert r.done
        ref = _ref_stream(prefill, decode, params, r)
        assert r.output == ref, (name, r.uid, r.output, ref)


def test_paged_cache_bytes_reclaimed(built):
    """`cache_bytes_in_use` accounting: zero at rest, grows while
    tenants hold pages, and returns exactly to the initial value once
    the pool drains (no leaked pages, no phantom residency)."""
    model, params, FastEngine, _, _ = built["qwen3_8b"]
    eng = FastEngine(model, params, batch_size=2, paging=PAGING)
    initial = eng.cache_bytes_in_use()
    assert initial == 0
    reqs = [
        E.Request(uid=i, prompt=jax.random.randint(
            jax.random.PRNGKey(60 + i), (5,), 0, model.cfg.vocab
        ), max_new=4)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    peak = 0
    for _ in range(40):
        n = eng.tick()
        peak = max(peak, eng.cache_bytes_in_use())
        if n == 0 and not eng._queue:
            break
    assert all(r.done for r in reqs)
    assert peak > initial
    assert eng.cache_bytes_in_use() == initial


def test_pure_recurrent_paging_degenerates_to_dense(built):
    """rwkv6 has nothing to page (span == 0): a paged engine builds the
    ordinary dense cache, runs no allocator, and still streams the
    reference tokens."""
    model, params, FastEngine, prefill, decode = built["rwkv6_3b"]
    eng = FastEngine(model, params, batch_size=2, paging=PAGING)
    assert eng._pg is None
    assert jax.tree.structure(
        eng.cache
    ) == jax.tree.structure(model.init_cache(2))
    req = E.Request(uid=0, prompt=jax.random.randint(
        jax.random.PRNGKey(70), (5,), 0, model.cfg.vocab
    ), max_new=4)
    eng.submit(req)
    eng.run(max_ticks=20)
    assert req.output == _ref_stream(prefill, decode, params, req)


# ---------------------------------------------------------------------------
# Allocator fuzz + invariants
# ---------------------------------------------------------------------------


def _random_alloc_ops(rng, n_ops):
    """A random op tape: (op, owner) pairs with owner ids drawn small
    so reserve/alloc/free collide and interleave."""
    ops = []
    for _ in range(n_ops):
        ops.append((
            rng.choice(["reserve", "alloc", "free", "shed"]),
            int(rng.integers(0, 6)),
            int(rng.integers(1, 5)),  # reserve size
        ))
    return ops


def _replay(alloc, ops):
    """Run an op tape, auditing invariants after every op; returns the
    layout trace (what each alloc handed out) for determinism checks."""
    trace = []
    for op, owner, n in ops:
        shard = owner % alloc.n_shards
        try:
            if op == "reserve":
                alloc.reserve(owner, n, shard)
                trace.append(("reserve", owner, n))
            elif op == "alloc":
                trace.append(("alloc", owner, alloc.alloc(owner)))
            else:  # free / shed are both a full release
                trace.append(("free", owner, alloc.free(owner)))
        except PagesExhaustedError:
            trace.append(("exhausted", owner, None))
        except ValueError:
            trace.append(("invalid", owner, None))
        alloc.check_invariants()
    return trace


@settings(max_examples=15, deadline=None)
@given(
    n_pages=st.sampled_from([8, 12, 16]),
    n_shards=st.sampled_from([1, 2]),
    seed=st.integers(0, 10_000),
)
def test_allocator_fuzz_invariants_and_determinism(
    n_pages, n_shards, seed
):
    """Random reserve/alloc/free/shed tapes: the page partition stays
    exact after every op (no double-alloc, no leak, reservations
    consistent), errors are typed, and an identical tape on a fresh
    allocator replays the identical physical layout."""
    if n_pages % n_shards:
        n_pages += n_shards - (n_pages % n_shards)
    rng = np.random.default_rng(seed)
    ops = _random_alloc_ops(rng, 60)
    a = _replay(PageAllocator(n_pages, n_shards), ops)
    b = _replay(PageAllocator(n_pages, n_shards), ops)
    assert a == b, "allocator layout is not deterministic"
    # full release drains everything
    alloc = PageAllocator(n_pages, n_shards)
    _replay(alloc, ops)
    for owner in range(6):
        alloc.free(owner)
    alloc.check_invariants()
    assert alloc.allocated_pages() == 0
    for s in range(n_shards):
        assert alloc.available(s) == alloc.usable_per_shard


def test_allocator_typed_errors():
    """The failure surface is typed, not corrupted state: exhaustion is
    PagesExhaustedError, misuse (double reserve, alloc without
    reservation) is ValueError, and scratch is never handed out."""
    alloc = PageAllocator(8, 2)  # 4 pages/shard: 3 usable + scratch
    with pytest.raises(PagesExhaustedError):
        alloc.reserve("big", 4, 0)  # > 3 usable
    alloc.reserve("a", 3, 0)
    with pytest.raises(ValueError):
        alloc.reserve("a", 1, 0)  # double reserve
    with pytest.raises(PagesExhaustedError):
        alloc.reserve("b", 1, 0)  # shard 0 fully reserved
    alloc.reserve("b", 1, 1)  # other shard unaffected
    with pytest.raises(ValueError):
        alloc.alloc("nobody")
    pages = [alloc.alloc("a") for _ in range(3)]
    assert alloc.scratch(0) not in pages
    assert alloc.scratch(1) not in pages
    with pytest.raises(PagesExhaustedError):
        alloc.alloc("a")  # reservation exhausted, no slack
    assert alloc.free("a") == 3
    alloc.check_invariants()


def test_submit_rejects_never_satisfiable_request(built):
    """A request whose worst-case page need exceeds a whole shard's
    usable pool can never seat: `submit` raises the typed
    PagesExhaustedError at the boundary instead of stalling the queue
    forever."""
    model, params, FastEngine, _, _ = built["qwen3_8b"]
    tiny = PagingConfig(page_size=PAGE, n_pages=3)  # 2 usable pages
    eng = FastEngine(model, params, batch_size=2, paging=tiny)
    with pytest.raises(PagesExhaustedError):
        eng.submit(E.Request(
            uid=0,
            prompt=jax.random.randint(
                jax.random.PRNGKey(0), (9,), 0, model.cfg.vocab
            ),
            max_new=8,  # worst case 4 pages > 2 usable
        ))
    assert 0 not in eng._inflight  # rejection left no residue
    assert eng.admissible(2, 2)
    assert not eng.admissible(9, 8)


def test_admission_defers_until_pages_free(built):
    """Exhaustion at admission is deferral, not rejection: two
    satisfiable-but-not-together requests serialize through the page
    pool and both finish with reference streams."""
    model, params, FastEngine, prefill, decode = built["qwen3_8b"]
    # 5 usable pages; each request's worst case is 4 -> one at a time
    eng = FastEngine(
        model, params, batch_size=2,
        paging=PagingConfig(page_size=PAGE, n_pages=6),
    )
    reqs = [
        E.Request(uid=i, prompt=jax.random.randint(
            jax.random.PRNGKey(80 + i), (9,), 0, model.cfg.vocab
        ), max_new=6)
        for i in range(2)
    ]
    for r in reqs:
        eng.submit(r)
    eng.tick()
    # only one seated; the other is held in FIFO order, still queued
    assert sum(s is not None for s in eng._slots) == 1
    assert len(eng._queue) == 1
    eng.run(max_ticks=40)
    for r in reqs:
        assert r.done
        assert r.output == _ref_stream(prefill, decode, params, r), r.uid
    eng._pg.check_invariants()
    assert eng._pg.allocated_pages() == 0


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("qwen3_8b", "recurrentgemma_2b"))
def test_chunked_prefill_does_not_starve_shorts(built, name):
    """Starvation regression: a max-length prompt submitted FIRST must
    not delay co-submitted shorts — the shorts' first tokens appear on
    the very first tick (batched admission) and are the first TTFT
    observations the telemetry layer sees, while the long prompt
    prefills in chunks and lands within its bounded tick budget."""
    from repro import obs

    model, params, FastEngine, prefill, decode = built[name]
    chunk = PAGE
    long_len = MAX_SEQ - 6  # max-length prompt for this pool
    saved = obs.get()
    tel = obs.configure(enabled=True)
    try:
        eng = FastEngine(
            model, params, batch_size=3, paging=PAGING,
            chunk_tokens=chunk,
        )
        long = E.Request(uid=0, prompt=jax.random.randint(
            jax.random.PRNGKey(90), (long_len,), 0, model.cfg.vocab
        ), max_new=4)
        shorts = [
            E.Request(uid=1 + i, prompt=jax.random.randint(
                jax.random.PRNGKey(91 + i), (3,), 0, model.cfg.vocab
            ), max_new=4)
            for i in range(2)
        ]
        ttft = tel.registry.histogram("serve.ttft_s")
        eng.submit(long)  # ahead of the shorts in FIFO order
        for r in shorts:
            eng.submit(r)
        eng.tick()
        for r in shorts:
            assert len(r.output) >= 1, "short starved behind long prefill"
        # the TTFT histogram saw exactly the two shorts — the long's
        # first token is still chunks away
        assert ttft.count == 2, ttft.count
        # the long prompt's first token needs ceil(long_len/chunk)
        # chunk ticks; allow one extra for seating
        budget = -(-long_len // chunk) + 1
        ticks = 1
        while not long.output and ticks < budget + 1:
            eng.tick()
            ticks += 1
        assert long.output, f"long prompt got no token in {ticks} ticks"
        assert ticks <= budget, (ticks, budget)
        assert ttft.count == 3, ttft.count
        eng.run(max_ticks=40)
        assert long.done and all(r.done for r in shorts)
    finally:
        obs.install(saved)
    for r in shorts:  # chunking must not perturb the shorts' streams
        assert r.output == _ref_stream(prefill, decode, params, r), r.uid


@pytest.mark.parametrize("name", ("qwen3_8b", "recurrentgemma_2b"))
def test_chunked_prefill_paged_matches_dense(built, name):
    """The chunked prefill cell is the same computation over both
    pools: dense-chunked and paged-chunked engines are bitwise
    token-identical on a mixed short/long workload."""
    model, params, FastEngine, _, _ = built[name]
    def mkreqs():
        return [
            E.Request(uid=i, prompt=jax.random.randint(
                jax.random.PRNGKey(95 + i), (s_len,), 0, model.cfg.vocab
            ), max_new=4)
            for i, s_len in enumerate((13, 2, 9, 3))
        ]
    outs = {}
    for label, kw in (
        ("dense", {}), ("paged", {"paging": PAGING}),
    ):
        reqs = mkreqs()
        eng = FastEngine(
            model, params, batch_size=2, chunk_tokens=PAGE, **kw
        )
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=60)
        assert all(r.done for r in reqs)
        outs[label] = [r.output for r in reqs]
    assert outs["dense"] == outs["paged"]


# ---------------------------------------------------------------------------
# Seating inverses
# ---------------------------------------------------------------------------


def test_scatter_then_gather_pages_roundtrip(built):
    """`gather_pages` inverts `scatter_pages`: seat dense rows into the
    paged pool under a page mapping, gather them back, and recover the
    rows bitwise (paged K/V leaves and dense slot_pos/recurrent leaves
    alike)."""
    model, params, _, prefill, _ = built["qwen3_8b"]
    layouts = model.page_layouts(PAGE)
    span = validate_page_size(PAGE, model.attn_capacities())
    pool = model.init_cache_paged(4, N_PAGES, PAGE)
    prompts = jax.random.randint(
        jax.random.PRNGKey(5), (2, MAX_SEQ), 0, model.cfg.vocab
    )
    _, rows = prefill(params, prompts)
    # two slots, fully mapped, disjoint pages (scratch untouched)
    phys = jnp.asarray(
        [list(range(span)), list(range(span, 2 * span))], jnp.int32
    )
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([1, 3], jnp.int32)
    pool2 = seating.scatter_pages(
        pool, rows, src, dst, phys, layouts=layouts
    )
    back = seating.gather_pages(pool2, dst, phys, layouts=layouts)
    for a, b in zip(jax.tree.leaves(rows), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paging_config_and_page_math():
    """PagingConfig validation and the pages_for_position ring cap."""
    with pytest.raises(ValueError):
        PagingConfig(page_size=0, n_pages=8)
    with pytest.raises(ValueError):
        PagingConfig(page_size=4, n_pages=1)
    with pytest.raises(ValueError):
        validate_page_size(5, (24, 8))  # 5 divides neither
    assert validate_page_size(4, (24, 8)) == 6
    assert validate_page_size(4, ()) == 0  # pure recurrent
    assert pages_for_position(-1, 4, 6) == 0
    assert pages_for_position(0, 4, 6) == 1
    assert pages_for_position(3, 4, 6) == 1
    assert pages_for_position(4, 4, 6) == 2
    assert pages_for_position(23, 4, 6) == 6
    # ring wrap: windowed caches cap at span regardless of position
    assert pages_for_position(1000, 4, 6) == 6
    assert pages_for_position(1000, 4, 0) == 0


# ---------------------------------------------------------------------------
# 8-device mesh (slow lane: scripts/ci.sh forces 8 host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (scripts/ci.sh forces 8 host devices)",
)
@pytest.mark.parametrize("name", ("qwen3_8b", "recurrentgemma_2b"))
@pytest.mark.parametrize("chunk_tokens", (None, PAGE))
def test_sharded_paged_matches_sharded_dense(built, name, chunk_tokens):
    """On the 8-device data mesh, the paged pool (pages sharded over
    the same data axis as the slots they serve) is token-for-token
    identical to the dense sharded pool — with and without chunked
    prefill — and every slot's pages stay on the slot's shard."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.serve import sharded as SH

    model, params, _, _, _ = built[name]
    mesh = make_smoke_mesh(8, 1)
    paging = PagingConfig(page_size=PAGE, n_pages=8 * N_PAGES)

    def mkreqs():
        rng = np.random.default_rng(3)
        return [
            E.Request(uid=i, prompt=jax.random.randint(
                jax.random.PRNGKey(1000 + i),
                (int(rng.choice(PROMPT_LENS)),), 0, model.cfg.vocab
            ), max_new=int(rng.integers(2, 5)))
            for i in range(12)
        ]

    outs = {}
    for label, kw in (
        ("dense", {}),
        ("paged", {"paging": paging, "chunk_tokens": chunk_tokens}),
    ):
        reqs = mkreqs()
        eng = SH.ShardedEngine(
            model, params, batch_size=8, mesh=mesh, **kw
        )
        for r in reqs:
            eng.submit(r)
        mid_checked = False
        for _ in range(60):
            n = eng.tick()
            if eng._pg is not None and any(
                s is not None for s in eng._slots
            ):
                # live audit: every mapped page (non-scratch entries)
                # lives in its slot's shard range
                per = eng._pg.per_shard
                for slot in range(eng.batch):
                    shard = eng._slot_shard(slot)
                    for p in eng._tbl[slot][: eng._npages[slot]]:
                        assert per * shard <= p < per * (shard + 1)
                mid_checked = True
            if n == 0 and not eng._queue:
                break
        assert all(r.done for r in reqs)
        if eng._pg is not None:
            assert mid_checked
            eng._pg.check_invariants()
            assert eng._pg.allocated_pages() == 0
        outs[label] = [r.output for r in reqs]
    assert outs["dense"] == outs["paged"]
