"""RWKV-6 chunked==stepwise; RG-LRU associative-scan==stepwise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru, rwkv6


def test_wkv_chunked_equals_step():
    B, S, H, hd = 2, 32, 2, 8
    key = jax.random.PRNGKey(0)
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd))
               for i in range(3))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                    (B, S, H, hd)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd)) * 0.1
    st0 = jnp.zeros((B, H, hd, hd))
    o_chunk, st_chunk = rwkv6.wkv_chunked(r, k, v, lw, u, st0)
    st = st0
    outs = []
    for t in range(S):
        o, st = rwkv6.wkv_step(r[:, t], k[:, t], v[:, t], lw[:, t], u, st)
        outs.append(o)
    o_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(o_chunk, o_step, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_chunk, st, rtol=1e-4, atol=1e-4)


def test_wkv_carries_state_across_calls():
    """Two half-sequences with carried state == one full sequence."""
    B, S, H, hd = 1, 32, 2, 8
    key = jax.random.PRNGKey(1)
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd))
               for i in range(3))
    lw = -jnp.exp(jnp.zeros((B, S, H, hd)) - 1.0)
    u = jnp.zeros((H, hd))
    st0 = jnp.zeros((B, H, hd, hd))
    full, _ = rwkv6.wkv_chunked(r, k, v, lw, u, st0)
    h = S // 2
    first, st_mid = rwkv6.wkv_chunked(
        r[:, :h], k[:, :h], v[:, :h], lw[:, :h], u, st0
    )
    second, _ = rwkv6.wkv_chunked(
        r[:, h:], k[:, h:], v[:, h:], lw[:, h:], u, st_mid
    )
    np.testing.assert_allclose(
        jnp.concatenate([first, second], 1), full, rtol=1e-4, atol=1e-4
    )


def test_rwkv_block_decode_equals_train():
    B, S, D, hd, F = 2, 16, 32, 8, 64
    p = rwkv6.rwkv_init(jax.random.PRNGKey(2), D, F, hd)
    p = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(jax.random.PRNGKey(3),
                                               x.shape), p
    )
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, D))
    full, _ = rwkv6.block_apply(p, x, hd, dtype=jnp.float32)
    cache = None
    outs = []
    for t in range(S):
        o, cache = rwkv6.block_apply(p, x[:, t:t + 1], hd, cache=cache,
                                     dtype=jnp.float32)
        outs.append(o)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), full, rtol=2e-4, atol=2e-4
    )


def test_rglru_decode_equals_train():
    B, S, D, R = 2, 24, 32, 16
    p = rglru.rglru_init(jax.random.PRNGKey(5), D, R)
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, D))
    full, _ = rglru.rglru_apply(p, x, dtype=jnp.float32)
    cache = None
    outs = []
    for t in range(S):
        o, cache = rglru.rglru_apply(p, x[:, t:t + 1], cache=cache,
                                     dtype=jnp.float32)
        outs.append(o)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), full, rtol=2e-4, atol=2e-4
    )


def test_rglru_state_bounded():
    """|a_t| < 1 by construction: state cannot blow up over long rollouts."""
    B, S, D, R = 1, 512, 16, 8
    p = rglru.rglru_init(jax.random.PRNGKey(7), D, R)
    x = jax.random.normal(jax.random.PRNGKey(8), (B, S, D)) * 5.0
    y, cache = rglru.rglru_apply(p, x, dtype=jnp.float32)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(cache["h"]).max()) < 1e3


def test_decay_clamp_keeps_chunks_finite():
    """Worst-case decay within the clamp cannot overflow f32 in a chunk."""
    B, S, H, hd = 1, rwkv6.CHUNK, 1, 4
    r = jnp.ones((B, S, H, hd))
    k = jnp.ones((B, S, H, hd))
    v = jnp.ones((B, S, H, hd))
    lw = jnp.full((B, S, H, hd), -4.0)  # fastest decay under WW_CLAMP
    u = jnp.zeros((H, hd))
    out, st = rwkv6.wkv_chunked(r, k, v, lw, u, jnp.zeros((B, H, hd, hd)))
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(st).all())
