"""Compressed multi-pod DP training: two-stage reduction properties,
error-buffer checkpointing, interrupted-vs-uninterrupted equivalence,
and the launcher composition.

Multi-device tests need `scripts/ci.sh` (8 forced host devices); on a
single-device host they skip. The hypothesis property tests sample pod
counts from the divisors of whatever device count is available, so the
n=1 degenerate case is exercised everywhere.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import configs, optim
from repro.data import lm
from repro.dist import compression as C
from repro.models import api
from repro.train import checkpoint as ckpt
from repro.train import fault, trainer

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (scripts/ci.sh forces 8 host devices)",
)
eight_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (scripts/ci.sh forces 8 host devices)",
)

_POD_COUNTS = [n for n in (1, 2, 4, 8) if n <= jax.device_count()]


def _pod_mesh(n):
    return jax.make_mesh(
        (n,), ("pod",), devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,),
    )


# ---------------------------------------------------------------------------
# two-stage reduction: hypothesis properties
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _two_stage_reduce(n, sizes):
    """Jitted shard_map running the two-stage reduction for a dict tree
    with leaves of the given flat sizes over an n-pod mesh. Inputs
    carry a leading (n,) pod dim."""
    mesh = _pod_mesh(n)

    def body(g, e1, e2):
        sq = lambda t: jax.tree.map(lambda x: x[0], t)  # noqa: E731
        ex = lambda t: jax.tree.map(lambda x: x[None], t)  # noqa: E731
        m, a, b = C.two_stage_psum_mean(sq(g), sq(e1), sq(e2), "pod")
        return m, ex(a), ex(b)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P("pod"), P("pod"), P("pod")),
        out_specs=(P(), P("pod"), P("pod")),
        check_rep=False,
    ))


def _rand_tree(key, n, sizes, mag):
    ks = jax.random.split(key, len(sizes))
    return {
        f"l{i}": jax.random.normal(ks[i], (n, s)) * mag
        for i, s in enumerate(sizes)
    }


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from(_POD_COUNTS),
    s0=st.integers(1, 40),
    s1=st.integers(1, 200),
    seed=st.integers(0, 2**16),
    logmag=st.integers(-2, 3),
)
def test_two_stage_mean_within_one_shot_bound(n, s0, s1, seed, logmag):
    """From zero buffers, the two-stage dequantized mean is within the
    composed one-shot quantization bound of the f32 mean: each stage
    contributes at most half its scale, and both scales are bounded by
    amax/127 (stage 2's by a hair more — its operand is the stage-1
    mean plus its own error, bounded by amax*(1 + 1/254))."""
    mag = 10.0 ** logmag
    sizes = (s0, s1)
    g = _rand_tree(jax.random.PRNGKey(seed), n, sizes, mag)
    e1 = jax.tree.map(jnp.zeros_like, g)
    e2 = {
        k: jnp.zeros((n, C.two_stage_shard_len(v.shape[1], n)))
        for k, v in g.items()
    }
    mean, _, _ = _two_stage_reduce(n, sizes)(g, e1, e2)
    for k in g:
        amax = float(jnp.abs(g[k]).max())
        bound = amax / 127.0 * 1.05 + 1e-7
        err = float(jnp.abs(mean[k] - jnp.mean(g[k], 0)).max())
        assert err <= bound, (k, err, bound, n, sizes, mag)


@settings(max_examples=6, deadline=None)
@given(
    n=st.sampled_from(_POD_COUNTS),
    s0=st.integers(1, 40),
    s1=st.integers(1, 200),
    steps=st.integers(2, 5),
    seed=st.integers(0, 2**16),
    logmag=st.integers(-2, 2),
)
def test_two_stage_error_feedback_telescopes(n, s0, s1, steps, seed,
                                             logmag):
    """Over multi-step sequences both error-feedback stages telescope:
    sum of the returned means + pod-mean of err1 + the assembled err2
    shards reconstructs the sum of true f32 means (losslessness over
    time, the property that makes compressed SGD unbiased)."""
    mag = 10.0 ** logmag
    sizes = (s0, s1)
    fn = _two_stage_reduce(n, sizes)
    key = jax.random.PRNGKey(seed)
    e1 = {f"l{i}": jnp.zeros((n, s)) for i, s in enumerate(sizes)}
    e2 = {
        f"l{i}": jnp.zeros((n, C.two_stage_shard_len(s, n)))
        for i, s in enumerate(sizes)
    }
    sent = {f"l{i}": jnp.zeros(s) for i, s in enumerate(sizes)}
    true = {f"l{i}": jnp.zeros(s) for i, s in enumerate(sizes)}
    for t in range(steps):
        g = _rand_tree(jax.random.fold_in(key, t), n, sizes, mag)
        mean, e1, e2 = fn(g, e1, e2)
        sent = jax.tree.map(jnp.add, sent, mean)
        true = jax.tree.map(
            jnp.add, true, jax.tree.map(lambda x: jnp.mean(x, 0), g)
        )
    for i, s in enumerate(sizes):
        k = f"l{i}"
        resid = jnp.mean(e1[k], 0) + e2[k].reshape(-1)[:s]
        np.testing.assert_allclose(
            np.asarray(sent[k] + resid), np.asarray(true[k]),
            rtol=2e-4, atol=2e-4 * mag * steps + 1e-6,
        )


# ---------------------------------------------------------------------------
# non-finite gradient parity across the reduction paths
# ---------------------------------------------------------------------------


@multidevice
def test_nonfinite_injection_parity_across_paths():
    """A loss-spike pod emitting inf/NaN is zeroed identically by every
    reduction path (compress=False included — the fair-ablation guard);
    `finite_guard=False` reproduces the raw IEEE propagation."""
    n = jax.device_count()
    mesh = _pod_mesh(n)
    k = 64
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, k))}
    bad = g["w"].at[0, 0].set(jnp.inf).at[0, 1].set(-jnp.inf)
    bad = bad.at[0, 2].set(jnp.nan)
    g = {"w": bad}
    # expected: the injecting pod's non-finite entries contribute 0
    zeroed = jnp.where(jnp.isfinite(bad), bad, 0.0)
    expected = jnp.mean(zeroed, 0)
    amax = float(jnp.abs(zeroed).max())

    def run_gather():
        e = {"w": jnp.zeros((n, k))}

        def body(gg, ee):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)  # noqa: E731
            m, ne = C.compressed_psum_mean(sq(gg), sq(ee), "pod")
            return m, jax.tree.map(lambda x: x[None], ne)

        f = shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P(), P("pod")), check_rep=False,
        )
        return f(g, e)[0]["w"]

    def run_two_stage():
        e1 = {"w": jnp.zeros((n, k))}
        e2 = {"w": jnp.zeros((n, C.two_stage_shard_len(k, n)))}
        return _two_stage_reduce(n, (k,))(
            {"l0": g["w"]}, {"l0": e1["w"]}, {"l0": e2["w"]}
        )[0]["l0"]

    def run_uncompressed(**kw):
        f = shard_map(
            lambda gg: C.uncompressed_psum_mean(
                jax.tree.map(lambda x: x[0], gg), "pod", **kw
            ),
            mesh=mesh, in_specs=(P("pod"),), out_specs=P(),
            check_rep=False,
        )
        return f(g)["w"]

    for name, out in (
        ("gather", run_gather()),
        ("two_stage", run_two_stage()),
        ("uncompressed", run_uncompressed()),
    ):
        assert bool(jnp.isfinite(out).all()), name
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expected),
            atol=2 * amax / 127.0 + 1e-6, err_msg=name,
        )
    raw = run_uncompressed(finite_guard=False)
    assert not bool(jnp.isfinite(raw).all())


# ---------------------------------------------------------------------------
# error-buffer checkpointing + interrupted-run equivalence
# ---------------------------------------------------------------------------


def _dp_setup(mesh, scheme, *, compress=True, seed=0):
    cfg = configs.reduced("qwen3_8b")
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(seed))
    opt = optim.adamw(3e-3)
    state = trainer.init_state(params, opt)
    state["err"] = trainer.init_dp_err(
        params, mesh, scheme=scheme, compress=compress
    )
    step = jax.jit(trainer.make_dp_step_compressed(
        model.loss, opt, mesh, scheme=scheme, compress=compress
    ))
    stream = lm.TokenStream(batch=8, seq_len=16, vocab=cfg.vocab,
                            seed=seed)
    return cfg, model, state, step, stream


@multidevice
@pytest.mark.parametrize("scheme", ["gather", "two_stage"])
def test_err_buffers_checkpoint_roundtrip_bitwise(tmp_path, scheme):
    """The per-pod error buffers are part of state and round-trip
    bitwise — including DISTINCT per-pod residuals (the old replicated
    out-spec silently saved one pod's copy for all, breaking the
    telescoping identity on every restart)."""
    n = jax.device_count()
    mesh = _pod_mesh(n)
    _, _, state, step, stream = _dp_setup(mesh, scheme)
    for i in range(3):
        state, _ = step(state, stream.batch_at(i))
    e1 = np.asarray(jax.tree.leaves(state["err"]["s1"])[0])
    per_pod = np.abs(e1).sum(axis=tuple(range(1, e1.ndim)))
    assert np.ptp(per_pod) > 0, "pods should carry distinct residuals"

    ckpt.save(state, str(tmp_path), 3)
    restored, s = ckpt.restore(str(tmp_path), state)
    assert s == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multidevice
@pytest.mark.parametrize("scheme", ["gather", "two_stage"])
def test_interrupted_equals_uninterrupted(tmp_path, scheme):
    """Kill-and-resume mid-run reproduces the uninterrupted loss curve
    bitwise: the restored error buffers re-enter the quantizer exactly
    where the killed run left them."""
    n = jax.device_count()
    mesh = _pod_mesh(n)
    _, _, state0, step, stream = _dp_setup(mesh, scheme)

    def fresh():
        return jax.tree.map(
            lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, state0
        )

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    final1, hist1 = fault.run_training(
        step, fresh(), stream.batch_at, num_steps=10,
        ckpt_dir=d1, ckpt_every=4, log_every=0,
    )
    # interrupted twin: stop at 6 (kill), then resume to 10
    fault.run_training(
        step, fresh(), stream.batch_at, num_steps=6,
        ckpt_dir=d2, ckpt_every=4, log_every=0,
    )
    final2, hist2b = fault.run_training(
        step, fresh(), stream.batch_at, num_steps=10,
        ckpt_dir=d2, ckpt_every=4, log_every=0,
    )
    assert hist2b[0]["step"] == 6
    tail1 = [h["loss"] for h in hist1 if h["step"] >= 6]
    tail2 = [h["loss"] for h in hist2b]
    assert tail1 == tail2  # bitwise: same floats, not approx
    for a, b in zip(jax.tree.leaves(final1), jax.tree.leaves(final2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the composed launcher path (pjit in-pod x compressed pod axis)
# ---------------------------------------------------------------------------


def _multipod_setup(scheme, *, compress=True, seed=0):
    from repro.launch.mesh import make_multipod_mesh

    cfg = configs.reduced("qwen3_8b")
    mesh = make_multipod_mesh("2x2x2")
    model = api.build_model(cfg, tp=1, max_seq=32)
    opt = optim.adamw(3e-3)

    def fresh_state():
        params = model.init(jax.random.PRNGKey(seed))
        state = trainer.init_state(params, opt)
        state["err"] = trainer.init_dp_err(
            params, mesh, scheme=scheme, compress=compress
        )
        return state

    state = fresh_state()
    py_step, s_shard = trainer.make_multipod_train_step(
        model.loss, opt, cfg, mesh, jax.eval_shape(lambda: state),
        scheme=scheme, compress=compress,
    )
    stream = lm.TokenStream(batch=8, seq_len=16, vocab=cfg.vocab,
                            seed=seed)
    return fresh_state, py_step, s_shard, stream


@eight_devices
@pytest.mark.slow
def test_multipod_kill_resume_bitwise(tmp_path):
    """Acceptance: the composed multi-pod step (in-pod pjit x pod-axis
    compressed reduction) under `fault.run_training` — kill-and-resume
    mid-run reproduces the uninterrupted loss curve bitwise, error
    buffers restored under the trainer's state shardings."""
    fresh_state, py_step, s_shard, stream = _multipod_setup("two_stage")
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    final1, hist1 = fault.run_training(
        py_step, fresh_state(), stream.batch_at, num_steps=8,
        ckpt_dir=d1, ckpt_every=3, log_every=0,
        restore_shardings=s_shard,
    )
    fault.run_training(
        py_step, fresh_state(), stream.batch_at, num_steps=5,
        ckpt_dir=d2, ckpt_every=3, log_every=0,
        restore_shardings=s_shard,
    )
    final2, hist2 = fault.run_training(
        py_step, fresh_state(), stream.batch_at, num_steps=8,
        ckpt_dir=d2, ckpt_every=3, log_every=0,
        restore_shardings=s_shard,
    )
    assert hist2[0]["step"] == 5
    tail1 = [h["loss"] for h in hist1 if h["step"] >= 5]
    assert tail1 == [h["loss"] for h in hist2]
    for a, b in zip(jax.tree.leaves(final1), jax.tree.leaves(final2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@eight_devices
@pytest.mark.slow
def test_multipod_fault_injection_recovers(tmp_path):
    """An injected mid-run failure rolls the composed step back to the
    latest checkpoint (err buffers included) and completes."""
    fresh_state, py_step, s_shard, stream = _multipod_setup("gather")
    injector = fault.FaultInjector(fail_at={5})
    final, hist = fault.run_training(
        py_step, fresh_state(), stream.batch_at, num_steps=8,
        ckpt_dir=str(tmp_path), ckpt_every=3, log_every=0,
        fault_hook=injector, restore_shardings=s_shard,
    )
    assert injector.failures == 1
    assert int(final["step"]) == 8
    assert hist[-1]["step"] == 7


@eight_devices
@pytest.mark.slow
def test_multipod_loss_decreases_all_modes():
    """gather / two_stage / uncompressed all train the reduced config:
    compression does not break convergence on the composed path."""
    for scheme, compress in (("gather", True), ("two_stage", True),
                             ("gather", False)):
        fresh_state, py_step, _, stream = _multipod_setup(
            scheme, compress=compress
        )
        state = fresh_state()
        losses = []
        for i in range(24):
            state, m = py_step(state, stream.batch_at(i))
            losses.append(float(m["loss"]))
        # tiny (8, 16) batches make single-step losses ±0.2 noisy, so
        # compare 6-step window means (the trend, which is the claim)
        # rather than two individual samples
        first = sum(losses[:6]) / 6
        last = sum(losses[-6:]) / 6
        assert last < first - 0.05, (scheme, compress, losses)


def test_multipod_requires_pod_axis():
    mesh = jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    cfg = configs.reduced("qwen3_8b")
    with pytest.raises(ValueError, match="pod"):
        trainer.make_multipod_train_step(
            lambda p, b: (0.0, {}), optim.adamw(1e-3), cfg, mesh, {}
        )


def test_init_dp_err_shapes_and_validation():
    mesh = _pod_mesh(1)
    params = {"w": jnp.zeros((5, 3)), "b": jnp.zeros((7,))}
    with pytest.raises(ValueError, match="scheme"):
        trainer.init_dp_err(params, mesh, scheme="bogus")
    assert trainer.init_dp_err(params, mesh, compress=False) == {}
    err = trainer.init_dp_err(params, mesh, scheme="two_stage")
    assert err["s1"]["w"].shape == (1, 5, 3)
    assert err["s2"]["w"].shape == (1, C.two_stage_shard_len(15, 1))
    assert err["s2"]["b"].shape == (1, 7)
