"""Data pipelines: filter response, morphology stats, determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import iegm, lm


def test_bandpass_response():
    resp = iegm.filter_response_db(np.array([2.0, 5.0, 20.0, 35.0, 50.0,
                                             90.0, 110.0]))
    # passband ~flat, stopbands heavily attenuated
    assert resp[2] > -3 and resp[3] > -3 and resp[4] > -6
    assert resp[0] < -40 and resp[-1] < -40


def test_bandpass_removes_wander():
    t = jnp.arange(512) / iegm.SAMPLE_RATE_HZ
    wander = jnp.sin(2 * jnp.pi * 0.3 * t)  # respiration band
    beat = jnp.sin(2 * jnp.pi * 25.0 * t)  # in-band
    y_w = iegm.bandpass(wander[None])
    y_b = iegm.bandpass(beat[None])
    assert float(jnp.std(y_w)) < 0.05 * float(jnp.std(y_b))


def test_synth_batch_schema_and_balance():
    b = iegm.synth_batch(jax.random.PRNGKey(0), 256)
    assert b["signal"].shape == (256, 512)
    assert b["signal"].dtype == jnp.float32
    assert 0.35 < float(b["label"].mean()) < 0.65
    assert bool(jnp.isfinite(b["signal"]).all())


def test_morphologies_are_spectrally_distinct():
    """VT is a 2.5-4.2 Hz near-sinusoid; NSR's narrow spikes put their
    dominant energy at higher harmonics — the feature the CNN learns."""
    key = jax.random.PRNGKey(1)
    n = 128
    nsr = iegm._nsr(key, n)
    vt = iegm._vt(key, n)
    def domfreq(x):
        f = jnp.abs(jnp.fft.rfft(x, axis=1))
        freqs = jnp.fft.rfftfreq(x.shape[1], 1 / iegm.SAMPLE_RATE_HZ)
        return freqs[jnp.argmax(f[:, 1:], axis=1) + 1]
    vt_dom = float(jnp.median(domfreq(vt)))
    nsr_dom = float(jnp.median(domfreq(nsr)))
    assert 2.0 < vt_dom < 9.0  # VT fundamental (150-250 bpm + harmonic)
    assert nsr_dom > vt_dom + 3.0  # spike harmonics sit well above


def test_stream_determinism_and_host_sharding():
    s0 = iegm.IEGMStream(batch=8, seed=3, host_id=0)
    s0b = iegm.IEGMStream(batch=8, seed=3, host_id=0)
    s1 = iegm.IEGMStream(batch=8, seed=3, host_id=1)
    a, b, c = s0.batch_at(5), s0b.batch_at(5), s1.batch_at(5)
    np.testing.assert_array_equal(a["signal"], b["signal"])
    assert float(jnp.abs(a["signal"] - c["signal"]).max()) > 1e-3


def test_diagnosis_batch_segments_share_label():
    d = iegm.synth_diagnosis_batch(jax.random.PRNGKey(2), 4)
    assert d["signal"].shape == (4, 6, 512)
    assert d["label"].shape == (4,)


def test_lm_stream_schema():
    b = lm.batch_at(0, 7, batch=4, seq_len=32, vocab=1000)
    assert b["tokens"].shape == (4, 32)
    assert int(b["tokens"].max()) < 1000
    # next-token alignment: targets are the shifted stream
    b2 = lm.batch_at(0, 7, batch=4, seq_len=32, vocab=1000)
    np.testing.assert_array_equal(b["targets"], b2["targets"])


def test_lm_learnable_structure():
    """The walk makes consecutive tokens close (mod vocab) — a model can
    beat the uniform baseline."""
    b = lm.batch_at(0, 0, batch=64, seq_len=128, vocab=1000)
    t, tgt = np.asarray(b["tokens"]), np.asarray(b["targets"])
    diff = np.minimum((tgt - t) % 1000, (t - tgt) % 1000)
    assert np.median(diff) <= 8
