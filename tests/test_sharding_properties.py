"""Property tests for `dist.sharding` cache/batch spec rules.

The contract the sharded decode path relies on: for ANY generated cache
pytree and mesh shape, the returned specs are divisibility-valid (every
named axis divides the dim it shards), and under `strict=True` a leaf
that cannot shard its batch dim raises `ShardingGuardError` instead of
silently replicating — per-device memory accounting is only honest if
replication can never happen behind the guard's back.

The spec functions are pure over (shapes, mesh.shape, mesh.axis_names),
so a duck-typed mesh lets hypothesis sweep mesh geometries far beyond
the host's real device count; `tests/test_dist_multidevice.py` covers
the same rules on real multi-device meshes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.sharding import ShardingGuardError


class _FakeMesh:
    """Duck-typed mesh: `.shape` (name -> size) and `.axis_names` are
    all the spec rules read."""

    def __init__(self, **axes: int):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


@dataclasses.dataclass(frozen=True)
class _Cfg:
    use_tp: bool = True
    fsdp: bool = True


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _cache_tree(g, b, s, h, dh, r):
    """The shapes `models.transformer.init_cache` produces: stacked
    "blocks" subtrees with a leading layer-group dim, KV buffers in
    (G, B, S, H, Dh) layout, plus batch-leading "tail" leaves."""
    return {
        "blocks": {
            "pos0": {
                "attn": {
                    "k": _sds(g, b, s, h, dh),
                    "v": _sds(g, b, s, h, dh),
                    "slot_pos": _sds(g, b, s),
                }
            }
        },
        "tail": {"pos0": {"rec": {"h": _sds(b, r), "conv": _sds(b, 3, r)}}},
    }


def _assert_valid(tree, specs, mesh):
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert len(spec) == len(leaf.shape), (spec, leaf.shape)
        for dim, entry in zip(leaf.shape, spec):
            size = shd._axis_size(entry, mesh)
            assert dim % size == 0, (leaf.shape, spec, entry)


@settings(max_examples=60, deadline=None)
@given(
    n_data=st.integers(1, 8),
    n_model=st.integers(1, 4),
    use_tp=st.booleans(),
    batch=st.integers(1, 48),
    heads=st.integers(1, 9),
    groups=st.integers(1, 3),
    slots=st.sampled_from([4, 16, 64]),
)
def test_cache_specs_valid_or_guarded(
    n_data, n_model, use_tp, batch, heads, groups, slots
):
    mesh = _FakeMesh(data=n_data, model=n_model)
    cfg = _Cfg(use_tp=use_tp)
    tree = _cache_tree(groups, batch, slots, heads, 8, 24)
    data_size = shd._axis_size(shd.data_axes(cfg, mesh), mesh)
    divisible = batch % data_size == 0

    # non-strict: always returns, always divisibility-valid
    specs = shd.cache_specs(tree, cfg, mesh)
    _assert_valid(tree, specs, mesh)

    if not divisible and data_size > 1:
        # strict: the guard fires — never a silently replicated leaf
        with pytest.raises(ShardingGuardError):
            shd.cache_specs(tree, cfg, mesh, strict=True)
        return

    strict_specs = shd.cache_specs(tree, cfg, mesh, strict=True)
    assert strict_specs == specs
    # every leaf's batch dim really is sharded over the data axes: the
    # per-device cache accounting divides by these factors, so none may
    # silently replicate
    if data_size > 1:
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for kp, spec in flat:
            parts = shd._path_str(kp).split("/")
            b_idx = 1 if parts[0] in ("blocks", "dec") else 0
            assert spec[b_idx] is not None, (parts, spec)
            assert shd.spec_shard_factor(spec, mesh) >= data_size

    # KV head rule: sharded over model iff tp is on and heads divide
    # (a size-1 model axis divides trivially and may be named — harmless)
    k_spec = specs["blocks"]["pos0"]["attn"]["k"]
    tp = shd._tp_axis(cfg, mesh)
    if tp is not None and heads % n_model == 0:
        assert k_spec[3] == "model"
    else:
        assert k_spec[3] is None
    # non-KV buffers never take the model axis
    assert all(
        e != "model" for e in specs["blocks"]["pos0"]["attn"]["slot_pos"]
    )


@settings(max_examples=60, deadline=None)
@given(
    n_data=st.integers(1, 8),
    n_model=st.integers(1, 4),
    use_tp=st.booleans(),
    batch=st.integers(1, 48),
    rank=st.integers(1, 4),
    with_scalar=st.booleans(),
)
def test_batch_specs_valid_or_guarded(
    n_data, n_model, use_tp, batch, rank, with_scalar
):
    mesh = _FakeMesh(data=n_data, model=n_model)
    cfg = _Cfg(use_tp=use_tp)
    tree = {"x": _sds(*([batch] + [3] * (rank - 1)))}
    if with_scalar:
        tree["s"] = _sds()
    data_size = shd._axis_size(shd.data_axes(cfg, mesh), mesh)

    specs = shd.batch_specs(tree, cfg, mesh)
    _assert_valid(tree, specs, mesh)
    # only the leading dim is ever sharded
    assert all(e is None for e in specs["x"][1:])

    ok = batch % data_size == 0 and not with_scalar
    if data_size > 1 and not ok:
        with pytest.raises(ShardingGuardError):
            shd.batch_specs(tree, cfg, mesh, strict=True)
    else:
        strict = shd.batch_specs(tree, cfg, mesh, strict=True)
        assert strict == specs
        if data_size > 1:
            assert shd.spec_shard_factor(strict["x"], mesh) == data_size


def test_bytes_per_device_accounting_matches_hand_count():
    mesh = _FakeMesh(data=4, model=2)
    cfg = _Cfg()
    tree = _cache_tree(2, 8, 16, 4, 8, 24)
    specs = shd.cache_specs(tree, cfg, mesh, strict=True)
    per_dev = shd.bytes_per_device(tree, specs, mesh)
    # k/v: 2*8*16*4*8 f32 sharded 4-way (data) and 2-way (model heads)
    kv = 2 * (2 * 8 * 16 * 4 * 8 * 4) // 8
    # slot_pos: 2*8*16 f32 sharded 4-way
    sp = (2 * 8 * 16 * 4) // 4
    # tail h: 8*24 f32 4-way; conv: 8*3*24 f32 4-way
    tail = (8 * 24 * 4) // 4 + (8 * 3 * 24 * 4) // 4
    assert per_dev == kv + sp + tail
    # replicated baseline is exactly the unsharded byte count
    repl = jax.tree.map(
        lambda s: P(*([None] * len(s))), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
    assert shd.bytes_per_device(tree, repl, mesh) == sum(
        l.size * 4 for l in jax.tree.leaves(tree)
    )


def test_guard_error_names_the_leaf():
    mesh = _FakeMesh(data=4, model=1)
    with pytest.raises(ShardingGuardError, match="blocks/pos0/attn/k"):
        shd.cache_specs(
            {"blocks": {"pos0": {"attn": {"k": _sds(1, 6, 4, 2, 8)}}}},
            _Cfg(), mesh, strict=True,
        )
