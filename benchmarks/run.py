"""Benchmark orchestrator. One section per paper table/figure:

  table1   — Table 1 (chip power/GOPS/latency/density vs prior works)
  ablation — compression recipe accuracy (sparsity x bit-width)
  kernels  — SPE/CMUL kernel correctness + bandwidth math
  roofline — dry-run roofline summary (when artifacts exist)

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import ablation, kernels, roofline_summary, table1

    print("name,us_per_call,derived")
    failed = []
    for mod in (table1, kernels, ablation, roofline_summary):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed.append((mod.__name__, repr(e)))
            traceback.print_exc()
    if failed:
        for name, err in failed:
            print(f"{name},nan,FAILED {err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
