"""Open-loop load lab: tail-latency-vs-offered-load, knees, SLO burn.

Drives both request paths through `repro.obs.loadlab` sweeps:

  * **serve** (wall time) — the slot engine on a reduced LM config.
    Capacity is measured first (closed-loop: every request intended at
    t≈0, achieved rate = n / drain time), then the open-loop sweep
    offers 0.25x..6-8x that rate (deep past nominal, since the
    wall-clock capacity estimate is noisy) with Poisson arrivals and
    measures TTFT
    and end-to-end latency **from intended arrival times** generated up
    front on fold_in-derived keys — coordinated omission is
    structurally impossible, and the record self-asserts the guard
    (intended-based >= submit-based, strictly greater at overload).
  * **stream** (virtual time) — the fleet scheduler + modeled chip
    batches under per-patient Poisson segment arrivals at 0.25x..3x
    the modeled capacity, exactly reproducible on any host. A
    pinned URGENT cohort checks class survival: preemption must keep
    its p99.9 deadline slack non-negative through 3x overload.
  * **frontend** (wall time, loopback socket) — the async serving
    frontend (`repro.serve.frontend`) with its admission bucket pinned
    to the serve sweep's measured knee, offered 0.25x/1x/3x that rate
    over a real TCP socket. Past the knee LM requests shed with typed
    rejections (accounting stays exact: submitted == completed +
    rejected), ROUTINE segments defer, URGENT segments always land. A
    paired in-process run at the lowest sub-knee point prices the
    transport itself (socket-minus-inproc tail delta).

Both sweeps locate the saturation knee (last point whose p99 stays
within 3x the fastest point's) and evaluate declared SLOs with
error-budget burn rates. A lineage pass then joins every traced
request's spans by request id across its subsystem hops
(serve: submit → admit → prefill/seat → decode → finish; stream:
enqueue → pack → flush → classify/vote) and samples per-request
critical paths for the report waterfall.

The record is `BENCH_load.json` (shared `telemetry` schema section,
like every other BENCH); render the standalone HTML report with

    python -m repro.obs.loadlab BENCH_load.json -o load_report.html

    PYTHONPATH=src python benchmarks/load_sweep.py [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.core import compiler, vadetect
from repro.models import api
from repro.obs import lineage, loadlab
from repro.serve.engine import Engine, Request
from repro.serve.frontend import Frontend, FrontendConfig, SocketClient
from repro.stream.fleet import FleetConfig, simulate
from repro.stream.runner import FleetRunner

ARCH = "qwen3_8b"
POOL = 4
PROMPT_LEN = 6


def build_serve(max_new: int):
    """(make_engine, make_prompts) closures over one built model —
    every sweep point gets a fresh engine (fresh slots/queue) but the
    params and jit caches are shared, so per-point warmup is cheap."""
    cfg = configs.reduced(ARCH)
    model = api.build_model(cfg, tp=1, max_seq=PROMPT_LEN + max_new + 2)
    params = model.init(jax.random.PRNGKey(0))

    def make_engine():
        return Engine(model, params, batch_size=POOL)

    def make_prompts(n: int):
        toks = jax.random.randint(
            jax.random.PRNGKey(7), (n, PROMPT_LEN), 0, cfg.vocab
        )
        return [jnp.asarray(toks[i], jnp.int32) for i in range(n)]

    return make_engine, make_prompts


def measure_serve_capacity(make_engine, make_prompts, *, n: int,
                           max_new: int) -> float:
    """Closed-loop anchor: intend every request at ~t=0 (a very high
    offered rate), so the achieved rate is the drain throughput."""
    pt = loadlab.run_serve_point(
        make_engine,
        make_prompts(n),
        rate_rps=1e5,
        max_new=max_new,
        key=jax.random.PRNGKey(99),
    )
    return float(pt["achieved_rps"])


def lineage_sample(runner, make_engine, make_prompts, *, max_new: int,
                   n_samples: int = 8) -> dict:
    """One traced run per engine, joined into per-request lineages.
    Kept separate from the sweeps (fresh tracer) so the join covers a
    bounded, fully-drained set of requests."""
    saved = obs.get()
    tel = obs.configure(enabled=True)
    try:
        # serve: enough requests to exercise queueing behind the pool
        eng = make_engine()
        for i, p in enumerate(make_prompts(POOL + 2)):
            eng.submit(Request(uid=i, prompt=p, max_new=max_new))
        eng.run(max_ticks=200)
        # stream: a small fleet, default periodic arrivals
        cfg = FleetConfig(
            n_patients=8, segments_per_patient=2, seed=0,
            buckets=(8,), va_fraction=0.0,
        )
        simulate(cfg, runner=runner)
        events = tel.tracer.events()
    finally:
        obs.install(saved)

    out = {}
    for name, prefix, min_hops in (
        ("serve", "serve:", 3), ("stream", "stream:", 3),
    ):
        joined = lineage.assert_joined(
            events, min_hops=min_hops, expect_prefix=prefix
        )
        mine = {r: h for r, h in joined.items() if r.startswith(prefix)}
        summ = lineage.summarize(
            [e for e in events
             if any(r.startswith(prefix) for r in lineage._event_rids(e))]
        )
        samples = []
        for rid in sorted(mine)[:n_samples]:
            cp = lineage.critical_path(mine[rid])
            cp["request_id"] = rid
            samples.append(cp)
        out[name] = {**summ, "min_hops_required": min_hops,
                     "samples": samples}
    return out


def frontend_lineage_sample(make_engine, runner, make_prompts, *,
                            max_new: int, n_lm: int = 6,
                            n_patients: int = 4,
                            n_samples: int = 8) -> dict:
    """Traced loopback-socket run with admission control off (nothing
    sheds), joined into per-request lineages: every request — LM and
    segment — must span >= 4 distinct hops INCLUDING the transport hop
    (client-minted ids survive the wire)."""
    import asyncio

    # warm under the ambient telemetry so the warmup requests'
    # uid>=1e6 lineages don't land in the sampled trace (they have no
    # transport hop and would trip the per-request assertion below)
    fe = Frontend(engine=make_engine(), n_patients=n_patients,
                  runner=runner, cfg=FrontendConfig())
    fe.warm(PROMPT_LEN)
    prompts = make_prompts(n_lm)

    saved = obs.get()
    tel = obs.configure(enabled=True)
    try:
        async def amain() -> None:
            host, port = await fe.start("127.0.0.1", 0)
            client = await SocketClient.connect(host, port)
            futs = []
            for i in range(n_lm):
                futs.append(await client.send_lm(
                    uid=i, prompt=[int(t) for t in prompts[i]],
                    max_new=max_new,
                ))
            for p in range(n_patients):
                futs.append(await client.send_segment(
                    patient=p, seq=0, urgent=(p == 0)
                ))
            for f in futs:
                await asyncio.wait_for(f, 120.0)
            await client.drain()
            await client.close()
            await fe.stop()

        asyncio.run(amain())
        events = tel.tracer.events()
    finally:
        obs.install(saved)

    joined = lineage.assert_joined(events, min_hops=4)
    for rid, hops in joined.items():
        assert any(h.name.startswith("frontend/") for h in hops), (
            rid, sorted({h.name for h in hops}),
        )
    summ = lineage.summarize(events)
    samples = []
    for rid in sorted(joined)[:n_samples]:
        cp = lineage.critical_path(joined[rid])
        cp["request_id"] = rid
        samples.append(cp)
    return {**summ, "min_hops_required": 4, "transport": "socket",
            "samples": samples}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI")
    ap.add_argument("--out", default="BENCH_load.json")
    ap.add_argument("--report", default=None, metavar="HTML",
                    help="also render the standalone HTML report here")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write the lineage-pass trace to PREFIX.jsonl "
                         "+ PREFIX.json (Chrome/Perfetto)")
    args = ap.parse_args()

    # enabled from the start so every jit cell registers with the
    # probe and the sweeps' spans land in the telemetry section
    obs.configure(enabled=True)

    # serve sweeps push much deeper past nominal capacity than stream:
    # the serve capacity estimate is a *wall-clock* closed-loop drain
    # measurement, so on a noisy box it can come in 2-3x below the
    # true sustainable rate — a 3x top fraction then never actually
    # saturates the engine and no knee appears (seen in CI). The 6-8x
    # ceiling guarantees decisive saturation even through a 2-3x
    # capacity misestimate. Stream capacity is derived from the
    # *virtual-time* service model (deterministic), so 3x suffices.
    if args.smoke:
        serve_fractions = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
        stream_fractions = (0.25, 0.5, 0.75, 1.0, 2.0, 3.0)
        # n_requests is NOT shrunk for the smoke: with n requests the
        # worst open-loop queueing delay is bounded by ~(n-1)/capacity,
        # and the knee bound is 3x the *minimum* observed p99 — a noise
        # burst that inflates the quietest point by 2x can push the
        # bound past what n=16 requests can physically queue up,
        # leaving the knee undetectable (seen in CI). n=32 keeps the
        # saturated tail decisively above any noise-inflated bound.
        n_requests, max_new = 32, 8
        n_patients, segments_at_capacity = 16, 384
    else:
        serve_fractions = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 6.0)
        stream_fractions = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0)
        n_requests, max_new = 32, 8
        n_patients, segments_at_capacity = 64, 2048

    make_engine, make_prompts = build_serve(max_new)
    capacity = measure_serve_capacity(
        make_engine, make_prompts, n=max(2 * POOL, 8), max_new=max_new
    )
    print(f"[load_sweep] serve closed-loop capacity ~{capacity:.0f} "
          f"req/s (pool={POOL}, prompt={PROMPT_LEN}, "
          f"max_new={max_new})")

    serve = loadlab.sweep_serve(
        make_engine,
        make_prompts,
        capacity_rps=capacity,
        load_fractions=serve_fractions,
        n_requests=n_requests,
        max_new=max_new,
    )
    print(f"[load_sweep] serve: knee@"
          f"{serve['knee'].get('knee_rate', float('nan')):.0f} req/s "
          f"(growth {serve['knee'].get('post_knee_growth', 0):.1f}x), "
          f"slo_sub_saturated={serve['slo']['met_sub_saturated']}, "
          f"verdict={serve['overload']['verdict']}")

    runner = FleetRunner(
        compiler.compile_model(vadetect.init(jax.random.PRNGKey(0)))
    )
    stream = loadlab.sweep_stream(
        n_patients=n_patients,
        buckets=(8, 32),
        load_fractions=stream_fractions,
        segments_at_capacity=segments_at_capacity,
        runner=runner,
    )
    print(f"[load_sweep] stream: capacity "
          f"{stream['capacity_segments_per_s']:.0f} seg/s, knee@"
          f"{stream['knee'].get('knee_rate', float('nan')):.0f} "
          f"(growth {stream['knee'].get('post_knee_growth', 0):.1f}x), "
          f"urgent_survived={stream['overload']['urgent_survived']}, "
          f"verdict={stream['overload']['verdict']}")

    # frontend sweep: admission bucket pinned to the serve knee, loads
    # offered through a real loopback socket (wall time)
    knee_rate = float(serve["knee"].get("knee_rate") or capacity)

    def make_frontend(fcfg):
        fe = Frontend(engine=make_engine(), n_patients=8,
                      runner=runner, cfg=fcfg)
        fe.warm(PROMPT_LEN)
        return fe

    frontend = loadlab.sweep_frontend(
        make_frontend,
        make_prompts,
        admission_rate_rps=knee_rate,
        load_fractions=(0.25, 1.0, 3.0),
        n_requests=24,
        max_new=max_new,
        n_patients=8,
        segs_per_patient=3,
        urgent_fraction=0.25,
    )
    fo = frontend["overload"]
    to = frontend["transport_overhead"]
    print(f"[load_sweep] frontend: admission@{knee_rate:.0f} req/s "
          f"(serve knee), shed_curve="
          f"{[(c['load_fraction'], round(c['shed_rate'], 2)) for c in frontend['shed_curve']]}, "
          f"verdict={fo['verdict']} "
          f"(retention {fo['throughput_retention']:.2f})")
    print(f"[load_sweep] frontend transport: socket-inproc p99 "
          f"{to['socket_minus_inproc_p99_s'] * 1e3:+.2f}ms at "
          f"{to['load_fraction']}x")

    lin = lineage_sample(runner, make_engine, make_prompts,
                         max_new=max_new)
    lin["frontend"] = frontend_lineage_sample(
        make_engine, runner, make_prompts, max_new=max_new
    )
    for name in ("serve", "stream", "frontend"):
        print(f"[load_sweep] lineage[{name}]: "
              f"{lin[name]['requests']} requests joined, "
              f"{lin[name]['min_distinct_hops']}-"
              f"{lin[name]['max_distinct_hops']} distinct hops")

    rec = {
        "benchmark": "load_sweep",
        "smoke": bool(args.smoke),
        "n_host_devices": jax.device_count(),
        "serve": serve,
        "stream": stream,
        "frontend": frontend,
        "lineage": lin,
        "telemetry": obs.telemetry_section(),
    }
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        rec["trace"] = {"jsonl": jsonl, "chrome": chrome}
        print(f"[obs] trace written: {jsonl} + {chrome}")
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    print(f"[load_sweep] -> {args.out}")
    if args.report:
        from repro.obs import report

        print(f"[load_sweep] report -> "
              f"{report.render_report(rec, args.report)}")

    # -- acceptance: the record self-asserts its claims -----------------
    for name, sweep in (("serve", serve), ("stream", stream)):
        assert len(sweep["points"]) >= 5, (name, len(sweep["points"]))
        for p in sweep["points"]:
            assert None not in (
                p["p50_s"], p["p99_s"], p["p999_s"]
            ), (name, p)
        assert sweep["knee"]["detected"], (name, sweep["knee"])
        g = sweep["coordinated_omission_guard"]
        assert g["intended_ge_dequeue"], (name, g)
        assert g["strictly_greater_at_overload"], (name, g)
        assert sweep["overload"]["verdict"] == "graceful_degradation", (
            name, sweep["overload"],
        )
    assert serve["slo"]["met_sub_saturated"], serve["slo"]
    assert stream["slo"]["urgent_overload"]["met"], stream["slo"]
    assert stream["overload"]["urgent_survived"]
    assert stream["overload"]["never_dropped"]
    # frontend: graceful degradation at 3x the knee with exact
    # terminal accounting, typed rejections only, and zero URGENT
    # stream loss
    assert fo["verdict"] == "graceful_degradation", fo
    assert fo["accounting_exact"] and fo["typed_rejections_only"], fo
    assert fo["urgent_survived"], fo
    for p in frontend["points"]:
        assert p["submitted"] == p["completed"] + p["rejected"], p
        assert p["segments"]["urgent_not_enqueued"] == 0, p["segments"]
        assert p["segments"]["dropped"] == 0, p["segments"]
        if p["load_fraction"] <= 0.25:
            # burst-8 bucket at a quarter of the knee: shedding here
            # would mean the admission gate is mis-wired
            assert p["rejected"] == 0, p
        if p["load_fraction"] >= 3.0:
            assert p["rejected"] > 0, p  # the gate actually engages
    assert "socket_minus_inproc_p99_s" in to, to
    # every sampled request joins across >= 3 subsystem hops (>= 4 for
    # the frontend sample, which must also cross the transport)
    for name in ("serve", "stream"):
        assert lin[name]["requests"] > 0, lin[name]
        assert lin[name]["min_distinct_hops"] >= 3, lin[name]
    flin = lin["frontend"]
    assert flin["requests"] > 0, flin
    assert flin["min_distinct_hops"] >= 4, flin
    assert flin["requests_with_transport_hop"] == flin["requests"], flin
    t = rec["telemetry"]
    assert t["schema_version"] == obs.SCHEMA_VERSION and t["enabled"]
    print("[load_sweep] all assertions passed")


if __name__ == "__main__":
    main()
