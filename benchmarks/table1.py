"""Paper Table 1 reproduction: chip comparison row from the perf model.

The silicon numbers (latency / GOPS / power / power density) are derived
from the analytic chip model (`core.perf_model`) at the paper's operating
point and printed next to the paper's measured row and the prior works.
"""

from __future__ import annotations

import time

from repro.core import perf_model, vadetect


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    meta = vadetect.layer_shapes(vadetect.VAConfig())
    wls = [
        perf_model.LayerWorkload(
            name=m["name"], c_in=m["c_in"], c_out=m["c_out"],
            ksize=m["ksize"], t_out=m["t_out"], macs=m["macs"],
            bits=m["bits"], keep_frac=m["keep_frac"], sparse=m["sparse"],
        )
        for m in meta
    ]
    r = perf_model.chip_report(wls)
    dt_us = (time.perf_counter() - t0) * 1e6
    s = r.summary()
    paper = perf_model.PAPER_MEASURED

    rows = [
        ("table1.latency_us", dt_us,
         f"model={s['latency_us']:.2f} paper={paper['latency_us']}"),
        ("table1.effective_GOPS", dt_us,
         f"model={s['effective_GOPS']:.1f} paper={paper['effective_GOPS']}"),
        ("table1.avg_power_uW", dt_us,
         f"model={s['avg_power_uW']:.2f} paper={paper['avg_power_uW']}"),
        ("table1.power_density_uW_mm2", dt_us,
         f"model={s['power_density_uW_mm2']:.3f} "
         f"paper={paper['power_density_uW_mm2']}"),
    ]
    best_prior = min(
        v["density"] for v in perf_model.PRIOR_WORKS.values()
        if v["density"] is not None
    )
    rows.append((
        "table1.density_improvement_x", dt_us,
        f"model={best_prior / s['power_density_uW_mm2']:.2f} paper=14.23",
    ))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
