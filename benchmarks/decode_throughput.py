"""Sharded LM decode throughput: tokens/s vs device count, with
per-device decode-cache memory accounted from the sharded avals.

Two throughput views per (arch, mesh) cell, mirroring how
BENCH_stream.json pairs wall numbers with the modeled chip fleet:

  * wall — what this host actually sustains through the jitted sharded
    decode loop. Forced host "devices" share the container's few CPU
    cores, so wall numbers need not scale with device count;
  * modeled device fleet — decode is memory-bound: each batched step
    streams every placed parameter byte plus the pool's decode cache
    through one device's memory system. Per-device step time is
    (param + cache bytes per device) / HBM bandwidth, both accounted
    exactly from the sharded avals (`serve.sharded.DecodePlan`), so
    tokens/s scales with devices precisely as the placement shrinks the
    per-device byte footprint — the deployment quantity, and the
    memory/bandwidth plan the paper's fixed-power datapath story maps
    onto.

A third section measures *admission*: the engine seats requests by
batched prefill + per-slot cache scatter (`serve.seating`), so the
work per admitted request is O(prompt) — independent of the pool size.
`admission_work` counts the (row x token) units the engine's prefill
cells actually processed (`Engine.admission_rowsteps`) at two pool
sizes and asserts they are identical; the counterfactual replay cost
(the PR 3 path: every prompt token stepped through the whole pool,
prompt x pool per request) is recorded alongside for the ratio.

`--smoke` runs the acceptance cells (2 arch families x {1, 8-data,
4x2-data-model} meshes on 8 forced host devices) and asserts: sharded
per-device cache bytes < the replicated baseline, modeled tokens/s
scaling with device count, valid (guard-checked) placements, and
pool-size-independent admission cost.

Telemetry: the emitted record carries a `telemetry` section in the
shared `repro.obs.telemetry_section` schema — {schema_version, enabled,
counters, gauges, histograms (count/sum/min/max/mean/p50/p90/p99/p999
per name, e.g. `serve.ttft_s`, `serve.inter_token_s`), recompiles (per
compiled cell, including per-admission-width `serve.prefill.w*`),
peak_device_memory_bytes} — identical across BENCH_stream/BENCH_decode/
BENCH_dist. The admission engines' registry counters are asserted to
mirror the engines' own `admission_rowsteps`/`admission_prefills`.

    PYTHONPATH=src python benchmarks/decode_throughput.py [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.analysis import audit_section
from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH

# Nominal HBM bandwidth of one modeled serving device (TPU-class twin).
# Only ratios across meshes matter for the scaling claim; the absolute
# tokens/s is a roofline, not a measurement.
HBM_BW_BYTES_PER_S = 819e9

ARCHS = ("qwen3_8b", "recurrentgemma_2b")  # attention KV + recurrent cache


def modeled_tokens_per_s(plan: SH.DecodePlan) -> float:
    """Memory-bound decode roofline: one pool step streams the placed
    params + cache once per device; the whole pool advances one token."""
    step_bytes = plan.param_bytes_per_device + plan.cache_bytes_per_device
    return plan.batch / (step_bytes / HBM_BW_BYTES_PER_S)


def run_cell(
    model,
    params,
    mesh_spec: str,
    *,
    batch: int,
    prompt_len: int,
    max_new: int,
    seed: int = 0,
) -> dict:
    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    mesh = make_serving_mesh(mesh_spec)
    plan = SH.plan_decode(model, params, mesh, batch_size=batch)
    prefill, decode = SH.compile_decode(model, plan)
    placed = SH.place_params(params, plan)
    prompts = jax.device_put(
        jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab),
        plan.prompts,
    )

    # warmup: compile both cells outside the timed region
    logits, cache = prefill(placed, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jax.device_put(
        jnp.full((batch,), prompt_len, jnp.int32), plan.token
    )
    logits, cache = decode(placed, cache, tok, pos)
    logits.block_until_ready()

    # timed: one prefill + max_new decode steps (greedy)
    t0 = time.monotonic()
    logits, cache = prefill(placed, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(max_new):
        pos = jax.device_put(
            jnp.full((batch,), prompt_len + t, jnp.int32), plan.token
        )
        logits, cache = decode(placed, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok.block_until_ready()
    dt = time.monotonic() - t0

    return {
        "arch": cfg.name,
        "mesh": mesh_spec,
        "devices": plan.n_devices,
        "n_data": plan.n_data,
        "batch": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "wall_s": dt,
        "wall_tokens_per_s": batch * max_new / dt,
        "modeled_tokens_per_s": modeled_tokens_per_s(plan),
        "param_bytes_per_device": plan.param_bytes_per_device,
        "cache_bytes_per_device": plan.cache_bytes_per_device,
        "cache_bytes_replicated_baseline": plan.cache_bytes_total,
        "cache_replication_factor": plan.cache_replication_factor,
    }


def _admission_cell(model, *, pool: int, n_requests: int,
                    prompt_len: int, mesh_spec=None, params=None) -> dict:
    """Admit `n_requests` into a `pool`-slot engine and report the
    measured admission work (row x token units through prefill cells)."""
    cfg = model.cfg
    if mesh_spec is None:
        eng = E.Engine(model, params, batch_size=pool)
    else:
        eng = SH.ShardedEngine(
            model, params, batch_size=pool,
            mesh=make_serving_mesh(mesh_spec),
        )
    reqs = [
        E.Request(
            uid=i,
            prompt=jax.random.randint(
                jax.random.PRNGKey(10 + i), (prompt_len,), 0, cfg.vocab
            ),
            max_new=3,
        )
        for i in range(n_requests)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=50)
    assert all(r.done for r in reqs)
    return {
        "arch": cfg.name,
        "mesh": mesh_spec or "1",
        "pool": pool,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "admission_rowsteps": eng.admission_rowsteps,
        "admission_prefills": eng.admission_prefills,
        "admission_work_per_request": eng.admission_rowsteps
        / n_requests,
        # what replay admission (PR 3) would have spent: every prompt
        # token stepped through the whole pool, per request
        "replay_rowsteps_counterfactual": n_requests * prompt_len * pool,
    }


def measure_admission(arch: str, *, prompt_len: int) -> list:
    """Admission-work cells at two pool sizes (plus the 8-device data
    mesh when available): `admission_rowsteps` must not change with the
    pool — seating is O(prompt), the replay counterfactual is
    O(prompt x pool)."""
    cfg = configs.reduced(arch)
    model = api.build_model(cfg, tp=1, max_seq=prompt_len + 6)
    params = model.init(jax.random.PRNGKey(0))
    cells = [
        _admission_cell(model, pool=pool, n_requests=4,
                        prompt_len=prompt_len, params=params)
        for pool in (4, 8)
    ]
    if jax.device_count() >= 8:
        cells += [
            _admission_cell(model, pool=pool, n_requests=4,
                            prompt_len=prompt_len, mesh_spec="8",
                            params=params)
            for pool in (8, 16)
        ]
    return cells


def _tree_bytes(avals) -> int:
    return sum(
        l.size * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(avals)
    )


def _drive_paged(eng, reqs, *, max_ticks: int = 600) -> tuple:
    """Submit `reqs`, drain, and report (peak concurrent tenants,
    peak resident cache bytes) observed across the ticks."""
    for r in reqs:
        eng.submit(r)
    peak = 0
    peak_bytes = 0
    for _ in range(max_ticks):
        n = eng.tick()
        peak = max(peak, sum(s is not None for s in eng._slots))
        peak_bytes = max(peak_bytes, eng.cache_bytes_in_use())
        if n == 0 and not eng._queue:
            break
    assert all(r.done for r in reqs), "paged workload did not drain"
    return peak, peak_bytes


def measure_paged(arch: str) -> dict:
    """The paged-cache tenancy cell: dense vs paged pool at (at most)
    the same cache byte budget, mixed-length workload.

    The dense pool gives every tenant a full `max_seq` cache row, so
    its concurrency is its slot count. The paged pool spends the same
    bytes on a shared page pool plus 4x the slots; short requests hold
    only the pages they wrote, so the same bytes host >= 2x the
    concurrent tenants (self-asserted). Long prompts run through
    chunked prefill, interleaving with the shorts' decode ticks.

    Two identical waves: wave 1 warms every cell (prefill widths, seat,
    chunk, decode), wave 2 must compile nothing (`recompiles_after_
    warmup == 0`) — and resident cache bytes must return to the initial
    value after each drain (pages freed, not leaked)."""
    from repro.serve.paging import PagingConfig, pages_for_position

    cfg = configs.reduced(arch)
    max_seq, page = 128, 4
    model = api.build_model(cfg, tp=1, max_seq=max_seq)
    params = model.init(jax.random.PRNGKey(0))
    dense_slots, paged_slots = 4, 16
    span = max(
        (c // page for c in model.attn_capacities()), default=0
    )
    dense_total = _tree_bytes(
        jax.eval_shape(lambda: model.init_cache(dense_slots))
    )
    # paged bytes are affine in n_pages: fit the byte budget exactly
    b2 = _tree_bytes(jax.eval_shape(
        lambda: model.init_cache_paged(paged_slots, 2, page)
    ))
    b3 = _tree_bytes(jax.eval_shape(
        lambda: model.init_cache_paged(paged_slots, 3, page)
    ))
    slope = b3 - b2
    n_pages = int((dense_total - (b2 - 2 * slope)) // slope)
    paged_total = b2 + (n_pages - 2) * slope
    assert paged_total <= dense_total, (paged_total, dense_total)

    short_len, long_len, max_new = 4, 40, 8
    def mkreqs(uid0):
        reqs = []
        for i in range(paged_slots - 2):
            reqs.append(E.Request(
                uid=uid0 + i,
                prompt=jax.random.randint(
                    jax.random.PRNGKey(uid0 + i), (short_len,), 0,
                    cfg.vocab,
                ),
                max_new=max_new,
            ))
        for i in range(2):
            reqs.append(E.Request(
                uid=uid0 + 100 + i,
                prompt=jax.random.randint(
                    jax.random.PRNGKey(uid0 + 100 + i), (long_len,), 0,
                    cfg.vocab,
                ),
                max_new=max_new,
            ))
        return reqs

    # the workload's worst-case page demand must fit, or admission
    # deferral would cap the concurrency this cell is measuring
    worst = (paged_slots - 2) * pages_for_position(
        short_len + max_new - 2, page, span
    ) + 2 * pages_for_position(long_len + max_new - 2, page, span)
    assert worst <= n_pages - 1, (worst, n_pages)

    dense = E.Engine(model, params, batch_size=dense_slots)
    paged = E.Engine(
        model, params, batch_size=paged_slots,
        paging=PagingConfig(page_size=page, n_pages=n_pages),
        chunk_tokens=2 * page,
    )
    initial_bytes = paged.cache_bytes_in_use()

    probe = obs.get().probe
    dense_peak, _ = _drive_paged(dense, mkreqs(0))
    paged_peak, peak_bytes = _drive_paged(paged, mkreqs(200))
    drain1_bytes = paged.cache_bytes_in_use()
    snap = probe.snapshot()
    dense_peak2, _ = _drive_paged(dense, mkreqs(400))
    paged_peak2, peak_bytes2 = _drive_paged(paged, mkreqs(600))
    misses = probe.new_misses(snap)
    paged._pg.check_invariants()

    return {
        "arch": cfg.name,
        "page_size": page,
        "n_pages": n_pages,
        "span": span,
        "max_seq": max_seq,
        "chunk_tokens": 2 * page,
        "dense_pool_slots": dense_slots,
        "paged_pool_slots": paged_slots,
        "dense_cache_bytes_total": dense_total,
        "paged_cache_bytes_total": paged_total,
        "dense_peak_concurrent": max(dense_peak, dense_peak2),
        "paged_peak_concurrent": max(paged_peak, paged_peak2),
        "concurrency_gain": max(paged_peak, paged_peak2)
        / max(dense_peak, dense_peak2),
        "bytes_in_use": {
            "initial": initial_bytes,
            "peak": max(peak_bytes, peak_bytes2),
            "post_drain": drain1_bytes,
            "post_drain_final": paged.cache_bytes_in_use(),
        },
        "recompiles_after_warmup": sum(misses.values()),
        "recompiles_after_warmup_by_cell": misses,
        # so main() can reconcile the registry's global admission
        # counters, which these two engines also feed
        "admission_rowsteps": dense.admission_rowsteps
        + paged.admission_rowsteps,
        "admission_prefills": dense.admission_prefills
        + paged.admission_prefills,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="acceptance cells only (CI: scripts/ci.sh)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write the telemetry trace to PREFIX.jsonl "
                         "(event log) + PREFIX.json (Chrome/Perfetto)")
    args = ap.parse_args()

    # before any engine compiles, so jit cells register with the probe
    obs.configure(enabled=True)

    mesh_specs = ["1", "8", "4x2"] if args.smoke else [
        "1", "2", "4", "8", "4x2"
    ]
    mesh_specs = [
        s for s in mesh_specs
        if (lambda dm: dm[0] * dm[1])(parse_mesh_spec(s))
        <= jax.device_count()
    ]

    cells = []
    for arch in ARCHS:
        # model/params are mesh-independent: build once per arch
        cfg = configs.reduced(arch)
        model = api.build_model(
            cfg, tp=1, max_seq=args.prompt_len + args.max_new + 2
        )
        params = model.init(jax.random.PRNGKey(0))
        for spec in mesh_specs:
            cell = run_cell(
                model, params, spec,
                batch=args.batch,
                prompt_len=args.prompt_len,
                max_new=args.max_new,
            )
            cells.append(cell)
            print(
                f"[decode_throughput] {cell['arch']:24s} mesh={spec:4s} "
                f"wall={cell['wall_tokens_per_s']:8.0f} tok/s "
                f"modeled={cell['modeled_tokens_per_s'] / 1e6:8.2f} Mtok/s "
                f"cache/dev={cell['cache_bytes_per_device']:8d} B "
                f"(repl {cell['cache_bytes_replicated_baseline']:8d} B)",
                flush=True,
            )

    # device-count scaling per arch (modeled fleet: the deployment
    # quantity; forced host devices share the CPU, so wall numbers are
    # reported but not the scaling claim — same policy as BENCH_stream)
    scaling = []
    for arch in ARCHS:
        ac = [c for c in cells if c["arch"] == configs.reduced(arch).name]
        lo = min(ac, key=lambda c: c["devices"])
        hi = max(ac, key=lambda c: c["devices"])
        scaling.append({
            "arch": lo["arch"],
            "devices_lo": lo["devices"],
            "devices_hi": hi["devices"],
            "modeled_tokens_per_s_lo": lo["modeled_tokens_per_s"],
            "modeled_tokens_per_s_hi": hi["modeled_tokens_per_s"],
            "modeled_speedup": hi["modeled_tokens_per_s"]
            / lo["modeled_tokens_per_s"],
            "cache_bytes_per_device_lo": lo["cache_bytes_per_device"],
            "cache_bytes_per_device_hi": hi["cache_bytes_per_device"],
            "wall_tokens_per_s_lo": lo["wall_tokens_per_s"],
            "wall_tokens_per_s_hi": hi["wall_tokens_per_s"],
        })

    admission = measure_admission(ARCHS[0], prompt_len=args.prompt_len)
    paged = measure_paged(ARCHS[0])

    # static cell audit over everything the sweep registered:
    # serve.decode_step / prefill / seat / chunk cells (base + sharded
    # variants), re-lowered from captured avals (repro.analysis)
    cell_audit = audit_section()

    telemetry = obs.telemetry_section()
    rec = {
        "benchmark": "decode_throughput",
        "n_host_devices": jax.device_count(),
        "hbm_bw_bytes_per_s": HBM_BW_BYTES_PER_S,
        "reduced_configs": True,
        "cells": cells,
        "scaling": scaling,
        "admission": admission,
        "paged": paged,
        "telemetry": telemetry,
        "cell_audit": cell_audit,
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[decode_throughput] -> {args.out}")
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")

    # acceptance: every multi-device placement keeps per-device cache
    # bytes strictly below the replicated baseline, and the modeled
    # memory-bound tokens/s scales with device count for every arch
    for c in cells:
        if c["devices"] > 1:
            assert (
                c["cache_bytes_per_device"]
                < c["cache_bytes_replicated_baseline"]
            ), c
    for s in scaling:
        if s["devices_hi"] >= 8 * s["devices_lo"]:
            assert s["modeled_speedup"] > 4.0, s
        print(
            f"[decode_throughput] {s['arch']}: modeled "
            f"{s['modeled_speedup']:.1f}x at {s['devices_hi']} devices "
            f"(cache/dev {s['cache_bytes_per_device_lo']} -> "
            f"{s['cache_bytes_per_device_hi']} B)"
        )
    # admission is O(prompt): measured work identical across pool sizes
    # (per mesh), and strictly below the replay counterfactual at the
    # larger pools
    by_mesh: dict = {}
    for c in admission:
        by_mesh.setdefault(c["mesh"], []).append(c)
        print(
            f"[decode_throughput] admission {c['arch']} mesh={c['mesh']} "
            f"pool={c['pool']:3d}: {c['admission_rowsteps']} rowsteps "
            f"({c['admission_work_per_request']:.0f}/req; replay would "
            f"be {c['replay_rowsteps_counterfactual']})"
        )
    for mesh_cells in by_mesh.values():
        works = {c["admission_rowsteps"] for c in mesh_cells}
        assert len(works) == 1, (
            f"admission work varies with pool size: {mesh_cells}"
        )
        big = max(mesh_cells, key=lambda c: c["pool"])
        assert (
            big["admission_rowsteps"]
            < big["replay_rowsteps_counterfactual"]
        ), big
    # paged tenancy gates: >= 2x concurrent tenants at a cache byte
    # budget no larger than the dense pool's, resident bytes fully
    # reclaimed after every drain, and nothing recompiled after the
    # warmup wave
    p = paged
    print(
        f"[decode_throughput] paged {p['arch']}: "
        f"{p['paged_peak_concurrent']} vs {p['dense_peak_concurrent']} "
        f"concurrent ({p['concurrency_gain']:.1f}x) at "
        f"{p['paged_cache_bytes_total']} <= "
        f"{p['dense_cache_bytes_total']} cache bytes; bytes in use "
        f"{p['bytes_in_use']['initial']} -> peak "
        f"{p['bytes_in_use']['peak']} -> drained "
        f"{p['bytes_in_use']['post_drain_final']}; "
        f"{p['recompiles_after_warmup']} recompiles after warmup"
    )
    assert p["concurrency_gain"] >= 2.0, p
    assert p["paged_cache_bytes_total"] <= p["dense_cache_bytes_total"], p
    assert p["bytes_in_use"]["post_drain"] == p["bytes_in_use"]["initial"], p
    assert (
        p["bytes_in_use"]["post_drain_final"]
        == p["bytes_in_use"]["initial"]
    ), p
    assert p["bytes_in_use"]["peak"] > p["bytes_in_use"]["initial"], p
    assert p["recompiles_after_warmup"] == 0, p

    # telemetry gates: the registry's admission counters mirror the
    # engines' own accounting exactly (summed over every admission
    # cell in this process), the per-request latency histograms are
    # populated with percentiles, and every compiled admission width
    # shows up in the recompile map
    t = telemetry
    assert t["schema_version"] == obs.SCHEMA_VERSION and t["enabled"]
    assert t["counters"]["serve.admission_rowsteps"] == sum(
        c["admission_rowsteps"] for c in admission
    ) + paged["admission_rowsteps"], t["counters"]
    assert t["counters"]["serve.admission_prefills"] == sum(
        c["admission_prefills"] for c in admission
    ) + paged["admission_prefills"], t["counters"]
    for name in ("serve.ttft_s", "serve.inter_token_s"):
        h = t["histograms"][name]
        assert h["count"] > 0 and None not in (
            h["p50"], h["p99"], h["p999"]
        ), (name, h)
    assert "serve.decode_step" in t["recompiles"], t["recompiles"]
    assert any(
        k.startswith("serve.prefill.w") for k in t["recompiles"]
    ), t["recompiles"]
    assert t["peak_device_memory_bytes"] > 0, t

    # cell audit gates: every registered serve cell was exercised by
    # the sweep (avals captured) and re-lowers with zero violations —
    # no host transfers, no f64, donations honored, collectives within
    # the sharded cells' declared budgets
    assert cell_audit["n_cells"] > 0
    assert cell_audit["violations_total"] == 0, cell_audit
    assert "serve.decode_step" in cell_audit["cells"], (
        cell_audit["cells"].keys()
    )
    assert any(
        k.startswith("serve.prefill") for k in cell_audit["cells"]
    ), cell_audit["cells"].keys()
    assert any(
        k.startswith("serve.seat") for k in cell_audit["cells"]
    ), cell_audit["cells"].keys()
    print(
        f"[decode_throughput] cell audit: {cell_audit['n_cells']} "
        f"cells, 0 violations"
    )


if __name__ == "__main__":
    main()
