"""Compressed vs uncompressed cross-pod gradient reduction.

Measures, on the *trainer's actual gradient tree* (a reduced LM config's
parameter tree), the two psum-mean paths from `repro.dist.compression`
over a forced multi-device host "pod" axis:

  * bytes-on-wire — two views: collective bytes parsed from the
    optimized HLO with the loop-aware analyzer
    (`launch.hlo_count.weighted_cost`, the dry-run's accounting), and
    the modeled per-device ring egress (2*(n-1)/n*4B for f32
    all-reduce vs (n-1)*(1B+scale) for the int8 all-gather) — the
    egress ratio is (8/n)x, a genuine 4x at the production 2-pod mesh
    and break-even at n=8 (see `dist.compression`'s docstring);
  * wall-clock    — per-call time of the jitted shard_map program
    (host-CPU collectives: a structural sanity check, not DCN numbers).

Emits BENCH_dist.json. Device count comes from
XLA_FLAGS=--xla_force_host_platform_device_count (forced to 8 here
unless already set; must precede any jax import).

    PYTHONPATH=src python benchmarks/dist_compression.py
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.hlo_count import weighted_cost
from repro.models import api
from repro.dist import compression as C


def grad_tree(arch: str):
    """The trainer's gradient pytree: one real value_and_grad of the
    reduced config's loss (grads mirror the f32 param tree)."""
    cfg = configs.reduced(arch)
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
        "targets": jax.random.randint(
            jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
    }
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (4, cfg.enc_seq, cfg.d_model),
            jnp.float32,
        )
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    return cfg, grads


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def modeled_egress(grads, n: int) -> dict:
    """Per-device ring-collective egress bytes for one reduction of
    the tree: f32 all-reduce vs int8(+f32 scale) full-leaf all-gather."""
    sizes = [x.size for x in jax.tree.leaves(grads)]
    unc = sum(2 * (n - 1) / n * 4 * s for s in sizes)
    comp = sum((n - 1) * (s + 4) for s in sizes)
    return {
        "uncompressed_bytes": unc,
        "compressed_bytes": comp,
        "ratio_uncompressed_over_compressed": unc / comp,
    }


def _time_call(fn, *args, reps: int = 10) -> float:
    jax.block_until_ready(fn(*args))  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(arch: str, out_path: str) -> dict:
    n = jax.device_count()
    mesh = jax.make_mesh(
        (n,), ("pod",),
        axis_types=(jax.sharding.AxisType.Auto,),
    )
    cfg, grads = grad_tree(arch)
    err = jax.tree.map(jnp.zeros_like, grads)
    rep = jax.tree.map(lambda _: P(), grads)

    comp = jax.jit(shard_map(
        lambda g, e: C.compressed_psum_mean(g, e, "pod"),
        mesh=mesh, in_specs=(rep, rep), out_specs=(rep, rep),
        check_rep=False,
    ))
    unc = jax.jit(shard_map(
        lambda g: C.uncompressed_psum_mean(g, "pod"),
        mesh=mesh, in_specs=(rep,), out_specs=rep, check_rep=False,
    ))

    wc_comp = weighted_cost(
        comp.lower(grads, err).compile().as_text()
    )
    wc_unc = weighted_cost(unc.lower(grads).compile().as_text())

    rec = {
        "arch": cfg.name,
        "n_devices": n,
        "grad_leaves": len(jax.tree.leaves(grads)),
        "grad_bytes": _nbytes(grads),
        "modeled_ring_egress_per_device": modeled_egress(grads, n),
        "compressed": {
            "collective_bytes": wc_comp.collective_bytes,
            "collective_by_op": wc_comp.collective_by_op,
            "wall_s_per_call": _time_call(comp, grads, err),
        },
        "uncompressed": {
            "collective_bytes": wc_unc.collective_bytes,
            "collective_by_op": wc_unc.collective_by_op,
            "wall_s_per_call": _time_call(unc, grads),
        },
    }
    if wc_comp.collective_bytes:
        rec["wire_ratio_uncompressed_over_compressed"] = (
            wc_unc.collective_bytes / wc_comp.collective_bytes
        )
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    eg = rec["modeled_ring_egress_per_device"]
    print(
        f"[dist_compression] {cfg.name} n_dev={n} "
        f"grads={rec['grad_bytes']/2**20:.2f}MiB  hlo-wire: "
        f"uncompressed={wc_unc.collective_bytes/2**20:.2f}MiB "
        f"compressed={wc_comp.collective_bytes/2**20:.2f}MiB "
        f"({rec.get('wire_ratio_uncompressed_over_compressed', 0):.2f}x)"
    )
    print(
        f"[dist_compression] modeled ring egress/device: "
        f"uncompressed={eg['uncompressed_bytes']/2**20:.2f}MiB "
        f"compressed={eg['compressed_bytes']/2**20:.2f}MiB "
        f"({eg['ratio_uncompressed_over_compressed']:.2f}x at n={n}; "
        f"8/n scaling -> 4x at the 2-pod production mesh)"
    )
    print(
        f"[dist_compression] wall/call: "
        f"uncompressed={rec['uncompressed']['wall_s_per_call']*1e3:.2f}ms "
        f"compressed={rec['compressed']['wall_s_per_call']*1e3:.2f}ms "
        f"-> {out_path}"
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--out", default="BENCH_dist.json")
    args = ap.parse_args()
    run(args.arch, args.out)


if __name__ == "__main__":
    main()
