"""Cross-pod gradient reduction: scheme x pod-count sweep + convergence.

Measures, on the *trainer's actual gradient tree* (a reduced LM config's
parameter tree), the three cross-pod reduction paths from
`repro.dist.compression` over forced multi-device host "pod" meshes:

  * sweep (n_pods in {2, 4, 8} x {gather, two_stage, uncompressed}) —
    two views of bytes-on-wire: collective bytes parsed from the
    optimized HLO with the loop-aware analyzer
    (`launch.hlo_count.weighted_cost`, the dry-run's accounting), and
    the modeled per-device ring egress:
      - f32 ring all-reduce:  2*(n-1)/n * 4B * |leaf|
      - int8 full-leaf gather: (n-1) * (|leaf| + 4B)      -> (8/n)x
      - int8 two-stage (reduce-scatter + all-gather):
        2*(n-1)/n * |leaf_padded| + 8B*(n-1)              -> ~4x, any n
    plus wall-clock per jitted call (host-CPU collectives: structural
    sanity, not DCN numbers).
  * convergence — short compressed-DP training runs of the reduced
    config (`trainer.make_dp_step_compressed` over the full forced pod
    mesh) per scheme, recording the loss curve: the wire-ratio vs
    loss-curve tradeoff in one table.

Asserted here (and therefore in `scripts/ci.sh`, which runs this):
  * two-stage egress ratio vs f32 is ~4x AND pod-count-independent
    (spread < 10% across n = 2/4/8);
  * gather decays like 8/n (>3.5x at n=2, <1.3x at n=8);
  * every scheme's loss curve decreases, compressed finals within
    tolerance of the f32 baseline.

Emits BENCH_dist.json, including a `telemetry` section in the shared
`repro.obs.telemetry_section` schema — {schema_version, enabled,
counters, gauges, histograms (count/sum/min/max/mean/p50/p90/p99/p999
per name, e.g. `train.step_latency_s`), recompiles (per compiled cell:
the per-scheme reduction jits and convergence train steps),
peak_device_memory_bytes} — identical across BENCH_stream/BENCH_decode/
BENCH_dist. Device count comes from
XLA_FLAGS=--xla_force_host_platform_device_count (forced to 8 here
unless already set; must precede any jax import).

    PYTHONPATH=src python benchmarks/dist_compression.py [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import configs, obs, optim
from repro.analysis import audit_section
from repro.data import lm
from repro.launch.hlo_count import weighted_cost
from repro.models import api
from repro.dist import compression as C
from repro.train import trainer

SCHEMES = ("uncompressed", "gather", "two_stage")


def grad_tree(arch: str):
    """The trainer's gradient pytree: one real value_and_grad of the
    reduced config's loss (grads mirror the f32 param tree)."""
    cfg = configs.reduced(arch)
    model = api.build_model(cfg, tp=1, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
        "targets": jax.random.randint(
            jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
    }
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (4, cfg.enc_seq, cfg.d_model),
            jnp.float32,
        )
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    return cfg, grads


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def modeled_egress(grads, n: int) -> dict:
    """Per-device ring-collective egress bytes for one reduction of the
    tree under each scheme (docstring formulas)."""
    sizes = [x.size for x in jax.tree.leaves(grads)]
    pad = lambda s: -(-s // n) * n  # noqa: E731
    unc = sum(2 * (n - 1) / n * 4 * s for s in sizes)
    gather = sum((n - 1) * (s + 4) for s in sizes)
    two = sum(2 * (n - 1) / n * pad(s) + 8 * (n - 1) for s in sizes)
    return {
        "uncompressed": unc,
        "gather": gather,
        "two_stage": two,
        "ratio_gather": unc / gather,
        "ratio_two_stage": unc / two,
    }


def _pod_mesh(n: int):
    return jax.make_mesh(
        (n,), ("pod",), devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,),
    )


def _reduction_fn(scheme: str, mesh, grads):
    """Jitted shard_map of one reduction call; returns (fn, args)."""
    rep = jax.tree.map(lambda _: P(), grads)
    if scheme == "uncompressed":
        fn = jax.jit(shard_map(
            lambda g: C.uncompressed_psum_mean(g, "pod"),
            mesh=mesh, in_specs=(rep,), out_specs=rep, check_rep=False,
        ))
        return fn, (grads,)
    if scheme == "gather":
        err = jax.tree.map(jnp.zeros_like, grads)
        fn = jax.jit(shard_map(
            lambda g, e: C.compressed_psum_mean(g, e, "pod"),
            mesh=mesh, in_specs=(rep, rep), out_specs=(rep, rep),
            check_rep=False,
        ))
        return fn, (grads, err)
    if scheme == "two_stage":
        n = mesh.shape["pod"]
        err1 = jax.tree.map(jnp.zeros_like, grads)
        err2 = jax.tree.map(
            lambda g: jnp.zeros(C.two_stage_shard_len(g.size, n)), grads
        )
        fn = jax.jit(shard_map(
            lambda g, a, b: C.two_stage_psum_mean(g, a, b, "pod"),
            mesh=mesh, in_specs=(rep, rep, rep),
            out_specs=(rep, rep, rep), check_rep=False,
        ))
        return fn, (grads, err1, err2)
    raise ValueError(scheme)


def _time_call(fn, *args, reps: int = 10) -> float:
    jax.block_until_ready(fn(*args))  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def sweep(grads, pod_counts) -> list[dict]:
    cells = []
    for n in pod_counts:
        mesh = _pod_mesh(n)
        eg = modeled_egress(grads, n)
        for scheme in SCHEMES:
            fn, args = _reduction_fn(scheme, mesh, grads)
            fn = obs.get().probe.track(
                f"dist.reduce.{scheme}.n{n}", fn
            )
            wc = weighted_cost(fn.lower(*args).compile().as_text())
            cells.append({
                "n_pods": n,
                "scheme": scheme,
                "modeled_egress_bytes_per_device": eg[scheme],
                "modeled_ratio_vs_f32":
                    eg["uncompressed"] / eg[scheme],
                "hlo_collective_bytes": wc.collective_bytes,
                "hlo_collective_by_op": wc.collective_by_op,
                "wall_s_per_call": _time_call(fn, *args),
            })
            print(
                f"[dist_compression] n={n} {scheme:>12}: "
                f"egress/device={eg[scheme]/2**20:7.2f}MiB "
                f"({eg['uncompressed']/eg[scheme]:4.2f}x vs f32)  "
                f"hlo={wc.collective_bytes/2**20:7.2f}MiB  "
                f"wall={cells[-1]['wall_s_per_call']*1e3:6.2f}ms"
            )
    return cells


def convergence(arch: str, steps: int) -> dict:
    """Wire-ratio vs loss-curve: train the reduced config with each
    reduction scheme over the full forced pod mesh."""
    n = jax.device_count()
    mesh = _pod_mesh(n)
    cfg = configs.reduced(arch)
    model = api.build_model(cfg, tp=1, max_seq=32)
    curves = {}
    for mode in ("f32", "gather", "two_stage"):
        compress = mode != "f32"
        scheme = mode if compress else "gather"
        params = model.init(jax.random.PRNGKey(0))
        opt = optim.adamw(3e-3)
        state = trainer.init_state(params, opt)
        state["err"] = trainer.init_dp_err(
            params, mesh, scheme=scheme, compress=compress
        )
        # Seat the initial state on the pod mesh with the step's output
        # sharding (replicated): otherwise the first call traces for
        # uncommitted single-device inputs and the second call retraces
        # for NamedSharding outputs — a silent 2x compile the recompile
        # telemetry (and the check() gate below) would flag.
        repl = jax.sharding.NamedSharding(mesh, P())
        for k in ("params", "opt", "step"):
            state[k] = jax.device_put(state[k], repl)
        step = obs.get().probe.track(
            f"train.dp_step.{mode}",
            jax.jit(trainer.make_dp_step_compressed(
                model.loss, opt, mesh, scheme=scheme, compress=compress
            )),
        )
        stream = lm.TokenStream(
            batch=8, seq_len=16, vocab=cfg.vocab, seed=0
        )
        tel = obs.get()
        step_hist = tel.registry.histogram("train.step_latency_s")
        losses = []
        for i in range(steps):
            t0 = time.perf_counter()
            with tel.span("train/step", cat="train", mode=mode, step=i):
                state, m = step(state, stream.batch_at(i))
                losses.append(round(float(m["loss"]), 6))
            step_hist.observe(time.perf_counter() - t0)
        curves[mode] = losses
        print(
            f"[dist_compression] convergence {mode:>9}: "
            f"loss {losses[0]:.4f} -> {losses[-1]:.4f} ({steps} steps, "
            f"n_pods={n})"
        )
    return {
        "arch": cfg.name, "n_pods": n, "steps": steps, "batch": 8,
        "curves": curves,
        "final": {k: v[-1] for k, v in curves.items()},
    }


def check(rec: dict) -> None:
    """The acceptance gates `scripts/ci.sh` relies on."""
    two = {c["n_pods"]: c["modeled_ratio_vs_f32"]
           for c in rec["sweep"] if c["scheme"] == "two_stage"}
    gather = {c["n_pods"]: c["modeled_ratio_vs_f32"]
              for c in rec["sweep"] if c["scheme"] == "gather"}
    # two-stage: ~4x below f32, independent of pod count
    for n, r in two.items():
        assert 3.5 < r < 4.3, ("two_stage ratio", n, r)
    spread = (max(two.values()) - min(two.values())) / min(two.values())
    assert spread < 0.10, ("two_stage not n-independent", two)
    # gather: (8/n)x decay — wins at n=2, dead by n=8
    assert gather[min(gather)] > 3.5, gather
    if 8 in gather:
        assert gather[8] < 1.3, gather
    # compressed wire really is smaller where XLA can show it: at every
    # n the HLO collective bytes of both int8 schemes undercut f32
    by_key = {(c["n_pods"], c["scheme"]): c for c in rec["sweep"]}
    for (n, scheme), c in by_key.items():
        if scheme == "uncompressed":
            continue
        unc = by_key[(n, "uncompressed")]["hlo_collective_bytes"]
        if unc and c["hlo_collective_bytes"]:
            assert c["hlo_collective_bytes"] < unc, (n, scheme)
    # convergence: every curve trains; compression stays near baseline
    cv = rec["convergence"]["curves"]
    for mode, losses in cv.items():
        assert losses[-1] < losses[0] - 0.05, (mode, losses[0],
                                               losses[-1])
    f32_final = cv["f32"][-1]
    drop = cv["f32"][0] - f32_final
    for mode in ("gather", "two_stage"):
        assert abs(cv[mode][-1] - f32_final) < max(0.25 * drop, 0.05), (
            mode, cv[mode][-1], f32_final
        )
    # telemetry gates: step-latency percentiles present for the
    # convergence runs, every per-scheme jitted cell in the recompile
    # map with exactly the expected compiled-variant count (one shape
    # each — any retrace after warmup would show here)
    t = rec["telemetry"]
    assert t["schema_version"] == obs.SCHEMA_VERSION and t["enabled"]
    h = t["histograms"]["train.step_latency_s"]
    assert h["count"] > 0 and None not in (
        h["p50"], h["p99"], h["p999"]
    ), h
    for mode in ("f32", "gather", "two_stage"):
        assert t["recompiles"].get(f"train.dp_step.{mode}") == 1, (
            mode, t["recompiles"]
        )
    assert t["peak_device_memory_bytes"] > 0, t
    # static cell audit: every registered jit cell re-lowered clean —
    # avals captured, no host callbacks/transfers, no f64, donations
    # honored, collectives within any declared budget
    ca = rec["cell_audit"]
    assert ca["n_cells"] > 0
    assert ca["violations_total"] == 0, ca
    assert any(k.startswith("dist.reduce.") for k in ca["cells"]), ca
    for mode in ("f32", "gather", "two_stage"):
        assert f"train.dp_step.{mode}" in ca["cells"], ca["cells"].keys()


def run(arch: str, out_path: str, *, steps: int,
        trace_out: str | None = None) -> dict:
    n_dev = jax.device_count()
    pod_counts = [n for n in (2, 4, 8) if n <= n_dev]
    if not pod_counts:
        raise SystemExit(
            f"dist_compression needs >= 2 devices for the scheme sweep "
            f"but jax sees {n_dev}; a pre-set XLA_FLAGS without "
            f"--xla_force_host_platform_device_count=8 overrides the "
            f"default this script would apply"
        )
    # before the reduction/step jits compile, so they register with
    # the probe
    obs.configure(enabled=True)
    cfg, grads = grad_tree(arch)
    rec = {
        "benchmark": "dist_compression",
        "arch": cfg.name,
        "n_devices": n_dev,
        "grad_leaves": len(jax.tree.leaves(grads)),
        "grad_bytes": _nbytes(grads),
        "sweep": sweep(grads, pod_counts),
        "convergence": convergence(arch, steps),
        "telemetry": obs.telemetry_section(),
        # every reduction / dp-step jit cell the sweep registered,
        # re-lowered and statically audited (repro.analysis)
        "cell_audit": audit_section(),
    }
    check(rec)
    rec["checked"] = True
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dist_compression] all gates passed -> {out_path}")
    if trace_out:
        jsonl, chrome = obs.get().finish(trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--out", default="BENCH_dist.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer convergence steps (CI)")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write the telemetry trace to PREFIX.jsonl "
                         "(event log) + PREFIX.json (Chrome/Perfetto)")
    args = ap.parse_args()
    run(args.arch, args.out, steps=24 if args.smoke else 60,
        trace_out=args.trace_out)


if __name__ == "__main__":
    main()
