"""Fleet streaming throughput: patients sustained at 250 Hz vs batch
bucket size vs device count.

Two throughput views per (bucket, devices) cell, mirroring how
BENCH_dist.json pairs HLO-accounted bytes with modeled ring egress:

  * wall — what this host actually sustains through the full loop
    (schedule → pack → sharded jitted inference → vectorized vote).
    Host CPUs have few cores, so forced host "devices" share them and
    wall numbers need not scale with device count;
  * modeled chip fleet — each mesh device is one accelerator chip twin
    running its shard of every bucket serially at the perf model's
    per-segment latency (35 µs at the paper's operating point). This is
    the deployment quantity — N chips monitor N disjoint fleet slices —
    and it scales exactly linearly: 8 devices = 8x one device.

`--smoke` runs the acceptance configuration: a 1000-patient fleet that
must sustain real-time rate (one 512-sample segment per patient per
2.048 s => ~488 seg/s aggregate) with zero scheduler drops, plus a
reduced sweep, and asserts both criteria. CI runs it on 8 forced host
devices (scripts/ci.sh).

Telemetry: the emitted record carries a `telemetry` section in the
shared `repro.obs.telemetry_section` schema — {schema_version, enabled,
counters, gauges, histograms (count/sum/min/max/mean/p50/p90/p99/p999
per name, e.g. `stream.flush_wall_s`), recompiles (per compiled cell),
peak_device_memory_bytes} — identical across BENCH_stream/BENCH_decode/
BENCH_dist, plus an `overhead` sub-record: enabled-vs-disabled wall
clock of the same fleet config on one shared runner, asserted < 3%.

    PYTHONPATH=src python benchmarks/stream_throughput.py [--smoke]
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

import argparse
import gc
import json
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.analysis import audit_section
from repro.core import compiler, vadetect
from repro.launch.stream import make_data_mesh
from repro.stream import (
    SEGMENT_PERIOD_S,
    FleetConfig,
    FleetRunner,
    simulate,
)


def _verify_batch_sharding(runner, bucket: int, devices: int) -> bool:
    """The modeled chip-fleet rate is N/latency *by definition*; what
    must be verified is the mechanism behind it — that the runner really
    splits a bucket bucket/N per device over the data axis (otherwise
    'N chip twins over disjoint fleet slices' is fiction)."""
    if devices <= 1:
        return True
    x = jax.device_put(
        jnp.zeros((bucket, vadetect.RECORD_LEN)), runner._in_sharding
    )
    shard_rows = {s.data.shape[0] for s in x.addressable_shards}
    return (
        len(x.sharding.device_set) == devices
        and shard_rows == {bucket // devices}
    )


def run_cell(
    program,
    *,
    patients: int,
    segments: int,
    bucket: int,
    devices: int,
    seed: int = 0,
) -> dict:
    """One (bucket, devices) cell: fleet sim with a single-bucket ladder
    (plus a small partial-batch bucket so drains stay fixed-shape)."""
    mesh = make_data_mesh(devices)
    runner = FleetRunner(program, path="twin", mesh=mesh)
    shard_ok = _verify_batch_sharding(runner, bucket, devices)
    small = max(8, bucket // 16)
    buckets = (small, bucket) if small < bucket else (bucket,)
    cfg = FleetConfig(
        n_patients=patients,
        segments_per_patient=segments,
        seed=seed,
        va_fraction=0.05,
        jitter_frac=0.02,
        buckets=buckets,
        path="twin",
    )
    out = simulate(cfg, runner=runner)
    m = out["metrics"]
    return {
        "bucket": bucket,
        "devices": devices,
        "batch_sharded_over_devices": shard_ok,
        "patients": patients,
        "segments_total": m["segments_total"],
        "dropped_total": m["dropped_total"],
        "pad_fraction": m["pad_fraction"],
        "jit_cache_misses": out["jit_cache_misses"],
        "wall_segments_per_s": m["segments_per_s_wall"],
        "modeled_chip_segments_per_s": out["chip"][
            "modeled_fleet_segments_per_s"
        ],
        "deadline_slack_s": m.get("deadline_slack_s"),
        "patients_sustained_at_250hz_wall": int(
            m["segments_per_s_wall"] * SEGMENT_PERIOD_S
        ),
        "patients_sustained_at_250hz_modeled_chips": int(
            out["chip"]["modeled_fleet_segments_per_s"]
            * SEGMENT_PERIOD_S
        ),
    }


def measure_overhead(
    program, *, patients: int = 128, segments: int = 5, reps: int = 10
) -> dict:
    """Measured (not assumed) telemetry tax: the same fleet config on
    one shared pre-warmed runner, simulated with telemetry disabled and
    enabled in interleaved reps; min-of-reps walls on both sides (the
    min is the noise-floor estimate — OS scheduling and GC only ever
    add time, so more reps tighten both sides symmetrically). GC is
    paused during the timed regions for the same reason."""
    cfg = FleetConfig(
        n_patients=patients,
        segments_per_patient=segments,
        va_fraction=0.05,
        jitter_frac=0.02,
        buckets=(32, 128),
        path="twin",
    )
    saved = obs.get()
    runner = FleetRunner(program, path="twin")
    walls = {"disabled": [], "enabled": []}
    try:
        obs.reset()
        simulate(cfg, runner=runner)  # untimed: compile everything
        for rep in range(reps):
            # alternate which mode runs first: scheduling noise arrives
            # in multi-second bursts, and a fixed order would let a
            # burst systematically land on one mode's phase
            order = ("disabled", "enabled") if rep % 2 == 0 else (
                "enabled", "disabled")
            for mode in order:
                if mode == "enabled":
                    obs.configure(enabled=True)
                else:
                    obs.reset()
                gc.disable()
                try:
                    t0 = time.perf_counter()
                    simulate(cfg, runner=runner)
                    walls[mode].append(time.perf_counter() - t0)
                finally:
                    gc.enable()
    finally:
        obs.install(saved)
    dis = min(walls["disabled"])
    en = min(walls["enabled"])
    # measurement resolution: how far the disabled-side walls spread
    # tells whether a 3% A/B difference is even resolvable here — on a
    # shared VM with steal time the spread routinely exceeds the
    # margin, and the strict assert downstream is gated on this
    d_sorted = sorted(walls["disabled"])
    noise_spread = d_sorted[len(d_sorted) // 2] / d_sorted[0] - 1.0
    return {
        "patients": patients,
        "segments": segments,
        "reps": reps,
        "disabled_wall_s": dis,
        "enabled_wall_s": en,
        "overhead_ratio": en / dis,
        "noise_spread": noise_spread,
        "resolvable": noise_spread <= 0.03,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + 1000-patient real-time check")
    ap.add_argument("--patients", type=int, default=512)
    ap.add_argument("--segments", type=int, default=6)
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write the telemetry trace to PREFIX.jsonl "
                         "(event log) + PREFIX.json (Chrome/Perfetto)")
    args = ap.parse_args()

    # before any runner compiles, so jit cells register with the probe
    obs.configure(enabled=True)
    params = vadetect.init(jax.random.PRNGKey(0))
    program = compiler.compile_model(params)

    if args.smoke:
        buckets = [32, 128]
        device_counts = [1, 8]
        sweep_patients, sweep_segments = 64, 4
    else:
        buckets = [32, 128, 256]
        device_counts = [1, 2, 4, 8]
        sweep_patients, sweep_segments = args.patients, args.segments
    device_counts = [d for d in device_counts if d <= jax.device_count()]

    cells = []
    for b in buckets:
        for d in device_counts:
            cell = run_cell(
                program,
                patients=sweep_patients,
                segments=sweep_segments,
                bucket=b,
                devices=d,
            )
            cells.append(cell)
            print(
                f"[stream_throughput] bucket={b:4d} devices={d} "
                f"wall={cell['wall_segments_per_s']:7.0f} seg/s "
                f"modeled-chips={cell['modeled_chip_segments_per_s']:7.0f} "
                f"seg/s dropped={cell['dropped_total']}",
                flush=True,
            )

    # device-count scaling on the largest bucket (modeled chip fleet:
    # the deployment quantity; forced host devices share the CPU, so
    # wall numbers are reported but not the scaling claim)
    largest = max(buckets)
    by_dev = {
        c["devices"]: c for c in cells if c["bucket"] == largest
    }
    lo, hi = min(by_dev), max(by_dev)
    scaling = {
        "bucket": largest,
        "devices_lo": lo,
        "devices_hi": hi,
        "modeled_chip_segments_per_s_lo": by_dev[lo][
            "modeled_chip_segments_per_s"
        ],
        "modeled_chip_segments_per_s_hi": by_dev[hi][
            "modeled_chip_segments_per_s"
        ],
        "modeled_speedup": by_dev[hi]["modeled_chip_segments_per_s"]
        / by_dev[lo]["modeled_chip_segments_per_s"],
        "wall_segments_per_s_lo": by_dev[lo]["wall_segments_per_s"],
        "wall_segments_per_s_hi": by_dev[hi]["wall_segments_per_s"],
    }

    # the 1000-patient real-time acceptance cell
    rt_devices = max(device_counts)
    rt_mesh = make_data_mesh(rt_devices)
    rt_runner = FleetRunner(program, path="twin", mesh=rt_mesh)
    rt_cfg = FleetConfig(
        n_patients=1000,
        segments_per_patient=6,  # one full vote window per patient
        va_fraction=0.05,
        jitter_frac=0.02,
        buckets=(32, 128, 512),
        path="twin",
    )
    rt = simulate(rt_cfg, runner=rt_runner)
    realtime = {
        "patients": 1000,
        "devices": rt_devices,
        "segments_total": rt["metrics"]["segments_total"],
        "dropped_total": rt["metrics"]["dropped_total"],
        "required_segments_per_s": rt["realtime"][
            "required_segments_per_s"
        ],
        "sustained_segments_per_s": rt["realtime"][
            "sustained_segments_per_s"
        ],
        "realtime_factor": rt["realtime"]["realtime_factor"],
        "deadline_slack_s": rt["metrics"].get("deadline_slack_s"),
        "jit_cache_misses": rt["jit_cache_misses"],
    }
    print(
        f"[stream_throughput] 1000 patients on {rt_devices} devices: "
        f"{realtime['sustained_segments_per_s']:.0f} seg/s sustained vs "
        f"{realtime['required_segments_per_s']:.0f} required "
        f"({realtime['realtime_factor']:.1f}x real-time), "
        f"dropped={realtime['dropped_total']}"
    )

    overhead = measure_overhead(program)
    print(
        f"[stream_throughput] telemetry overhead: enabled "
        f"{overhead['enabled_wall_s']:.3f}s vs disabled "
        f"{overhead['disabled_wall_s']:.3f}s "
        f"({(overhead['overhead_ratio'] - 1) * 100:+.1f}%, host noise "
        f"spread {overhead['noise_spread']:.1%})"
    )
    telemetry = obs.telemetry_section()
    telemetry["overhead"] = overhead

    # static cell audit over the probe registry (stream.classify.*
    # from the sweep runners + stream.vote): re-lower each cell from
    # its captured call avals and check host-transfer/f64/donation/
    # budget properties (repro.analysis.cellaudit)
    cell_audit = audit_section()

    rec = {
        "benchmark": "stream_throughput",
        "n_host_devices": jax.device_count(),
        "chip_latency_us": program.report.latency_s * 1e6,
        "cells": cells,
        "scaling_largest_bucket": scaling,
        "realtime_1000_patients": realtime,
        "telemetry": telemetry,
        "cell_audit": cell_audit,
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[stream_throughput] -> {args.out}")
    if args.trace_out:
        # after measure_overhead re-installed the main telemetry, so
        # the trace covers the sweep + acceptance cells
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")

    # acceptance: zero scheduler drops everywhere; real-time sustained
    # for 1000 patients; and the scaling claim's *mechanism* — the
    # modeled chip-fleet rate is N/latency by construction, so what can
    # regress (and is asserted) is that every multi-device cell really
    # sharded its buckets bucket/N per device over the data axis
    assert all(c["dropped_total"] == 0 for c in cells)
    assert realtime["dropped_total"] == 0
    assert all(c["batch_sharded_over_devices"] for c in cells), cells
    if hi >= 8 * lo:
        assert scaling["modeled_speedup"] > 4.0, scaling
    assert realtime["realtime_factor"] >= 1.0, realtime
    # telemetry gates: the registry's own zero-drop counter (summed
    # over every simulate in this process), flush-latency percentiles
    # present, the classify jit cell's recompile count visible, and the
    # measured enabled-telemetry tax under 3% wall
    t = telemetry
    assert t["schema_version"] == obs.SCHEMA_VERSION and t["enabled"]
    assert t["counters"]["stream.dropped_total"] == 0, t["counters"]
    flush = t["histograms"]["stream.flush_wall_s"]
    assert flush["count"] > 0 and None not in (
        flush["p50"], flush["p99"], flush["p999"]
    ), flush
    assert any(
        k.startswith("stream.classify") and v
        for k, v in t["recompiles"].items()
    ), t["recompiles"]
    assert t["peak_device_memory_bytes"] > 0, t
    # cell audit gates: the classify + vote cells must all have been
    # exercised (avals captured) and re-lower with zero violations
    assert cell_audit["n_cells"] > 0
    assert cell_audit["violations_total"] == 0, cell_audit
    assert any(
        k.startswith("stream.classify") for k in cell_audit["cells"]
    ), cell_audit["cells"].keys()
    assert "stream.vote" in cell_audit["cells"], cell_audit["cells"].keys()
    # strict wall-clock assert only when the host can resolve a 3%
    # A/B (disabled-side spread within the margin); on a noisy shared
    # VM the ratio is below measurement resolution — record it and
    # lean on the per-emission budget test in tests/test_obs.py, which
    # enforces the enabled-path cost unconditionally
    if overhead["resolvable"]:
        assert overhead["overhead_ratio"] < 1.03, overhead
    else:
        print(
            f"[stream_throughput] overhead assert skipped: host noise "
            f"spread {overhead['noise_spread']:.1%} > 3% resolution "
            f"(ratio {overhead['overhead_ratio']:.3f} recorded)"
        )


if __name__ == "__main__":
    main()
