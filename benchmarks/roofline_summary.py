"""Roofline summary over dry-run artifacts (the §Roofline data source).

Requires a prior `python -m repro.launch.dryrun` run; prints one CSV row
per recorded (arch x shape) cell with the three terms and the dominant
bottleneck. Skips gracefully when no dry-run output exists (CI machines).
"""

from __future__ import annotations

import os

from repro.launch.roofline import load_records

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run() -> list[tuple[str, float, str]]:
    rows = []
    for mesh in ("singlepod_16x16", "multipod_2x16x16"):
        for r in load_records(DRYRUN_DIR, mesh):
            rf = r["roofline"]
            rows.append((
                f"roofline.{mesh}.{r['arch']}.{r['shape']}",
                rf["bound_s"] * 1e6,
                f"dom={rf['dominant']} frac={rf['roofline_fraction']:.3f} "
                f"useful={rf['useful_flops_ratio']:.3f} "
                f"mem_gib={r['memory']['total_per_device_bytes'] / 2**30:.2f}",
            ))
    if not rows:
        rows.append(("roofline.missing", 0.0,
                     "run `python -m repro.launch.dryrun` first"))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
