"""Kernel micro-benchmarks (interpret-mode timings + bandwidth math).

CPU interpret timings are NOT TPU performance; the derived column reports
the structural quantity that *does* transfer: HBM bytes moved per matmul
vs the dense-f32 baseline (the memory-roofline win the SPE/CMUL formats
buy). Correctness vs oracles is asserted on every call.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core import sparsity as S
from repro.kernels import ops, ref

M, K, N = 128, 512, 256
G, KEEP = 16, 8


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6, out


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    dense_bytes = K * N * 4

    # nm_spmm (SPE): int8 values + uint8 selects, half the rows
    values, select = S.compress(
        S.apply_prune(w, S.SparsityConfig(G, KEEP)), S.SparsityConfig(G, KEEP)
    )
    q, scale = Q.quantize(values, Q.QuantConfig(bits=8))
    us, y = _time(
        lambda a: ops.nm_spmm(a, q, select, scale.reshape(1, -1),
                              group_size=G, keep=KEEP), x,
    )
    y_ref = ref.nm_spmm_ref(x, q, select, scale.reshape(1, -1),
                            group_size=G, keep=KEEP)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    spe_bytes = q.size + select.size // 2 + N * 4
    rows.append(("kernels.nm_spmm", us,
                 f"hbm_bytes={spe_bytes} vs_dense_f32={dense_bytes} "
                 f"({dense_bytes / spe_bytes:.2f}x)"))

    # quant_matmul at each CMUL precision
    for bits in (8, 4, 2, 1):
        qd, sd = Q.quantize(w, Q.QuantConfig(bits=bits))
        packed = Q.pack_planes(qd, bits)
        us, y = _time(
            lambda a, p=packed, s=sd, b=bits: ops.quant_matmul(
                a, p, s.reshape(1, -1), bits=b), x,
        )
        y_ref = ref.quant_matmul_ref(x, packed, sd.reshape(1, -1),
                                     bits=bits, k=K)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
        b = packed.size + N * 4
        rows.append((f"kernels.quant_matmul_{bits}b", us,
                     f"hbm_bytes={b} ({dense_bytes / b:.2f}x)"))

    # fused sparse conv (one VA layer)
    ks, stride, c, nout, t = 7, 2, 4, 16, 512
    kd = -(-(ks * c) // G) * G
    wc = jax.random.normal(jax.random.PRNGKey(2), (kd, nout))
    v2, s2 = S.compress(S.apply_prune(wc, S.SparsityConfig(G, KEEP)),
                        S.SparsityConfig(G, KEEP))
    q2, sc2 = Q.quantize(v2, Q.QuantConfig(bits=8))
    sig = jax.random.normal(jax.random.PRNGKey(3), (4, t, c))
    us, y = _time(
        lambda a: ops.sparse_conv1d(a, q2, s2, sc2.reshape(1, -1),
                                    ksize=ks, stride=stride,
                                    group_size=G, keep=KEEP), sig,
    )
    y_ref = ref.sparse_conv1d_ref(sig, q2, s2, sc2.reshape(1, -1),
                                  ksize=ks, stride=stride, group_size=G,
                                  keep=KEEP)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    rows.append(("kernels.sparse_conv1d", us,
                 "fused_im2col=True (no HBM patch materialization)"))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
