"""Sparsity x bit-width ablation (the compression recipe's accuracy cost).

Trains the VA detector under each (sparsity, bits) operating point for a
short budget on synthetic IEGM and reports per-segment accuracy + model
storage. Reproduces the paper's design decision: 50% balanced sparsity +
8-bit costs almost nothing vs the dense float baseline; the CMUL's
sub-byte modes trade accuracy for energy.
"""

from __future__ import annotations

import time

import jax

from repro import optim
from repro.core import compiler, vadetect
from repro.core.spe import SPEConfig
from repro.data import iegm
from repro.train import trainer

POINTS = [
    ("dense_f32", None),
    ("sparse50_8b", SPEConfig(bits=8, sparse=True, quantized=True)),
    ("sparse50_4b", SPEConfig(bits=4, sparse=True, quantized=True)),
    ("dense_8b", SPEConfig(bits=8, sparse=False, quantized=True)),
    ("sparse50_2b", SPEConfig(bits=2, sparse=True, quantized=True)),
]

STEPS = 120
BATCH = 64


def run(steps: int = STEPS) -> list[tuple[str, float, str]]:
    rows = []
    for name, spe in POINTS:
        cfg = vadetect.VAConfig(spe=spe)
        params = vadetect.init(jax.random.PRNGKey(0), cfg)
        opt = optim.adam(3e-3)
        state = trainer.init_state(params, opt)
        step = jax.jit(trainer.make_train_step(
            lambda p, b, cfg=cfg: vadetect.loss_fn(p, b, cfg), opt,
            clip_norm=1.0,
        ), donate_argnums=(0,))
        stream = iegm.IEGMStream(batch=BATCH, seed=0)
        t0 = time.perf_counter()
        accs = []
        for i in range(steps):
            state, m = step(state, stream.batch_at(i))
            accs.append(float(m["accuracy"]))
        us = (time.perf_counter() - t0) / steps * 1e6
        acc = sum(accs[-10:]) / 10
        if spe is not None and spe.quantized:
            prog = compiler.compile_model(state["params"], cfg)
            kb = prog.weight_hbm_bytes() / 1024
            ratio = prog.compression_ratio()
        else:
            n = vadetect.param_count(state["params"])
            kb = n * 4 / 1024
            ratio = 1.0
        rows.append((
            f"ablation.{name}", us,
            f"acc={acc:.4f} weights_kb={kb:.1f} compress={ratio:.2f}x",
        ))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
