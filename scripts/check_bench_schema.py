"""Bench-schema guard: every BENCH_*.json must carry the shared
telemetry section.

All benchmark records (BENCH_stream / BENCH_decode / BENCH_dist /
BENCH_load, committed or CI-emitted) attach `repro.obs.
telemetry_section()` under the "telemetry" key. A benchmark that stops
doing so — or drifts to a different schema version — silently rots the
cross-benchmark telemetry contract; this guard turns that into a CI
failure.

    python scripts/check_bench_schema.py BENCH_a.json [BENCH_b.json ...]

Exits nonzero listing every violation. Checks per file:
  * a "telemetry" dict is present;
  * telemetry["schema_version"] == repro.obs.SCHEMA_VERSION;
  * telemetry was enabled and the shared sub-sections exist
    (counters / gauges / histograms / recompiles).
"""

from __future__ import annotations

import json
import sys

REQUIRED_KEYS = ("counters", "gauges", "histograms", "recompiles")


def check_file(path: str, schema_version: int) -> list[str]:
    errors = []
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    tel = rec.get("telemetry")
    if not isinstance(tel, dict):
        return [f"{path}: no 'telemetry' section"]
    v = tel.get("schema_version")
    if v != schema_version:
        errors.append(
            f"{path}: telemetry schema_version {v!r}, "
            f"expected {schema_version}"
        )
    if not tel.get("enabled"):
        errors.append(f"{path}: telemetry was not enabled")
    for k in REQUIRED_KEYS:
        if not isinstance(tel.get(k), dict):
            errors.append(f"{path}: telemetry missing {k!r}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_bench_schema.py BENCH_*.json", file=sys.stderr)
        return 2
    from repro import obs

    errors = []
    for path in argv:
        errors.extend(check_file(path, obs.SCHEMA_VERSION))
    for e in errors:
        print(f"[bench-schema] FAIL {e}", file=sys.stderr)
    if not errors:
        print(
            f"[bench-schema] {len(argv)} record(s) OK "
            f"(telemetry schema v{obs.SCHEMA_VERSION})"
        )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
