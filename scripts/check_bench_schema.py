"""Bench-schema guard: every BENCH_*.json must carry the shared
telemetry section.

All benchmark records (BENCH_stream / BENCH_decode / BENCH_dist /
BENCH_load, committed or CI-emitted) attach `repro.obs.
telemetry_section()` under the "telemetry" key. A benchmark that stops
doing so — or drifts to a different schema version — silently rots the
cross-benchmark telemetry contract; this guard turns that into a CI
failure.

    python scripts/check_bench_schema.py BENCH_a.json [BENCH_b.json ...]

Exits nonzero listing every violation. Checks per file:
  * a "telemetry" dict is present;
  * telemetry["schema_version"] == repro.obs.SCHEMA_VERSION;
  * telemetry was enabled and the shared sub-sections exist
    (counters / gauges / histograms / recompiles).

Per-benchmark sections (keyed on the record's "benchmark" name):
  * load_sweep must carry the serving-frontend socket sweep: a
    "frontend" dict with transport == "socket", a numeric admission
    rate, a non-empty points list, the overload verdict block, and the
    socket-vs-inproc transport_overhead pairing — the loopback-socket
    sweep silently falling out of the bench fails here.
"""

from __future__ import annotations

import json
import sys

REQUIRED_KEYS = ("counters", "gauges", "histograms", "recompiles")

# required (key, type) pairs of the load_sweep record's frontend
# (loopback-socket) section — `loadlab.sweep_frontend` output
FRONTEND_KEYS = (
    ("transport", str),
    ("admission_rate_rps", (int, float)),
    ("points", list),
    ("shed_curve", list),
    ("overload", dict),
    ("transport_overhead", dict),
)


def _check_frontend(path: str, rec: dict) -> list[str]:
    fe = rec.get("frontend")
    if not isinstance(fe, dict):
        return [f"{path}: load_sweep record has no 'frontend' "
                f"(loopback-socket sweep) section"]
    errors = []
    for k, typ in FRONTEND_KEYS:
        if not isinstance(fe.get(k), typ):
            errors.append(f"{path}: frontend section missing {k!r}")
    if fe.get("transport") != "socket":
        errors.append(
            f"{path}: frontend transport {fe.get('transport')!r}, "
            f"expected 'socket' (the committed record must price the "
            f"real wire)"
        )
    if isinstance(fe.get("points"), list) and not fe["points"]:
        errors.append(f"{path}: frontend points list is empty")
    if not (fe.get("overload") or {}).get("verdict"):
        errors.append(f"{path}: frontend overload verdict missing")
    return errors


def check_file(path: str, schema_version: int) -> list[str]:
    errors = []
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    tel = rec.get("telemetry")
    if not isinstance(tel, dict):
        return [f"{path}: no 'telemetry' section"]
    v = tel.get("schema_version")
    if v != schema_version:
        errors.append(
            f"{path}: telemetry schema_version {v!r}, "
            f"expected {schema_version}"
        )
    if not tel.get("enabled"):
        errors.append(f"{path}: telemetry was not enabled")
    for k in REQUIRED_KEYS:
        if not isinstance(tel.get(k), dict):
            errors.append(f"{path}: telemetry missing {k!r}")
    if rec.get("benchmark") == "load_sweep":
        errors.extend(_check_frontend(path, rec))
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_bench_schema.py BENCH_*.json", file=sys.stderr)
        return 2
    from repro import obs

    errors = []
    for path in argv:
        errors.extend(check_file(path, obs.SCHEMA_VERSION))
    for e in errors:
        print(f"[bench-schema] FAIL {e}", file=sys.stderr)
    if not errors:
        print(
            f"[bench-schema] {len(argv)} record(s) OK "
            f"(telemetry schema v{obs.SCHEMA_VERSION})"
        )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
