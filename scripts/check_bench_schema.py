"""Bench-schema guard: every BENCH_*.json must carry the shared
telemetry section.

All benchmark records (BENCH_stream / BENCH_decode / BENCH_dist /
BENCH_load, committed or CI-emitted) attach `repro.obs.
telemetry_section()` under the "telemetry" key. A benchmark that stops
doing so — or drifts to a different schema version — silently rots the
cross-benchmark telemetry contract; this guard turns that into a CI
failure.

    python scripts/check_bench_schema.py BENCH_a.json [BENCH_b.json ...]

Exits nonzero listing every violation. Checks per file:
  * a "telemetry" dict is present;
  * telemetry["schema_version"] == repro.obs.SCHEMA_VERSION;
  * telemetry was enabled and the shared sub-sections exist
    (counters / gauges / histograms / recompiles).

Per-benchmark sections (keyed on the record's "benchmark" name):
  * load_sweep must carry the serving-frontend socket sweep: a
    "frontend" dict with transport == "socket", a numeric admission
    rate, a non-empty points list, the overload verdict block, and the
    socket-vs-inproc transport_overhead pairing — the loopback-socket
    sweep silently falling out of the bench fails here.
  * decode_throughput must carry the paged-cache tenancy cell: a
    "paged" dict with the pool geometry, a concurrency_gain >= 2 at
    paged bytes <= dense bytes, the bytes_in_use residency trace
    returning to its initial value after drain, and zero recompiles
    after warmup — the paged section falling out of the bench (or the
    tenancy win regressing) fails here.
  * decode_throughput / stream_throughput / dist_compression must
    carry the static "cell_audit" section (`repro.analysis.
    audit_section()`): every jit cell the warmup registered,
    re-lowered from its captured avals, with zero violations — the
    audit falling out of a bench (or a committed record carrying a
    violation) fails here.

Analysis reports (emitted by `python -m repro.analysis --json`, keyed
on "report" == "analysis") are validated instead against the analyzer
schema: current schema_version, nonzero files_scanned, a populated
rule catalog, zero live findings and zero stale baseline entries.
"""

from __future__ import annotations

import json
import sys

REQUIRED_KEYS = ("counters", "gauges", "histograms", "recompiles")

# required (key, type) pairs of the load_sweep record's frontend
# (loopback-socket) section — `loadlab.sweep_frontend` output
FRONTEND_KEYS = (
    ("transport", str),
    ("admission_rate_rps", (int, float)),
    ("points", list),
    ("shed_curve", list),
    ("overload", dict),
    ("transport_overhead", dict),
)


def _check_frontend(path: str, rec: dict) -> list[str]:
    fe = rec.get("frontend")
    if not isinstance(fe, dict):
        return [f"{path}: load_sweep record has no 'frontend' "
                f"(loopback-socket sweep) section"]
    errors = []
    for k, typ in FRONTEND_KEYS:
        if not isinstance(fe.get(k), typ):
            errors.append(f"{path}: frontend section missing {k!r}")
    if fe.get("transport") != "socket":
        errors.append(
            f"{path}: frontend transport {fe.get('transport')!r}, "
            f"expected 'socket' (the committed record must price the "
            f"real wire)"
        )
    if isinstance(fe.get("points"), list) and not fe["points"]:
        errors.append(f"{path}: frontend points list is empty")
    if not (fe.get("overload") or {}).get("verdict"):
        errors.append(f"{path}: frontend overload verdict missing")
    return errors


# required (key, type) pairs of the decode_throughput record's paged
# (tenancy) section — `decode_throughput.measure_paged` output
PAGED_KEYS = (
    ("page_size", int),
    ("n_pages", int),
    ("span", int),
    ("dense_pool_slots", int),
    ("paged_pool_slots", int),
    ("dense_cache_bytes_total", int),
    ("paged_cache_bytes_total", int),
    ("dense_peak_concurrent", int),
    ("paged_peak_concurrent", int),
    ("concurrency_gain", (int, float)),
    ("bytes_in_use", dict),
    ("recompiles_after_warmup", int),
)


def _check_paged(path: str, rec: dict) -> list[str]:
    pg = rec.get("paged")
    if not isinstance(pg, dict):
        return [f"{path}: decode_throughput record has no 'paged' "
                f"(paged-cache tenancy) section"]
    errors = []
    for k, typ in PAGED_KEYS:
        if not isinstance(pg.get(k), typ):
            errors.append(f"{path}: paged section missing {k!r}")
    gain = pg.get("concurrency_gain")
    if isinstance(gain, (int, float)) and gain < 2.0:
        errors.append(
            f"{path}: paged concurrency_gain {gain} < 2.0 (the "
            f"committed record must show the tenancy win)"
        )
    if (isinstance(pg.get("paged_cache_bytes_total"), int)
            and isinstance(pg.get("dense_cache_bytes_total"), int)
            and pg["paged_cache_bytes_total"]
            > pg["dense_cache_bytes_total"]):
        errors.append(
            f"{path}: paged pool spends more cache bytes than dense "
            f"({pg['paged_cache_bytes_total']} > "
            f"{pg['dense_cache_bytes_total']}) — the gain must come at "
            f"a fixed byte budget"
        )
    biu = pg.get("bytes_in_use")
    if isinstance(biu, dict):
        for k in ("initial", "peak", "post_drain", "post_drain_final"):
            if not isinstance(biu.get(k), int):
                errors.append(f"{path}: paged bytes_in_use missing {k!r}")
        if (isinstance(biu.get("post_drain_final"), int)
                and isinstance(biu.get("initial"), int)
                and biu["post_drain_final"] != biu["initial"]):
            errors.append(
                f"{path}: paged pool did not drain to its initial "
                f"residency ({biu['post_drain_final']} != "
                f"{biu['initial']}) — leaked pages"
            )
    if pg.get("recompiles_after_warmup") != 0:
        errors.append(
            f"{path}: paged cells recompiled after warmup "
            f"({pg.get('recompiles_after_warmup')!r}) — paging broke "
            f"the per-width compiled-cell discipline"
        )
    return errors


# benchmarks whose records must carry the repro.analysis cell audit
CELL_AUDIT_BENCHMARKS = (
    "decode_throughput", "stream_throughput", "dist_compression"
)


def _check_cell_audit(path: str, rec: dict) -> list[str]:
    ca = rec.get("cell_audit")
    if not isinstance(ca, dict):
        return [f"{path}: {rec.get('benchmark')} record has no "
                f"'cell_audit' (repro.analysis) section"]
    errors = []
    if not isinstance(ca.get("n_cells"), int) or ca["n_cells"] < 1:
        errors.append(f"{path}: cell_audit covers no cells")
    if ca.get("violations_total") != 0:
        errors.append(
            f"{path}: cell_audit carries "
            f"{ca.get('violations_total')!r} violation(s) — a "
            f"committed record must audit clean"
        )
    cells = ca.get("cells")
    if not isinstance(cells, dict) or not cells:
        errors.append(f"{path}: cell_audit 'cells' map missing/empty")
        return errors
    if isinstance(ca.get("n_cells"), int) and len(cells) != ca["n_cells"]:
        errors.append(
            f"{path}: cell_audit n_cells {ca['n_cells']} != "
            f"{len(cells)} cells listed"
        )
    for name, cell in cells.items():
        if not isinstance(cell, dict) or not isinstance(
                cell.get("violations"), list):
            errors.append(
                f"{path}: cell_audit cell {name!r} malformed"
            )
        elif cell["violations"]:
            errors.append(
                f"{path}: cell {name!r}: {cell['violations'][0]}"
            )
        if isinstance(cell, dict) and not isinstance(
                cell.get("collectives"), dict):
            errors.append(
                f"{path}: cell {name!r} missing collective inventory"
            )
    return errors


def _check_analysis(path: str, rec: dict) -> list[str]:
    from repro import analysis

    errors = []
    v = rec.get("schema_version")
    if v != analysis.SCHEMA_VERSION:
        errors.append(
            f"{path}: analysis schema_version {v!r}, expected "
            f"{analysis.SCHEMA_VERSION}"
        )
    if not isinstance(rec.get("files_scanned"), int) or (
            rec["files_scanned"] < 1):
        errors.append(f"{path}: analysis report scanned no files")
    rules = rec.get("rules")
    if not isinstance(rules, list) or not rules:
        errors.append(f"{path}: analysis rule catalog missing/empty")
    else:
        for r in rules:
            if not isinstance(r, dict) or not all(
                    isinstance(r.get(k), str)
                    for k in ("id", "summary", "incident")):
                errors.append(
                    f"{path}: malformed rule entry {r!r}"
                )
    if rec.get("findings") != []:
        errors.append(
            f"{path}: analysis report carries live findings — the "
            f"tree must be clean (fix or suppress with a pragma)"
        )
    if rec.get("stale_baseline") != []:
        errors.append(
            f"{path}: analysis baseline is stale (entries no longer "
            f"match live findings — prune analysis_baseline.json)"
        )
    if not isinstance(rec.get("suppressed"), list):
        errors.append(f"{path}: analysis 'suppressed' list missing")
    return errors


def check_file(path: str, schema_version: int) -> list[str]:
    errors = []
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if rec.get("report") == "analysis":
        return _check_analysis(path, rec)
    tel = rec.get("telemetry")
    if not isinstance(tel, dict):
        return [f"{path}: no 'telemetry' section"]
    v = tel.get("schema_version")
    if v != schema_version:
        errors.append(
            f"{path}: telemetry schema_version {v!r}, "
            f"expected {schema_version}"
        )
    if not tel.get("enabled"):
        errors.append(f"{path}: telemetry was not enabled")
    for k in REQUIRED_KEYS:
        if not isinstance(tel.get(k), dict):
            errors.append(f"{path}: telemetry missing {k!r}")
    if rec.get("benchmark") == "load_sweep":
        errors.extend(_check_frontend(path, rec))
    if rec.get("benchmark") == "decode_throughput":
        errors.extend(_check_paged(path, rec))
    if rec.get("benchmark") in CELL_AUDIT_BENCHMARKS:
        errors.extend(_check_cell_audit(path, rec))
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_bench_schema.py BENCH_*.json", file=sys.stderr)
        return 2
    from repro import obs

    errors = []
    for path in argv:
        errors.extend(check_file(path, obs.SCHEMA_VERSION))
    for e in errors:
        print(f"[bench-schema] FAIL {e}", file=sys.stderr)
    if not errors:
        print(
            f"[bench-schema] {len(argv)} record(s) OK "
            f"(telemetry schema v{obs.SCHEMA_VERSION})"
        )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
