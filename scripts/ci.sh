#!/usr/bin/env bash
# Tier-1 CI: run the full suite on a forced 8-device host platform so
# the sharding rules, shard_map collectives, and the multi-device tests
# (tests/test_dist_multidevice.py, tests/test_decode_multidevice.py,
# tests/test_admission_properties.py) are exercised on a >1-device mesh
# (single-device hosts would silently skip them). The `slow`-marked
# multi-device tests run here; every run ends with a per-file test-time
# report (tests/conftest.py) so a new test file ballooning the suite is
# visible immediately.
#
# Usage: scripts/ci.sh [--smoke] [--fast] [pytest args...]
#   --fast   fast lane: pytest -m "not slow" and no benchmark smokes —
#            the local inner-loop entry point.
#   --smoke  benchmark smokes below always run in full CI; flag kept so
#            the documented `scripts/ci.sh --smoke` entry point names
#            what it runs; any other args pass through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

# Tree hygiene: compiled bytecode must never be committed (a PR once
# landed 12 __pycache__/*.pyc files; .gitignore plus this guard keeps
# the tree clean even if the ignore file regresses).
if [[ -n "$(git ls-files '*.pyc' '*.pyo' 2>/dev/null)" ]]; then
  echo "ERROR: committed bytecode files:" >&2
  git ls-files '*.pyc' '*.pyo' >&2
  exit 1
fi

# our flag goes LAST: XLA takes the last duplicate, so a pre-set
# device-count in the caller's environment cannot silently shrink the
# mesh and skip the multidevice tests
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
PYTEST_ARGS=()
for a in "$@"; do
  case "$a" in
    --smoke) ;;  # benchmarks below always run; flag kept for the docs
    --fast) FAST=1 ;;
    *) PYTEST_ARGS+=("$a") ;;
  esac
done

# Static analysis gate (repro.analysis): the repo-specific AST rule
# pack over src/ + scripts/ + benchmarks/ + examples/. Zero unsuppressed
# findings and a non-stale baseline or the lane fails — this is the
# fast lane's cheapest, earliest signal (the compiled-cell audit runs
# inside the benchmark smokes below, after their warmups).
python -m repro.analysis --fail-on-findings --json /tmp/analysis_ci.json

if [[ "$FAST" == 1 ]]; then
  python -m pytest -x -q -m "not slow" ${PYTEST_ARGS+"${PYTEST_ARGS[@]}"}
  exit 0
fi

python -m pytest -x -q ${PYTEST_ARGS+"${PYTEST_ARGS[@]}"}

# Benchmark + example smokes on the same 8 forced host devices, so none
# can bit-rot:
#  * stream_throughput — tiny sweep + the 1000-patient real-time cell;
#    asserts zero scheduler drops and >= real-time sustained throughput.
#  * decode_throughput — sharded LM decode acceptance cells; asserts
#    per-device cache bytes < replicated baseline, modeled tokens/s
#    scaling with device count, and pool-size-independent (O(prompt))
#    batched-prefill admission cost.
#  * dist_compression — scheme x pod-count reduction sweep; asserts the
#    two-stage per-device egress is pod-count-independent (~4x below
#    the f32 ring at n = 2/4/8), the gather scheme decays like 8/n,
#    and the compressed loss curves track the f32 baseline.
#  * serve_lm example — batched admission demo (multiple prompts seated
#    per prefill cell) through the plain and mesh-sharded engines; run
#    with --trace-out as the telemetry trace smoke: the emitted JSONL
#    event log is validated line-by-line against the repro.obs.trace
#    event schema and the Chrome/Perfetto export checked well-formed
#    (python -m repro.obs.trace exits nonzero on empty/malformed).
#  * load_sweep — the open-loop load lab: offered-load sweeps for both
#    engines with latency from intended arrivals; asserts knee located,
#    coordinated-omission guard, URGENT-class SLO survival under
#    overload, graceful frontend degradation at 3x the knee (typed
#    shedding, exact accounting, zero URGENT loss), and every sampled
#    request's lineage joining across >= 3 subsystem hops (>= 4 with
#    the transport hop for the frontend sample).
#  * frontend sweep — the async serving frontend end-to-end over a real
#    loopback socket via the launcher: admission pinned to measured
#    capacity, one sub-knee + one 3x-overload offered-load point; the
#    emitted trace must carry the transport hops (validated below).
python benchmarks/stream_throughput.py --smoke --out /tmp/BENCH_stream_ci.json --trace-out /tmp/ci_trace_stream
python benchmarks/decode_throughput.py --smoke --out /tmp/BENCH_decode_ci.json --trace-out /tmp/ci_trace_decode
python benchmarks/dist_compression.py --smoke --out /tmp/BENCH_dist_ci.json --trace-out /tmp/ci_trace_dist
python benchmarks/load_sweep.py --smoke --out /tmp/BENCH_load_ci.json --trace-out /tmp/ci_trace_load
python examples/serve_lm.py --smoke --trace-out /tmp/ci_trace
python -m repro.launch.serve --arch qwen3-8b --reduced --batch 4 \
  --prompt-len 6 --max-new 8 --patients 8 --frontend-sweep \
  --load-fractions 0.25,3.0 --load-requests 16 \
  --trace-out /tmp/ci_trace_frontend

# Paged-cache rejection smoke: a paged engine behind the frontend over
# a real loopback socket, with a page pool deliberately too small for
# one of the two requests. The satisfiable request must complete and
# the never-satisfiable one must come back as a typed
# rejected/pages_exhausted lm_result — the wire contract clients size
# down from (tests/test_frontend.py covers the in-proc path; this
# prices the socket).
python - <<'EOF'
import asyncio
import jax

from repro import configs
from repro.models import api
from repro.serve import engine as E
from repro.serve.frontend import (
    Frontend, FrontendConfig, SocketClient,
    REASON_PAGES, STATUS_COMPLETED, STATUS_REJECTED,
)
from repro.serve.paging import PagingConfig

cfg = configs.reduced("qwen3_8b")
model = api.build_model(cfg, tp=1, max_seq=24)
params = model.init(jax.random.PRNGKey(0))
# 3 pages of 4 positions: 2 usable + scratch -> worst case of
# prompt 9 + max_new 8 (4 pages) can never seat
eng = E.Engine(
    model, params, batch_size=2,
    paging=PagingConfig(page_size=4, n_pages=3),
)

async def main():
    fe = Frontend(engine=eng, cfg=FrontendConfig())
    host, port = await fe.start()
    cli = await SocketClient.connect(host, port)
    ok = await cli.send_lm(0, [3, 1, 4], max_new=3)
    bad = await cli.send_lm(1, list(range(2, 11)), max_new=8)
    ok, bad = await asyncio.gather(
        asyncio.wait_for(ok, 120), asyncio.wait_for(bad, 120)
    )
    assert ok["status"] == STATUS_COMPLETED and ok["tokens"], ok
    assert bad["status"] == STATUS_REJECTED, bad
    assert bad["reason"] == REASON_PAGES, bad
    await cli.close()
    await fe.stop()
    print(
        f"[ci] pages_exhausted smoke: uid 0 completed "
        f"({len(ok['tokens'])} tokens), uid 1 rejected "
        f"({bad['reason']})"
    )

asyncio.run(main())
EOF

# Every emitted trace is validated line-by-line against the
# repro.obs.trace event schema and its Chrome/Perfetto export checked
# well-formed (exits nonzero on empty/malformed) — not just the
# serve_lm smoke.
for t in /tmp/ci_trace /tmp/ci_trace_stream /tmp/ci_trace_decode \
         /tmp/ci_trace_dist /tmp/ci_trace_load /tmp/ci_trace_frontend; do
  python -m repro.obs.trace "$t.jsonl" "$t.json"
done

# Bench-schema guard: committed and CI-emitted BENCH records must all
# carry the shared telemetry section at the expected schema_version —
# and decode/stream/dist records the clean repro.analysis cell_audit
# section (every warmed jit cell re-lowered: no host transfers, no
# f64, donations honored, collectives within declared budgets). The
# analyzer's own JSON report is validated against the same guard.
python scripts/check_bench_schema.py BENCH_*.json /tmp/BENCH_*_ci.json \
  /tmp/analysis_ci.json
