#!/usr/bin/env bash
# Tier-1 CI: run the full suite on a forced 8-device host platform so
# the sharding rules, shard_map collectives, and the multi-device tests
# (tests/test_dist_multidevice.py, tests/test_decode_multidevice.py)
# are exercised on a >1-device mesh (single-device hosts would silently
# skip them). The `slow`-marked multi-device decode tests run here;
# skip them locally with `pytest -m "not slow"`.
#
# Usage: scripts/ci.sh [--smoke] [pytest args...]
# The benchmark smokes (stream + sharded decode) run in every CI
# invocation — `--smoke` is accepted explicitly so the documented
# `scripts/ci.sh --smoke` entry point names what it runs; any other
# args pass through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

# our flag goes LAST: XLA takes the last duplicate, so a pre-set
# device-count in the caller's environment cannot silently shrink the
# mesh and skip the multidevice tests
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=()
for a in "$@"; do
  case "$a" in
    --smoke) ;;  # benchmarks below always run; flag kept for the docs
    *) PYTEST_ARGS+=("$a") ;;
  esac
done

python -m pytest -x -q ${PYTEST_ARGS+"${PYTEST_ARGS[@]}"}

# Benchmark smokes on the same 8 forced host devices, so neither can
# bit-rot:
#  * stream_throughput — tiny sweep + the 1000-patient real-time cell;
#    asserts zero scheduler drops and >= real-time sustained throughput.
#  * decode_throughput — sharded LM decode acceptance cells; asserts
#    per-device cache bytes < replicated baseline and modeled tokens/s
#    scaling with device count.
python benchmarks/stream_throughput.py --smoke --out /tmp/BENCH_stream_ci.json
python benchmarks/decode_throughput.py --smoke --out /tmp/BENCH_decode_ci.json
