#!/usr/bin/env bash
# Tier-1 CI: run the full suite on a forced 8-device host platform so
# the sharding rules, shard_map collectives, and the multi-device tests
# in tests/test_dist_multidevice.py are exercised on a >1-device mesh
# (single-device hosts would silently skip them).
set -euo pipefail
cd "$(dirname "$0")/.."

# our flag goes LAST: XLA takes the last duplicate, so a pre-set
# device-count in the caller's environment cannot silently shrink the
# mesh and skip the multidevice tests
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

# Streaming-fleet benchmark smoke (tiny sweep + the 1000-patient
# real-time cell on the same 8 forced host devices) so
# benchmarks/stream_throughput.py can never bit-rot; it asserts zero
# scheduler drops and >= real-time sustained throughput.
python benchmarks/stream_throughput.py --smoke --out /tmp/BENCH_stream_ci.json
