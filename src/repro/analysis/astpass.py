"""AST scan infrastructure: file walk, rule dispatch, suppression.

The rule pack itself lives in `rules`; this module owns everything
around it — parsing each file once, running every rule over the shared
parse, honoring inline `# repro: allow[rule-id]` pragmas and the
checked-in baseline, and folding the outcome into the JSON report the
CI lane validates.

Suppression semantics (both layers keep CI honest):

  * **Pragma** — `# repro: allow[rule-id] reason` on the flagged line
    or the line directly above it. Scoped to one line and one rule, so
    a pragma can never blanket-silence a file.
  * **Baseline** — `analysis_baseline.json` entries match on
    (rule, file, stripped source text), *not* line numbers, so code
    motion doesn't rot them; every entry must still match a live
    finding or the scan fails with a stale-baseline error (exit 2),
    so fixed code can't leave a dead suppression behind.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Optional

PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([a-z0-9-]+)\]")


@dataclasses.dataclass
class Finding:
    """One typed finding: `rule` id, repo-relative `path`, 1-based
    `line`, human `message`, and the stripped source `snippet` (the
    baseline match key). `suppressed_by` is None for live findings,
    else "pragma" or "baseline"."""

    rule: str
    path: str
    line: int
    message: str
    snippet: str
    suppressed_by: Optional[str] = None

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> dict:
        d = {
            "rule": self.rule, "path": self.path, "line": self.line,
            "message": self.message, "snippet": self.snippet,
        }
        if self.suppressed_by:
            d["suppressed_by"] = self.suppressed_by
        return d


@dataclasses.dataclass
class FileInfo:
    """One parsed file handed to every rule: absolute `path`,
    repo-relative `rel`, the `ast` module tree, and raw `lines`."""

    path: Path
    rel: str
    tree: ast.Module
    lines: list

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = ""
        if 1 <= line <= len(self.lines):
            snippet = self.lines[line - 1].strip()
        return Finding(
            rule=rule, path=self.rel, line=line,
            message=message, snippet=snippet,
        )


@dataclasses.dataclass
class Context:
    """Cross-file state shared by all rules over one scan: the full
    file list (so two-pass rules like driver-thread-affinity can
    collect markers project-wide before flagging call sites)."""

    files: list
    driver_methods: set = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class ScanResult:
    findings: list          # live (unsuppressed) findings
    suppressed: list        # findings silenced by pragma/baseline
    stale_baseline: list    # baseline entries matching no finding
    files_scanned: int

    def to_report(self, schema_version: int, rules) -> dict:
        return {
            "report": "analysis",
            "schema_version": schema_version,
            "files_scanned": self.files_scanned,
            "rules": [
                {"id": r.rule_id, "summary": r.summary,
                 "incident": r.incident}
                for r in rules
            ],
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "stale_baseline": list(self.stale_baseline),
        }


def iter_py_files(paths) -> list:
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    # dedupe, stable order
    seen, uniq = set(), []
    for p in out:
        rp = p.resolve()
        if rp not in seen:
            seen.add(rp)
            uniq.append(p)
    return uniq


def parse_file(path: Path, root: Optional[Path] = None) -> Optional[FileInfo]:
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=str(path))
    except (OSError, SyntaxError):
        return None
    try:
        rel = str(path.resolve().relative_to((root or Path.cwd()).resolve()))
    except ValueError:
        rel = str(path)
    return FileInfo(
        path=path, rel=rel, tree=tree, lines=src.splitlines(),
    )


def pragma_allows(info: FileInfo, finding: Finding) -> bool:
    """True if the flagged line (or the one above) carries a
    `# repro: allow[<rule>]` pragma for this finding's rule."""
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(info.lines):
            for m in PRAGMA_RE.finditer(info.lines[ln - 1]):
                if m.group(1) == finding.rule:
                    return True
    return False


def load_baseline(path) -> list:
    """Baseline entries: [{"rule", "path", "snippet"}, ...]."""
    with open(path) as f:
        data = json.load(f)
    entries = data.get("entries", data) if isinstance(data, dict) else data
    out = []
    for e in entries:
        out.append({
            "rule": str(e["rule"]), "path": str(e["path"]),
            "snippet": str(e["snippet"]).strip(),
        })
    return out


def _baseline_key(f: Finding):
    return (f.rule, f.path, f.snippet.strip())


def scan_paths(paths, rules, baseline=None,
               root: Optional[Path] = None) -> ScanResult:
    """Run `rules` over every .py under `paths`; returns live and
    suppressed findings plus any stale baseline entries."""
    infos = []
    for p in iter_py_files(paths):
        info = parse_file(p, root=root)
        if info is not None:
            infos.append(info)
    ctx = Context(files=infos)
    for rule in rules:
        prep = getattr(rule, "prepare", None)
        if prep is not None:
            prep(ctx)

    live, suppressed = [], []
    matched = [False] * len(baseline or [])
    for info in infos:
        for rule in rules:
            for f in rule.check(ctx, info):
                if pragma_allows(info, f):
                    f.suppressed_by = "pragma"
                    suppressed.append(f)
                    continue
                key = _baseline_key(f)
                hit = False
                for i, e in enumerate(baseline or []):
                    if (e["rule"], e["path"], e["snippet"]) == key:
                        matched[i] = hit = True
                if hit:
                    f.suppressed_by = "baseline"
                    suppressed.append(f)
                else:
                    live.append(f)
    stale = [
        e for i, e in enumerate(baseline or []) if not matched[i]
    ]
    live.sort(key=lambda f: (f.path, f.line, f.rule))
    suppressed.sort(key=lambda f: (f.path, f.line, f.rule))
    return ScanResult(
        findings=live, suppressed=suppressed, stale_baseline=stale,
        files_scanned=len(infos),
    )
