"""CLI: `python -m repro.analysis [paths...] [options]`.

Scans the given paths (default: `src/ scripts/ benchmarks/ examples/`,
whichever exist) with the full rule pack,
prints typed `path:line: rule: message` findings, optionally writes
the JSON report `scripts/check_bench_schema.py` validates, and exits:

    0  clean (no unsuppressed findings, baseline fresh)
    1  unsuppressed findings
    2  usage error / unreadable baseline / stale baseline entries

`--baseline` defaults to `analysis_baseline.json` at the repo root
when it exists. `--no-fail` reports without gating (exploration mode);
CI runs the default gating behavior (`--fail-on-findings` is accepted
as an explicit alias).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro import analysis
from repro.analysis import astpass
from repro.analysis.rules import RULES


def find_root(start: Path) -> Path:
    """Nearest ancestor containing .git or pyproject.toml (else cwd) —
    findings are reported relative to it so baseline entries are
    machine-independent."""
    for p in [start] + list(start.parents):
        if (p / ".git").exists() or (p / "pyproject.toml").exists():
            return p
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific AST rule pack (see "
                    "docs/analysis_rules.md)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/ scripts/ "
                         "benchmarks/ examples/)")
    ap.add_argument("--json", metavar="OUT",
                    help="write the JSON analysis report here")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="baseline suppression file (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 on unsuppressed findings (the "
                         "default; kept explicit for CI readability)")
    ap.add_argument("--no-fail", action="store_true",
                    help="report only; always exit 0 unless the "
                         "baseline is stale")
    args = ap.parse_args(argv)

    root = find_root(Path.cwd())
    paths = args.paths or [
        p for p in (root / "src", root / "scripts",
                    root / "benchmarks", root / "examples")
        if p.exists()
    ] or [root / "src"]

    baseline, baseline_path = [], args.baseline
    if baseline_path is None:
        default = root / "analysis_baseline.json"
        if default.exists():
            baseline_path = str(default)
    if baseline_path:
        try:
            baseline = astpass.load_baseline(baseline_path)
        except (OSError, ValueError, KeyError) as e:
            print(f"[analysis] unreadable baseline "
                  f"{baseline_path}: {e}", file=sys.stderr)
            return 2

    result = astpass.scan_paths(paths, RULES, baseline=baseline, root=root)

    for f in result.findings:
        print(f"{f.location()}: {f.rule}: {f.message}")
    if args.json:
        report = result.to_report(analysis.SCHEMA_VERSION, RULES)
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)

    if result.stale_baseline:
        for e in result.stale_baseline:
            print(
                f"[analysis] STALE baseline entry (no live finding "
                f"matches): {e['rule']} @ {e['path']}: {e['snippet']!r}",
                file=sys.stderr,
            )
        print(
            f"[analysis] {len(result.stale_baseline)} stale baseline "
            f"entr{'y' if len(result.stale_baseline) == 1 else 'ies'} — "
            f"remove them from {baseline_path}", file=sys.stderr,
        )
        return 2

    n = len(result.findings)
    print(
        f"[analysis] {result.files_scanned} files, {len(RULES)} rules: "
        f"{n} finding(s), {len(result.suppressed)} suppressed"
    )
    if n and not args.no_fail:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
