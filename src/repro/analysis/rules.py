"""The repo-specific AST rule pack.

Every rule encodes a bug class this repo has already hit (or nearly
hit) at runtime — the PR number in each rule's `incident` points at
the CHANGES.md entry that motivated it; docs/analysis_rules.md is the
narrative catalog. Rules are deliberately repo-shaped: they know the
telemetry emission surface, the seating-cell names, and the frontend's
single-driver-thread convention, trading generality for a near-zero
false-positive rate on this codebase.

Each rule is an object with `rule_id` / `summary` / `incident` and a
`check(ctx, info) -> list[Finding]`; cross-file rules also implement
`prepare(ctx)` (run once over the whole file set before any check).
"""

from __future__ import annotations

import ast

# dotted-name helpers -------------------------------------------------------


def dotted(node) -> str:
    """'jax.random.normal' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def terminal_name(node) -> str:
    """Last identifier of a Name/Attribute ('scatter_pages' for
    `seating.scatter_pages`), '' otherwise."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def iter_scopes(tree):
    """Yield (scope_node, body_stmts) for the module and every
    function def, at any nesting depth."""
    yield tree, tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.body


def walk_scope(stmts):
    """Walk every node under `stmts` without descending into nested
    function/class defs (those get their own scope)."""
    stack = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            stack.append(child)


def _jit_decorated(fn) -> bool:
    """True if `fn` carries a jax.jit / @partial(jax.jit, ...)
    decorator."""
    for dec in fn.decorator_list:
        d = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(d)
        if name in ("jax.jit", "jit"):
            return True
        if name in ("functools.partial", "partial"):
            if (isinstance(dec, ast.Call) and dec.args
                    and dotted(dec.args[0]) in ("jax.jit", "jit")):
                return True
    return False


def _jit_static_names(fn) -> set:
    """Param names a jit decorator marks static (not traced)."""
    out = set()
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        kwargs = {k.arg: k.value for k in dec.keywords if k.arg}
        names = kwargs.get("static_argnames")
        if isinstance(names, ast.Constant) and isinstance(names.value, str):
            out.add(names.value)
        elif isinstance(names, (ast.Tuple, ast.List)):
            out.update(
                e.value for e in names.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            )
        nums = kwargs.get("static_argnums")
        idxs = []
        if isinstance(nums, ast.Constant) and isinstance(nums.value, int):
            idxs = [nums.value]
        elif isinstance(nums, (ast.Tuple, ast.List)):
            idxs = [
                e.value for e in nums.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)
            ]
        for i in idxs:
            if 0 <= i < len(params):
                out.add(params[i])
    return out


# ---------------------------------------------------------------------------
# np-index-dtype — the PR 8 `mark_urgent([])` class
# ---------------------------------------------------------------------------

_NP_CTORS = ("np.asarray", "np.array", "numpy.asarray", "numpy.array")
_INDEX_CONSUMERS = (
    "np.nonzero", "np.flatnonzero", "np.argwhere", "np.where",
    "numpy.nonzero", "numpy.flatnonzero", "numpy.argwhere", "numpy.where",
)
_BITOPS = (ast.BitOr, ast.BitAnd, ast.BitXor)


def _unpinned_np_call(node):
    """The np.asarray/np.array Call node if it has no dtype pin."""
    if (isinstance(node, ast.Call) and dotted(node.func) in _NP_CTORS
            and len(node.args) == 1
            and not any(k.arg == "dtype" for k in node.keywords)):
        return node
    return None


class NpIndexDtypeRule:
    rule_id = "np-index-dtype"
    summary = ("dtype-unpinned np.asarray/np.array result used as an "
               "index or boolean mask")
    incident = ("PR 8: `mark_urgent([])` — an empty Python list becomes "
                "float64, crashing integer indexing only on the "
                "empty-input path")

    def check(self, ctx, info):
        findings = []
        flagged = set()

        def flag(call_node, how):
            if id(call_node) in flagged:
                return
            flagged.add(id(call_node))
            findings.append(info.finding(
                self.rule_id, call_node,
                f"{ast.unparse(call_node.func)}(...) without an explicit "
                f"dtype is {how}; an empty input defaults to float64 "
                f"(pin dtype=bool / np.intp)",
            ))

        for _scope, body in iter_scopes(info.tree):
            tracked = {}
            for node in walk_scope(body):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    call = _unpinned_np_call(node.value)
                    if call is not None:
                        tracked[node.targets[0].id] = call

            def resolve(expr):
                call = _unpinned_np_call(expr)
                if call is not None:
                    return call
                if isinstance(expr, ast.Name):
                    return tracked.get(expr.id)
                return None

            for node in walk_scope(body):
                if isinstance(node, ast.Subscript):
                    idx = node.slice
                    parts = idx.elts if isinstance(idx, ast.Tuple) else [idx]
                    for p in parts:
                        call = resolve(p)
                        if call is not None:
                            flag(call, "used as a subscript index")
                elif isinstance(node, ast.BinOp) and isinstance(
                        node.op, _BITOPS):
                    for p in (node.left, node.right):
                        call = resolve(p)
                        if call is not None:
                            flag(call, "combined with a bitwise mask op")
                elif isinstance(node, ast.UnaryOp) and isinstance(
                        node.op, ast.Invert):
                    call = resolve(node.operand)
                    if call is not None:
                        flag(call, "inverted as a boolean mask")
                elif (isinstance(node, ast.Call)
                      and dotted(node.func) in _INDEX_CONSUMERS):
                    for p in node.args:
                        call = resolve(p)
                        if call is not None:
                            flag(call, "fed to an index-producing "
                                       "numpy reduction")
        return findings


# ---------------------------------------------------------------------------
# prng-key-reuse
# ---------------------------------------------------------------------------

_KEY_NONCONSUMING = {
    "split", "fold_in", "PRNGKey", "key", "wrap_key_data", "key_data",
    "clone", "key_impl",
}


def _key_expr_text(node):
    """Stable text for a key argument worth tracking: a bare name or a
    constant-indexed subscript (`ks[0]`)."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and isinstance(node.slice, ast.Constant)):
        return f"{node.value.id}[{node.slice.value!r}]"
    return None


def _base_name(text):
    return text.split("[")[0]


class PrngKeyReuseRule:
    rule_id = "prng-key-reuse"
    summary = ("PRNG key consumed by two samplers without a split/"
               "fold_in between the uses")
    incident = ("PR 3/7: every serving/stream path derives per-request "
                "keys via fold_in; reusing a raw key correlates "
                "'independent' samples silently")

    def check(self, ctx, info):
        findings = []
        for scope, body in iter_scopes(info.tree):
            if isinstance(scope, ast.Module):
                continue
            events = []
            self._collect(body, (), events)
            last_use = {}
            for kind, text, node, path in events:
                if kind == "assign":
                    for t in list(last_use):
                        if _base_name(t) == text:
                            del last_use[t]
                    continue
                prev = last_use.get(text)
                if prev is not None and self._compatible(prev[1], path):
                    findings.append(info.finding(
                        self.rule_id, node,
                        f"PRNG key `{text}` already consumed on line "
                        f"{prev[0].lineno}; split or fold_in before "
                        f"sampling again",
                    ))
                last_use[text] = (node, path)
        return findings

    @staticmethod
    def _compatible(a, b):
        """True unless the two branch paths take different arms of the
        same `if` (mutually exclusive code)."""
        arms_a = dict(a)
        return all(arms_a.get(i, arm) == arm for i, arm in b)

    @staticmethod
    def _terminates(stmts) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break)
        )

    def _collect(self, stmts, path, events):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.If):
                self._stmt_events(st.test, path, events)
                self._collect(st.body, path + ((id(st), 0),), events)
                self._collect(st.orelse, path + ((id(st), 1),), events)
                # a terminating arm means the rest of this block only
                # runs on the *other* arm — keeps `if ...: return`
                # ladders (mutually exclusive uses) from conflicting
                if self._terminates(st.body):
                    path = path + ((id(st), 1),)
                elif self._terminates(st.orelse):
                    path = path + ((id(st), 0),)
                continue
            self._stmt_events(st, path, events)

    def _stmt_events(self, st, path, events):
        for node in ast.walk(st):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if (name.startswith("jax.random.")
                        and name.rsplit(".", 1)[1]
                        not in _KEY_NONCONSUMING and node.args):
                    text = _key_expr_text(node.args[0])
                    if text is not None:
                        events.append(("use", text, node, path))
            elif isinstance(node, (ast.Assign, ast.AugAssign,
                                   ast.AnnAssign, ast.For)):
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    elts = t.elts if isinstance(
                        t, (ast.Tuple, ast.List)) else [t]
                    for e in elts:
                        if isinstance(e, ast.Name):
                            events.append(("assign", e.id, e, path))
        events.sort(key=lambda ev: (ev[2].lineno, ev[2].col_offset))


# ---------------------------------------------------------------------------
# traced-python-branch — the recompile/ConcretizationError class
# ---------------------------------------------------------------------------

_SAFE_ATTRS = {"shape", "ndim", "dtype", "size"}
_SAFE_CALLS = {"len", "isinstance", "hasattr"}


class TracedPythonBranchRule:
    rule_id = "traced-python-branch"
    summary = ("Python if/while on a traced value inside a jitted "
               "function (ConcretizationError or a silent recompile "
               "per value)")
    incident = ("PR 6: the recompile-visibility work exists because "
                "value-dependent Python control flow turns one "
                "compiled cell into one cache entry per value")

    def check(self, ctx, info):
        findings = []
        for node in ast.walk(info.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not _jit_decorated(node):
                continue
            a = node.args
            params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
            params -= _jit_static_names(node)
            for sub in ast.walk(node):
                if isinstance(sub, (ast.If, ast.While)):
                    bad = self._traced_names(sub.test, params)
                    if bad:
                        kw = "while" if isinstance(sub, ast.While) else "if"
                        findings.append(info.finding(
                            self.rule_id, sub,
                            f"Python `{kw}` on traced value(s) "
                            f"{sorted(bad)} inside jitted "
                            f"`{node.name}`; use jnp.where / "
                            f"lax.cond or mark the arg static",
                        ))
        return findings

    @staticmethod
    def _traced_names(test, params):
        safe_ids = set()
        for node in ast.walk(test):
            if (isinstance(node, ast.Attribute)
                    and node.attr in _SAFE_ATTRS):
                for sub in ast.walk(node):
                    safe_ids.add(id(sub))
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in _SAFE_CALLS):
                for sub in ast.walk(node):
                    safe_ids.add(id(sub))
            elif (isinstance(node, ast.Compare)
                  and all(isinstance(op, (ast.Is, ast.IsNot))
                          for op in node.ops)
                  and all(isinstance(c, ast.Constant)
                          for c in node.comparators)):
                for sub in ast.walk(node):
                    safe_ids.add(id(sub))
        return {
            n.id for n in ast.walk(test)
            if isinstance(n, ast.Name) and n.id in params
            and id(n) not in safe_ids
        }


# ---------------------------------------------------------------------------
# jit-donate-pool — seating cells must donate the pool
# ---------------------------------------------------------------------------

_POOL_FUNCS = {"scatter_slots", "scatter_pages"}


class JitDonatePoolRule:
    rule_id = "jit-donate-pool"
    summary = ("pool-mutating function jitted without donate_argnums "
               "(doubles pool-cache residency per call)")
    incident = ("PR 9: seating cells donate the pool cache "
                "(donate_argnums=0) so paged admission updates in "
                "place instead of copying the whole pool")

    def check(self, ctx, info):
        pool_defs = {
            n.name for n in ast.walk(info.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and (n.args.posonlyargs + n.args.args)
            and (n.args.posonlyargs + n.args.args)[0].arg == "pool"
        }
        findings = []
        for node in ast.walk(info.tree):
            if not (isinstance(node, ast.Call)
                    and dotted(node.func) in ("jax.jit", "jit")
                    and node.args):
                continue
            if any(k.arg == "donate_argnums" for k in node.keywords):
                continue
            target = self._pool_target(node.args[0], pool_defs)
            if target:
                findings.append(info.finding(
                    self.rule_id, node,
                    f"jax.jit({target}) mutates its pool argument but "
                    f"declares no donate_argnums — the old pool buffer "
                    f"stays live across the call",
                ))
        return findings

    def _pool_target(self, fn, pool_defs):
        name = terminal_name(fn)
        if name in _POOL_FUNCS or name in pool_defs:
            return name
        if (isinstance(fn, ast.Call)
                and dotted(fn.func) in ("functools.partial", "partial")
                and fn.args):
            return self._pool_target(fn.args[0], pool_defs)
        if isinstance(fn, ast.Lambda):
            a = fn.args
            if (a.posonlyargs + a.args) and (
                    a.posonlyargs + a.args)[0].arg == "pool":
                return "<lambda pool=...>"
        return None


# ---------------------------------------------------------------------------
# driver-thread-affinity — the frontend single-driver-thread invariant
# ---------------------------------------------------------------------------


class DriverThreadAffinityRule:
    rule_id = "driver-thread-affinity"
    summary = ("@driver_thread_only method called from code inside an "
               "async def (event-loop thread)")
    incident = ("PR 8: the frontend's engines are single-threaded by "
                "contract — exactly one driver thread may touch "
                "Engine/MicroBatchScheduler state; async handlers must "
                "go through the inbox")

    def prepare(self, ctx):
        for info in ctx.files:
            for node in ast.walk(info.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        d = dec.func if isinstance(dec, ast.Call) else dec
                        if terminal_name(d) == "driver_thread_only":
                            ctx.driver_methods.add(node.name)

    def check(self, ctx, info):
        if not ctx.driver_methods:
            return []
        findings = []
        for node in ast.walk(info.tree):
            if not isinstance(node, ast.AsyncFunctionDef):
                continue
            containers = self._container_locals(node)
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in ctx.driver_methods
                        and self._receiver_base(sub.func)
                        not in containers):
                    findings.append(info.finding(
                        self.rule_id, sub,
                        f"`.{sub.func.attr}(...)` is "
                        f"@driver_thread_only but is called inside "
                        f"async `{node.name}` (event-loop thread); "
                        f"post through the driver inbox instead",
                    ))
        return findings

    @staticmethod
    def _receiver_base(attr):
        node = attr.value
        while isinstance(node, ast.Attribute):
            node = node.value
        return node.id if isinstance(node, ast.Name) else None

    @staticmethod
    def _container_locals(fn) -> set:
        """Locals bound to plain containers (`x = []`, `x = list()`):
        their `.extend`/`.submit` etc. are builtin methods sharing a
        marked name, not driver-thread surfaces."""
        out = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            v = node.value
            is_container = isinstance(
                v, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                    ast.DictComp, ast.SetComp)
            ) or (
                isinstance(v, ast.Call)
                and isinstance(v.func, ast.Name)
                and v.func.id in ("list", "dict", "set", "deque")
            )
            if is_container:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
        return out


# ---------------------------------------------------------------------------
# telemetry-eager-format — the disabled-path nanosecond budget
# ---------------------------------------------------------------------------

_EMIT_ATTRS = {"counter", "gauge", "histogram", "span", "instant"}


class TelemetryEagerFormatRule:
    rule_id = "telemetry-eager-format"
    summary = ("string formatting evaluated on a telemetry emission "
               "path even when telemetry is disabled")
    incident = ("PR 6: disabled emission must cost nanoseconds "
                "(tests/test_obs.py asserts the stream loop's <3% "
                "budget); an f-string metric name formats "
                "unconditionally")

    def check(self, ctx, info):
        findings = []
        self._visit(info, info.tree.body, False, findings)
        return findings

    def _visit(self, info, stmts, guarded, findings):
        for st in stmts:
            g = guarded
            if isinstance(st, ast.If) and self._enabled_guard(st.test):
                self._scan_expr(info, st.test, guarded, findings)
                self._visit(info, st.body, True, findings)
                self._visit(info, st.orelse, guarded, findings)
                continue
            for child in ast.iter_child_nodes(st):
                if isinstance(child, (ast.stmt,)):
                    continue
                self._scan_expr(info, child, g, findings)
            body_fields = [
                getattr(st, f) for f in ("body", "orelse", "finalbody")
                if getattr(st, f, None)
            ]
            for body in body_fields:
                self._visit(info, body, g, findings)
            for h in getattr(st, "handlers", []) or []:
                self._visit(info, h.body, g, findings)

    @staticmethod
    def _enabled_guard(test) -> bool:
        for node in ast.walk(test):
            if isinstance(node, ast.Attribute) and node.attr == "enabled":
                return True
            if isinstance(node, ast.Name) and node.id == "enabled":
                return True
        return False

    def _scan_expr(self, info, expr, guarded, findings):
        if guarded or expr is None:
            return
        for node in ast.walk(expr):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _EMIT_ATTRS):
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if self._formats(arg):
                    findings.append(info.finding(
                        self.rule_id, node,
                        f"`.{node.func.attr}(...)` argument does "
                        f"string formatting unconditionally; guard "
                        f"with `if tel.enabled:` or precompute the "
                        f"name",
                    ))
                    break

    @staticmethod
    def _formats(arg) -> bool:
        if isinstance(arg, ast.JoinedStr) and any(
                isinstance(v, ast.FormattedValue) for v in arg.values):
            return True
        if isinstance(arg, ast.BinOp) and isinstance(
                arg.op, (ast.Mod, ast.Add)):
            return any(
                isinstance(s, ast.Constant) and isinstance(s.value, str)
                for s in (arg.left, arg.right)
            )
        if (isinstance(arg, ast.Call)
                and isinstance(arg.func, ast.Attribute)
                and arg.func.attr == "format"):
            return True
        return False


# ---------------------------------------------------------------------------
# numpy-in-jit — host numpy inside a traced function
# ---------------------------------------------------------------------------


class NumpyInJitRule:
    rule_id = "numpy-in-jit"
    summary = ("host numpy call inside a jitted function (constant-"
               "folds a traced value or forces a host sync)")
    incident = ("PR 2: stream classify cells are pure jnp so the "
                "bucket cells stay device-resident; np.* inside jit "
                "either crashes on tracers or silently freezes values")

    def check(self, ctx, info):
        findings = []
        for node in ast.walk(info.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not _jit_decorated(node):
                continue
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Call)
                        and dotted(sub.func).split(".")[0]
                        in ("np", "numpy")):
                    findings.append(info.finding(
                        self.rule_id, sub,
                        f"`{ast.unparse(sub.func)}(...)` inside jitted "
                        f"`{node.name}`; use jnp (host numpy can't see "
                        f"tracers)",
                    ))
        return findings


# ---------------------------------------------------------------------------
# mutable-default
# ---------------------------------------------------------------------------


class MutableDefaultRule:
    rule_id = "mutable-default"
    summary = "mutable default argument shared across calls"
    incident = ("PR 7: lineage tags accumulate per call — a shared "
                "default dict would bleed tags across requests")

    def check(self, ctx, info):
        findings = []
        for node in ast.walk(info.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            for default in list(node.args.defaults) + [
                    d for d in node.args.kw_defaults if d is not None]:
                mutable = isinstance(
                    default, (ast.List, ast.Dict, ast.Set)
                ) or (
                    isinstance(default, ast.Call)
                    and isinstance(default.func, ast.Name)
                    and default.func.id in ("list", "dict", "set")
                )
                if mutable:
                    findings.append(info.finding(
                        self.rule_id, default,
                        f"mutable default in `{node.name}(...)` is "
                        f"shared across calls; default to None and "
                        f"allocate inside",
                    ))
        return findings


# ---------------------------------------------------------------------------
# broad-except-pass
# ---------------------------------------------------------------------------


class BroadExceptPassRule:
    rule_id = "broad-except-pass"
    summary = "bare/broad except that swallows the error with pass"
    incident = ("PR 8: serve/stream errors must surface as typed "
                "rejections or driver-thread faults; a swallowed "
                "exception is a silent SLO breach")

    def check(self, ctx, info):
        findings = []
        for node in ast.walk(info.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            broad = node.type is None or dotted(node.type) in (
                "Exception", "BaseException",
            )
            silent = all(
                isinstance(st, ast.Pass)
                or (isinstance(st, ast.Expr)
                    and isinstance(st.value, ast.Constant))
                for st in node.body
            )
            if broad and silent:
                findings.append(info.finding(
                    self.rule_id, node,
                    "broad except swallows the error; at minimum "
                    "count it on a telemetry counter or narrow the "
                    "type",
                ))
        return findings


# ---------------------------------------------------------------------------
# wallclock-in-measurement
# ---------------------------------------------------------------------------


class WallclockRule:
    rule_id = "wallclock-ban"
    summary = ("time.time() in library code (NTP-steppable; use "
               "perf_counter/monotonic, or pragma for metadata)")
    incident = ("PR 7: latency accounting is perf_counter end to end "
                "so coordinated-omission math can't be skewed by "
                "clock steps")

    def check(self, ctx, info):
        findings = []
        for node in ast.walk(info.tree):
            if (isinstance(node, ast.Call)
                    and dotted(node.func) == "time.time"):
                findings.append(info.finding(
                    self.rule_id, node,
                    "time.time() is wall clock; measurement code must "
                    "use time.perf_counter()/monotonic() (metadata "
                    "timestamps: suppress with a pragma)",
                ))
        return findings


RULES = (
    NpIndexDtypeRule(),
    PrngKeyReuseRule(),
    TracedPythonBranchRule(),
    JitDonatePoolRule(),
    DriverThreadAffinityRule(),
    TelemetryEagerFormatRule(),
    NumpyInJitRule(),
    MutableDefaultRule(),
    BroadExceptPassRule(),
    WallclockRule(),
)
