"""Textual checks over one compiled cell's optimized HLO.

Pure functions over `compiled.as_text()` — no jax imports — so the
scans are unit-testable against literal HLO snippets. The collective
inventory reuses `repro.launch.hlo_count.weighted_cost` (loop-aware:
a collective inside a while body counts once per trip), which is the
same parser tests/test_hlo_count.py pins down.

What each scan encodes:

  * **f64** — the accelerator story is mixed *low* precision (int4/
    int8 activations, f32 accumulation at most); a single f64 op means
    an unpinned Python float/np default leaked into a traced value.
  * **host ops** — decode/stream/train hot cells must stay device-
    resident: callbacks lower to `custom-call` targets carrying
    "callback"/"python" markers, and infeed/outfeed/send/recv are
    host-transfer primitives by definition.
  * **donation** — when a jit declares `donate_argnums`, the optimized
    module header must carry an `input_output_alias` map; XLA dropping
    the donation (shape/layout mismatch) silently doubles the pool's
    memory residency.
"""

from __future__ import annotations

import re

from repro.launch.hlo_count import weighted_cost

F64_RE = re.compile(r"\bf64\[")
_HOST_OPS = ("infeed(", "outfeed(", "send(", "send-done(",
             "recv(", "recv-done(")
_HOST_CUSTOM_CALL_MARKERS = ("callback", "python", "host")
_CUSTOM_CALL_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')
ALIAS_RE = re.compile(r"input_output_alias=\{([^}]*(?:\{[^}]*\}[^}]*)*)\}")


def f64_lines(text: str) -> list:
    """1-based line numbers of ops touching an f64 type."""
    return [
        i for i, line in enumerate(text.splitlines(), 1)
        if F64_RE.search(line)
    ]


def host_transfer_ops(text: str) -> list:
    """Host-boundary ops in the module: infeed/outfeed/send/recv plus
    custom-calls whose target smells like a Python host callback."""
    out = []
    for i, line in enumerate(text.splitlines(), 1):
        s = line.strip()
        op = s.split("=", 1)[-1].lstrip()
        if any(op.startswith(h) or f" {h}" in op for h in _HOST_OPS):
            out.append((i, op.split("(", 1)[0].strip()))
            continue
        m = _CUSTOM_CALL_TARGET_RE.search(line)
        if m and any(k in m.group(1).lower()
                     for k in _HOST_CUSTOM_CALL_MARKERS):
            out.append((i, m.group(1)))
    return out


def has_input_output_alias(text: str) -> bool:
    """True if the HloModule header declares any input/output alias —
    the positive signal that a declared donation survived XLA."""
    for line in text.splitlines():
        if line.startswith("HloModule"):
            m = ALIAS_RE.search(line)
            return bool(m and m.group(1).strip())
    return False


def collective_counts(text: str) -> dict:
    """op name -> loop-aware occurrence count in the optimized module."""
    return {
        k: int(v)
        for k, v in weighted_cost(text).collective_counts.items()
        if v
    }


def over_budget(counts: dict, budget: dict) -> list:
    """(op, count, allowed) rows where the inventory exceeds the
    declared budget. `budget` maps op name -> max count; ops absent
    from the budget are allowed zero occurrences; an allowance of
    "*" (or a negative count) means unbounded."""
    rows = []
    for op, n in sorted(counts.items()):
        allowed = budget.get(op, 0)
        if allowed == "*" or (isinstance(allowed, int) and allowed < 0):
            continue
        if n > int(allowed):
            rows.append((op, n, int(allowed)))
    return rows
