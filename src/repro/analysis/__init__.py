"""`repro.analysis`: repo-specific static analysis, gating CI.

Two halves, one contract — the bug classes this repo has already paid
for at runtime must fail CI *before* they ship:

  * **AST rule pack** (`astpass`, `rules`) — ≥8 lints, each encoding a
    historical incident from CHANGES.md: the PR 8 `mark_urgent([])`
    float64-index crash becomes `np-index-dtype`; the PR 6 silent
    double-compile family becomes `traced-python-branch` /
    `numpy-in-jit`; the PR 8 single-driver-thread convention becomes
    `driver-thread-affinity`; the PR 6 disabled-telemetry overhead
    budget becomes `telemetry-eager-format`; and so on (see
    docs/analysis_rules.md for the full catalog).
  * **Compiled-cell auditor** (`cellaudit`, `hloscan`) — walks the
    `obs.jaxprobe` named-cell registry after benchmark warmup, re-lowers
    every cell from its captured call avals, and asserts zero host
    callbacks, zero f64 ops, zero dropped donations, declared-sharded
    outputs actually sharded, and a collective inventory within each
    cell's declared comm budget (generalizing tests/test_hlo_count.py
    from hand-picked cases to every registered cell).

CLI: `python -m repro.analysis [paths] [--json OUT]`; exit 0 clean,
1 on unsuppressed findings, 2 on usage errors or a stale baseline.
Suppression: `# repro: allow[rule-id] reason` on (or one line above)
the flagged line, or a checked-in `analysis_baseline.json` whose every
entry must still match a live finding.
"""

from __future__ import annotations

from repro.analysis.astpass import (  # noqa: F401
    Finding,
    ScanResult,
    load_baseline,
    scan_paths,
)
from repro.analysis.cellaudit import audit_cells, audit_section  # noqa: F401
from repro.analysis.rules import RULES  # noqa: F401

SCHEMA_VERSION = 1
