"""Compiled-cell auditor: re-lower every registered jit cell and
assert its static safety properties.

Walks `obs.jaxprobe`'s named-cell registry (`probe.cells()`), re-traces
each cell from the argument avals its `TrackedCell` wrapper captured at
first real call, and checks — before any of this ships — the
properties the benchmarks used to assert only pointwise:

  * **captured** — a registered cell that was never called has no
    avals; coverage is part of the contract, so that's a violation,
    not a skip.
  * **no host callbacks** — the jaxpr holds no callback primitives
    (`pure_callback` / `io_callback` / `debug_callback`), and the
    optimized HLO no infeed/outfeed/send/recv or python-callback
    custom-calls (`hloscan.host_transfer_ops`).
  * **no f64** — mixed-bit-width means *down*, never up; an f64 type
    anywhere in the module is an unpinned-default leak.
  * **donation honored** — cells declaring `donate=(...)` must lower
    without XLA's "donated buffers were not usable" warning and carry
    an `input_output_alias` in the module header.
  * **sharded outputs stay sharded** — cells declaring
    `sharded_outputs=True` must not compile to all-fully-replicated
    outputs (the PR 4/9 silent-replication class).
  * **collective budget** — the loop-aware collective inventory must
    stay within the cell's declared `budget` (op -> max count; absent
    ops are allowed zero; cells with no declared budget skip this
    gate), generalizing tests/test_hlo_count.py to every registered
    cell.

The AOT path (`fn.trace(...).lower().compile()`) does not populate the
jit dispatch cache, so auditing after warmup does not disturb the
zero-recompile guards the benchmarks also assert.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.analysis import hloscan


@dataclasses.dataclass
class CellAudit:
    """Audit outcome for one cell; `violations` empty means clean."""

    name: str
    violations: list
    collectives: dict = dataclasses.field(default_factory=dict)
    donation_aliased: Optional[bool] = None

    def to_dict(self) -> dict:
        return {
            "violations": list(self.violations),
            "collectives": dict(self.collectives),
            "donation_aliased": self.donation_aliased,
        }


_CALLBACK_MARKERS = ("callback", "outside_call")


def _jaxpr_callbacks(jaxpr, out=None) -> list:
    """Names of callback primitives anywhere in a (closed) jaxpr,
    including sub-jaxprs carried in eqn params (scan/while/cond/...)."""
    out = [] if out is None else out
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if any(m in name for m in _CALLBACK_MARKERS):
            out.append(name)
        for p in eqn.params.values():
            for sub in p if isinstance(p, (tuple, list)) else (p,):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    _jaxpr_callbacks(sub, out)
    return out


def audit_cell(info) -> CellAudit:
    """Audit one `obs.jaxprobe.CellInfo`; never raises — failures to
    trace/lower are themselves violations."""
    v = []
    if info.call_avals is None:
        return CellAudit(name=info.name, violations=[
            "never called: no argument avals captured, cell is "
            "unaudited (warmup must cover every registered cell)"
        ])
    args, kwargs = info.call_avals
    try:
        traced = info.fn.trace(*args, **kwargs)
    except Exception as e:  # noqa: BLE001 — report, don't crash the audit
        return CellAudit(name=info.name, violations=[
            f"trace from captured avals failed: {type(e).__name__}: {e}"
        ])

    for name in sorted(set(_jaxpr_callbacks(traced.jaxpr))):
        v.append(f"host callback primitive in jaxpr: {name}")

    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            compiled = traced.lower().compile()
        for w in caught:
            if "donat" in str(w.message).lower():
                v.append(f"dropped donation: {w.message}")
    except Exception as e:  # noqa: BLE001
        v.append(f"lower/compile failed: {type(e).__name__}: {e}")
        return CellAudit(name=info.name, violations=v)

    text = compiled.as_text()
    f64 = hloscan.f64_lines(text)
    if f64:
        v.append(
            f"{len(f64)} f64 op(s) in optimized HLO "
            f"(first at line {f64[0]})"
        )
    for line, op in hloscan.host_transfer_ops(text):
        v.append(f"host transfer op in optimized HLO: {op} (line {line})")

    aliased = None
    if info.donate:
        aliased = hloscan.has_input_output_alias(text)
        if not aliased:
            v.append(
                f"declared donate={tuple(info.donate)} but the module "
                f"header has no input_output_alias — donation dropped"
            )

    counts = hloscan.collective_counts(text)
    if info.budget is not None:
        # unbudgeted cells skip the inventory gate (a declared budget
        # of {} means "zero collectives allowed" — different thing)
        for op, n, allowed in hloscan.over_budget(counts, info.budget):
            v.append(
                f"collective budget exceeded: {op} x{n} > {allowed} "
                f"(declared budget {info.budget})"
            )

    if info.sharded_outputs:
        try:
            import jax

            leaves = jax.tree.leaves(compiled.output_shardings)
            if leaves and all(
                    s.is_fully_replicated for s in leaves):
                v.append(
                    "declared sharded_outputs but every compiled "
                    "output is fully replicated"
                )
        except Exception as e:  # noqa: BLE001
            v.append(f"output-sharding check failed: {e}")

    return CellAudit(
        name=info.name, violations=v, collectives=counts,
        donation_aliased=aliased,
    )


def audit_cells(cells=None) -> dict:
    """name -> CellAudit over `cells` (default: the live probe's
    registry)."""
    if cells is None:
        from repro import obs

        cells = obs.get().probe.cells()
    return {name: audit_cell(info) for name, info in cells.items()}


def audit_section(cells=None) -> dict:
    """JSON-able BENCH record section; benchmarks attach this under
    "cell_audit" and assert violations_total == 0."""
    audits = audit_cells(cells)
    return {
        "n_cells": len(audits),
        "violations_total": sum(
            len(a.violations) for a in audits.values()
        ),
        "cells": {name: a.to_dict() for name, a in audits.items()},
    }
