"""Fleet streaming driver: continuous multi-patient VA monitoring.

  PYTHONPATH=src python -m repro.launch.stream --patients 256 \\
      --segments 8 --buckets 8,32,128,256 --devices 4

Builds a data-axis mesh over the first `--devices` host devices, trains
nothing (weights are random — the point is the serving path), compiles
the accelerator program, and drives the `repro.stream` fleet simulation:
virtual-time arrivals with jitter/dropout, deadline-aware micro-batching
with urgent-patient preemption, sharded bucketed inference, vectorized
6-segment voting. Prints the fleet metrics summary.

To exercise a multi-device mesh on a CPU host, force host devices
*before* any jax import:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.launch.stream --devices 8 ...
"""

from __future__ import annotations

import argparse
import json

import jax

from repro import obs
from repro.core import compiler, vadetect
from repro.stream import FleetConfig, simulate


def make_data_mesh(n_devices: int) -> jax.sharding.Mesh | None:
    """1-D data-parallel mesh over the first n host devices."""
    if n_devices <= 1:
        return None
    avail = jax.device_count()
    if n_devices > avail:
        raise SystemExit(
            f"--devices {n_devices} > available {avail}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices}"
        )
    return jax.make_mesh(
        (n_devices,),
        ("data",),
        devices=jax.devices()[:n_devices],
        axis_types=(jax.sharding.AxisType.Auto,),
    )


def stream_load_sweep(args, program, buckets, mesh) -> None:
    """Open-loop offered-load sweep in virtual time: per-patient
    Poisson/trace segment arrivals at fractions of the modeled fleet
    capacity, latency from intended arrival, knee location, pinned
    URGENT-cohort deadline-slack SLO, overload verdict
    (see `repro.obs.loadlab`). Exactly reproducible on any host."""
    from repro.obs import loadlab
    from repro.stream import FleetRunner

    runner = FleetRunner(program, path=args.path, mesh=mesh)
    fractions = tuple(float(f) for f in args.load_fractions.split(","))
    out = loadlab.sweep_stream(
        n_patients=args.patients,
        buckets=buckets,
        load_fractions=fractions,
        segments_at_capacity=args.segments_at_capacity,
        seed=args.seed,
        urgent_fraction=args.urgent_fraction,
        process=args.arrival_process,
        runner=runner,
    )
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")
    if args.json:
        print(json.dumps(out, indent=1, default=float))
        return
    print(
        f"[stream] open-loop sweep: {args.patients} patients, "
        f"buckets={list(buckets)}, capacity "
        f"{out['capacity_segments_per_s']:.0f} seg/s, "
        f"{args.arrival_process} arrivals"
    )
    for p in out["points"]:
        print(
            f"[stream]   {p['load_fraction']:>5.2f}x  "
            f"offered {p['offered_load']:9.0f}/s  "
            f"p50 {p['p50_s'] * 1e3:7.2f}ms  "
            f"p99 {p['p99_s'] * 1e3:7.2f}ms  "
            f"p99.9 {p['p999_s'] * 1e3:7.2f}ms  "
            f"dropped={p['dropped']}"
        )
    k = out["knee"]
    if k.get("detected"):
        print(
            f"[stream] saturation knee @ {k['knee_rate']:.0f} seg/s "
            f"(p99 grows {k['post_knee_growth']:.1f}x past it)"
        )
    print(
        f"[stream] URGENT cohort ({out['urgent_patients']} patients) "
        f"overload burn rate "
        f"{out['slo']['urgent_overload'].get('burn_rate'):.2f}; "
        f"verdict = {out['overload']['verdict']}"
    )


def stream_listen(args, program, buckets, mesh) -> None:
    """Accept patient segments over the serving-frontend socket
    transport (`repro.serve.frontend`): ROUTINE segments are deferred
    (never dropped) past --stream-rate, URGENT always pass and flip
    the scheduler's preemption bitmap."""
    import asyncio

    from repro.serve.frontend import Frontend, FrontendConfig
    from repro.stream import FleetRunner

    host, _, port = args.listen.rpartition(":")
    fe = Frontend(
        n_patients=args.patients,
        runner=FleetRunner(program, path=args.path, mesh=mesh),
        cfg=FrontendConfig(
            stream_rate_rps=args.stream_rate,
            stream_buckets=buckets,
            stream_max_wait_s=args.max_wait,
        ),
    )
    fe.warm()

    async def amain() -> None:
        bound = await fe.start(host or "127.0.0.1", int(port))
        print(f"[stream] frontend listening on "
              f"{bound[0]}:{bound[1]} ({args.patients} patients, "
              f"routine rate: {args.stream_rate or 'unbounded'})")
        try:
            await asyncio.Event().wait()
        finally:
            await fe.stop()

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        print("[stream] frontend stopped")


def stream_connect(args) -> None:
    """Open-loop socket client: offer --patients x --segments patient
    segments at --offered-rate seg/s (first --urgent-fraction of
    patients URGENT), then drain and report the ack ledger."""
    import asyncio
    import time

    from repro.obs import loadlab
    from repro.serve.frontend import SocketClient

    host, _, port = args.connect.rpartition(":")
    n_urgent = max(1, int(round(args.urgent_fraction * args.patients)))
    total = args.patients * args.segments
    intended = loadlab.arrival_times(
        jax.random.PRNGKey(args.seed), 0, rate_hz=args.offered_rate,
        n=total, process=args.arrival_process,
    )

    async def amain():
        client = await SocketClient.connect(host or "127.0.0.1",
                                            int(port))
        futs = []
        t0 = time.perf_counter()
        for i in range(total):
            delay = intended[i] - (time.perf_counter() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
            p, s = i % args.patients, i // args.patients
            futs.append(await client.send_segment(
                patient=p, seq=s, urgent=p < n_urgent
            ))
        acks = [await asyncio.wait_for(f, 60.0) for f in futs]
        stats = (await client.drain()).get("stats", {})
        await client.close()
        return acks, stats

    acks, stats = asyncio.run(amain())
    by = {}
    for a in acks:
        by[a["status"]] = by.get(a["status"], 0) + 1
    print(f"[stream] {total} segments offered at "
          f"{args.offered_rate:.1f}/s ({n_urgent} urgent patients): "
          f"acks {by}")
    enq = stats.get("sched_enqueued_total", 0)
    packed = stats.get("sched_packed_total", 0)
    print(f"[stream] drained: enqueued={enq} packed={packed} "
          f"left-behind={enq - packed} "
          f"deferred={stats.get('seg_deferred', 0)}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--patients", type=int, default=256)
    ap.add_argument("--segments", type=int, default=8,
                    help="segments per patient over the horizon")
    ap.add_argument("--buckets", default="8,32,128,256")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--path", default="twin",
                    choices=["twin", "reference", "kernel", "dense"])
    ap.add_argument("--va-fraction", type=float, default=0.05)
    ap.add_argument("--jitter", type=float, default=0.05,
                    help="arrival jitter std as a fraction of 2.048s")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-segment telemetry-gap probability")
    ap.add_argument("--max-wait", type=float, default=0.256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load-sweep", action="store_true",
                    help="run the open-loop offered-load sweep "
                         "(repro.obs.loadlab, virtual time) instead "
                         "of the periodic-arrival simulation")
    ap.add_argument("--load-fractions",
                    default="0.25,0.5,0.75,1.0,1.5,2.0",
                    help="offered load as fractions of the modeled "
                         "capacity (comma-separated)")
    ap.add_argument("--segments-at-capacity", type=int, default=1024,
                    help="virtual horizon, expressed as segments "
                         "offered by the 1.0x point")
    ap.add_argument("--urgent-fraction", type=float, default=0.125,
                    help="pinned URGENT cohort fraction for the "
                         "class-survival SLO")
    ap.add_argument("--arrival-process", default="poisson",
                    choices=["poisson", "trace"],
                    help="interarrival process for --load-sweep")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="accept patient segments over the serving "
                         "frontend's socket transport")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="open-loop socket client against a --listen "
                         "frontend (sends patients x segments at "
                         "--offered-rate)")
    ap.add_argument("--offered-rate", type=float, default=100.0,
                    help="with --connect: offered load in segments/s")
    ap.add_argument("--stream-rate", type=float, default=None,
                    help="with --listen: ROUTINE admission rate in "
                         "segments/s (past it segments defer, never "
                         "drop; default unbounded)")
    ap.add_argument("--json", action="store_true",
                    help="dump the full result record as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="enable telemetry; on exit write PREFIX.jsonl "
                         "(event log) and PREFIX.json (Chrome/Perfetto "
                         "trace)")
    args = ap.parse_args()
    if args.trace_out:
        # before the runner compiles so its jit cell registers with the
        # probe
        obs.configure(enabled=True)

    if args.connect:
        stream_connect(args)
        return
    buckets = tuple(sorted(int(b) for b in args.buckets.split(",")))
    mesh = make_data_mesh(args.devices)
    params = vadetect.init(jax.random.PRNGKey(args.seed))
    program = compiler.compile_model(params)
    if args.listen:
        stream_listen(args, program, buckets, mesh)
        return
    if args.load_sweep:
        stream_load_sweep(args, program, buckets, mesh)
        return
    cfg = FleetConfig(
        n_patients=args.patients,
        segments_per_patient=args.segments,
        seed=args.seed,
        va_fraction=args.va_fraction,
        jitter_frac=args.jitter,
        dropout=args.dropout,
        buckets=buckets,
        max_wait_s=args.max_wait,
        path=args.path,
    )
    out = simulate(cfg, program, mesh=mesh)
    if args.trace_out:
        out["telemetry"] = obs.telemetry_section()
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")
    if args.json:
        print(json.dumps(out, indent=1, default=str))
        return
    m, rt, chip = out["metrics"], out["realtime"], out["chip"]
    print(
        f"[stream] {args.patients} patients x {args.segments} segments, "
        f"buckets={list(buckets)}, devices={out['config']['n_devices']}, "
        f"path={args.path}"
    )
    print(
        f"[stream] segments={m['segments_total']} "
        f"batches={m['batches_total']} pad={m['pad_fraction']:.1%} "
        f"dropped={m['dropped_total']} "
        f"jit_cache_misses={out['jit_cache_misses']}"
    )
    print(
        f"[stream] wall {m['segments_per_s_wall']:.0f} seg/s "
        f"({rt['realtime_factor']:.1f}x the {rt['required_segments_per_s']:.0f} "
        f"seg/s real-time requirement); modeled chip fleet "
        f"{chip['modeled_fleet_segments_per_s']:.0f} seg/s"
    )
    if "deadline_slack_s" in m:
        sl = m["deadline_slack_s"]
        print(
            f"[stream] deadline slack p50={sl['p50']*1e3:.1f}ms "
            f"worst-1%={sl['worst_1pct']*1e3:.1f}ms "
            f"violations={sl['violations']}"
        )
    print(
        f"[stream] diagnoses={m['diagnoses_total']} "
        f"(VA={m['va_diagnoses_total']}) urgent-packed="
        f"{m['urgent_packed_total']} chip/segment="
        f"{chip['latency_us_per_segment']:.1f}us"
    )


if __name__ == "__main__":
    main()
