"""Roofline report: render EXPERIMENTS.md tables from dry-run JSONs.

Reads experiments/dryrun/<mesh>/<arch>__<shape>.json (written by
dryrun.py) and emits:
  * the per-cell three-term table (compute / memory / collective seconds,
    dominant term, MODEL_FLOPS/HLO_FLOPs, roofline fraction),
  * per-cell one-line improvement notes (rule-based on the dominant term),
  * a machine-readable summary JSON for the §Perf hillclimb loop.

No jax import — runs anywhere, any time after a dry-run.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Optional


_SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_records(
    out_dir: str, mesh: str, *, include_variants: bool = False
) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
        stem = os.path.basename(fn)[: -len(".json")]
        is_baseline = any(
            stem.endswith("__" + s) for s in _SHAPE_NAMES
        )
        if not include_variants and not is_baseline:
            continue  # tagged hillclimb variants live in §Perf
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def improvement_note(rec: dict) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    useful = r["useful_flops_ratio"]
    if dom == "compute":
        if useful < 0.5:
            return (
                "compute-bound with low useful ratio: cut masked/padded "
                "FLOPs (causal block-skip, tighter head/vocab padding)"
            )
        return "compute-bound: already near useful peak; overlap collectives"
    if dom == "memory":
        if rec["kind"] == "decode":
            return (
                "memory-bound decode: weight bytes dominate -> SPE "
                "quant+sparse storage (the paper's technique) cuts HBM "
                "traffic ~2-8x"
            )
        return (
            "memory-bound: raise arithmetic intensity (fusion, larger "
            "microbatch, bf16 master weights or opt-state offload)"
        )
    return (
        "collective-bound: reshard to cut all-gathers (FSDP->TP shift), "
        "overlap via latency-hiding scheduler, or compress grads"
    )


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:7.2f}s "
    if s >= 1e-3:
        return f"{s * 1e3:7.2f}ms"
    return f"{s * 1e6:7.1f}us"


def render_table(recs: list[dict]) -> str:
    hdr = (
        "| arch | shape | t_compute | t_memory | t_collective | dominant "
        "| mem/dev GiB (tpu-adj) | MODEL/HLO flops | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        rf = r["roofline"]
        mem = r["memory"]["total_per_device_bytes"]
        adj = mem - r["memory"].get("bf16_emulation_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} |"
            f" {fmt_seconds(rf['t_compute_s'])} |"
            f" {fmt_seconds(rf['t_memory_s'])} |"
            f" {fmt_seconds(rf['t_collective_s'])} |"
            f" **{rf['dominant']}** |"
            f" {mem / 2**30:.2f} ({adj / 2**30:.2f}) |"
            f" {rf['useful_flops_ratio']:.3f} |"
            f" {rf['roofline_fraction']:.3f} |"
        )
    return hdr + "\n".join(rows) + "\n"


def render_notes(recs: list[dict]) -> str:
    out = []
    for r in recs:
        out.append(
            f"- **{r['arch']} x {r['shape']}** ({r['roofline']['dominant']}"
            f"-bound): {improvement_note(r)}"
        )
    return "\n".join(out) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="singlepod_16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh)
    if not recs:
        print(f"no records under {args.dir}/{args.mesh}")
        return
    print(render_table(recs))
    print(render_notes(recs))
    # summary for the hillclimb loop
    worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(recs, key=lambda r: r["roofline"]["t_collective_s"])
    print("\nhillclimb candidates:")
    print(f"  worst roofline fraction : {worst['arch']} x {worst['shape']}"
          f" ({worst['roofline']['roofline_fraction']:.3f})")
    print(f"  most collective-bound   : {coll['arch']} x {coll['shape']}"
          f" ({coll['roofline']['t_collective_s']:.4f}s)")


if __name__ == "__main__":
    main()
