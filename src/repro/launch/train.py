"""Training driver: --arch <id> [--reduced] over the current devices.

On the CPU container this runs REDUCED configs end-to-end (the examples
use it); on a TPU slice the same driver runs the full configs over
`make_production_mesh()`. The step function, sharding rules, data
pipeline, checkpointing and fault tolerance are identical in both modes —
only the mesh differs.

`--multi-pod PxD[xM]` switches to compressed multi-pod data parallelism
(`trainer.make_multipod_train_step`): a ("pod", "data", "model") mesh
where the in-pod axes run the sharded pjit step with XLA collectives
and the pod axis reduces gradients through `dist.compression` —
`--scheme gather` (default; (8/n)x egress, best below 8 pods) or
`--scheme two_stage` (n-independent ~4x), `--no-compress` for the f32
ablation baseline. The error-feedback buffers ride in the checkpointed
state, so kill-and-resume reproduces the uninterrupted run bitwise.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \\
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch va-cnn --steps 300
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \\
      --multi-pod 2x2x2 --scheme two_stage --steps 40 --batch 8 \\
      --ckpt /tmp/ck_mp
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.data import iegm, lm
from repro.dist import sharding as shd
from repro.launch.mesh import make_multipod_mesh, make_smoke_mesh
from repro.models import api
from repro.optim import adamw, linear_warmup_cosine
from repro.train import fault, trainer


def _lm_cfg(args):
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    if args.spe_bits or args.spe_sparse:
        cfg = dataclasses.replace(
            cfg, spe_bits=args.spe_bits, spe_sparse=args.spe_sparse
        )
    return cfg


def _lm_batch_at(stream, cfg, args):
    """step -> batch, adding the deterministic enc-dec frames the
    whisper-family loss consumes."""
    def batch_at(step):
        b = stream.batch_at(step)
        if cfg.is_enc_dec:
            fkey = jax.random.fold_in(jax.random.PRNGKey(7), step)
            b["frames"] = jax.random.normal(
                fkey, (args.batch, cfg.enc_seq, cfg.d_model),
                jnp.float32,
            )
        return b

    return batch_at


def train_lm_multipod(args) -> dict:
    """Compressed multi-pod DP: in-pod sharded pjit x pod-axis
    quantized reduction, checkpoint-restartable (error buffers
    included)."""
    cfg = _lm_cfg(args)
    mesh = make_multipod_mesh(args.multi_pod)
    n_pod = mesh.shape["pod"]
    if args.batch % n_pod:
        raise SystemExit(
            f"--batch {args.batch} must divide by {n_pod} pods"
        )
    compress = not args.no_compress
    model = api.build_model(cfg, tp=1, max_seq=args.seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    logging.info(
        "arch=%s params=%.3fM mesh=%s scheme=%s compress=%s",
        cfg.name, n_params / 1e6, dict(mesh.shape),
        args.scheme, compress,
    )

    opt = adamw(
        linear_warmup_cosine(args.lr, args.warmup, args.steps),
        weight_decay=0.01,
    )
    state = trainer.init_state(params, opt)
    state["err"] = trainer.init_dp_err(
        params, mesh, scheme=args.scheme, compress=compress
    )
    step_fn, s_shard = trainer.make_multipod_train_step(
        model.loss, opt, cfg, mesh, jax.eval_shape(lambda: state),
        scheme=args.scheme, compress=compress, clip_norm=1.0,
        n_micro=args.grad_accum,
    )

    stream = lm.TokenStream(
        batch=args.batch, seq_len=args.seq, vocab=cfg.vocab, seed=args.seed
    )
    state, history = fault.run_training(
        step_fn, state, _lm_batch_at(stream, cfg, args),
        num_steps=args.steps,
        ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every,
        watchdog=fault.StragglerWatchdog(),
        log_every=args.log_every,
        restore_shardings=s_shard,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(
        f"[train] {cfg.name} multi-pod {args.multi_pod} "
        f"scheme={args.scheme if compress else 'f32'}: "
        f"loss {first:.4f} -> {last:.4f} ({len(history)} steps)"
    )
    return {"history": history, "state": state}


def train_lm(args) -> dict:
    cfg = _lm_cfg(args)
    model = api.build_model(cfg, tp=1, max_seq=args.seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    logging.info("arch=%s params=%.3fM", cfg.name, n_params / 1e6)

    opt = adamw(
        linear_warmup_cosine(args.lr, args.warmup, args.steps),
        weight_decay=0.01,
    )
    state = trainer.init_state(params, opt)
    step_fn = obs.get().probe.track("train.step", jax.jit(
        trainer.make_train_step(
            model.loss, opt, clip_norm=1.0, n_micro=args.grad_accum
        ),
        donate_argnums=(0,),
    ), donate=(0,))

    stream = lm.TokenStream(
        batch=args.batch, seq_len=args.seq, vocab=cfg.vocab, seed=args.seed
    )

    watchdog = fault.StragglerWatchdog()
    state, history = fault.run_training(
        step_fn, state, _lm_batch_at(stream, cfg, args),
        num_steps=args.steps,
        ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every,
        watchdog=watchdog,
        log_every=args.log_every,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] {cfg.name}: loss {first:.4f} -> {last:.4f} "
          f"({len(history)} steps)")
    return {"history": history, "state": state}


def train_va(args) -> dict:
    from repro.configs import va_cnn
    from repro.core import vadetect

    cfg = va_cnn.CONFIG
    key = jax.random.PRNGKey(args.seed)
    params = vadetect.init(key, cfg)
    opt = adamw(linear_warmup_cosine(args.lr, args.warmup, args.steps))
    state = trainer.init_state(params, opt)
    step_fn = obs.get().probe.track("train.step", jax.jit(
        trainer.make_train_step(
            lambda p, b: vadetect.loss_fn(p, b, cfg), opt, clip_norm=1.0
        ),
        donate_argnums=(0,),
    ), donate=(0,))
    stream = iegm.IEGMStream(batch=args.batch, seed=args.seed)
    state, history = fault.run_training(
        step_fn, state, stream.batch_at,
        num_steps=args.steps, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every, log_every=args.log_every,
    )
    accs = [h["accuracy"] for h in history[-20:]]
    print(f"[train] va-cnn: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f}; acc(last20) "
          f"{sum(accs)/len(accs):.4f}")
    return {"history": history, "state": state}


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spe-bits", type=int, default=None)
    ap.add_argument("--spe-sparse", action="store_true")
    ap.add_argument(
        "--multi-pod", type=str, default=None, metavar="PxD[xM]",
        help="compressed multi-pod DP over a (pod, data, model) mesh, "
             "e.g. 2x2x2 (needs P*D*M devices)",
    )
    ap.add_argument(
        "--scheme", choices=("gather", "two_stage"), default="gather",
        help="cross-pod wire layout: gather=(8/n)x egress (n<8 pods), "
             "two_stage=n-independent ~4x (n>=8)",
    )
    ap.add_argument(
        "--no-compress", action="store_true",
        help="f32 cross-pod reduction (ablation baseline)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PREFIX",
        help="enable telemetry; on exit write PREFIX.jsonl (event log) "
             "and PREFIX.json (Chrome/Perfetto trace)",
    )
    args = ap.parse_args()
    if args.trace_out:
        # before any step compilation so jit cells register with the probe
        obs.configure(enabled=True)
    if args.multi_pod:
        if args.arch == "va-cnn":
            raise SystemExit(
                "--multi-pod currently drives the LM trainer; va-cnn "
                "fits on one pod (use the plain path)"
            )
        train_lm_multipod(args)
    elif args.arch == "va-cnn":
        train_va(args)
    else:
        train_lm(args)
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome} "
              f"(recompiles: {obs.get().probe.cache_sizes()})")


if __name__ == "__main__":
    main()
