"""Weighted HLO cost analysis — loop-aware FLOPs / bytes / collectives.

XLA's `compiled.cost_analysis()` counts every computation ONCE, including
while-loop bodies — so a scan-over-layers model reports ~1/n_layers of
its real FLOPs. This module re-derives the three roofline inputs by
walking the optimized HLO text with execution-count weighting:

  * computations are parsed into per-op symbol tables;
  * `while` trip counts are resolved from the loop-carried bound: the
    max s32 scalar constant in the init tuple (jax scans carry
    (counter=0, limit=T, ...); validated against known models);
  * FLOPs: every `dot` contributes 2 * prod(result) * prod(contracting)
    (recursing into fusions and called computations), `convolution`
    contributes 2 * prod(result) * prod(kernel) / out_features;
  * bytes: operands + results of top-level ops (fusions counted at their
    boundary, mirroring XLA's bytes-accessed model), weighted by count;
  * collective bytes: per-op wire-byte conventions (see hlo_analysis).

This is a cost MODEL of the compiled program — dot-dominated by design
(elementwise FLOPs are ignored; on an MXU machine they are not the
roofline term). Validated in tests against closed-form matmul/scan cases
and cross-checked against cost_analysis() on loop-free programs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]"
)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->", re.M)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "opt-barrier", "while", "conditional", "call",
}


def _parse_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _shape_bytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_shapes: list  # [(dtype, dims), ...]
    operands: list  # operand %names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict  # name -> Op
    order: list


def parse_computations(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        m = _COMP_RE.match(raw.strip()) if "{" in raw else None
        if m and ("->" in raw):
            cur = Computation(name=m.group(1), ops={}, order=[])
            comps[cur.name] = cur
            if raw.strip().startswith("ENTRY"):
                entry = cur.name
            # computation parameters: "%p.1: f32[..]" pairs
            for pm in re.finditer(
                r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))", m.group(2)
            ):
                pname, ptype = pm.group(1), pm.group(2)
                op = Op(pname, "parameter", _parse_shapes(ptype), [], raw)
                cur.ops[pname] = op
                cur.order.append(pname)
            continue
        if cur is None:
            continue
        om = _OP_RE.match(raw)
        if om:
            name, typestr, kind, rest = om.groups()
            # operands: %names inside the first balanced paren chunk
            operand_str = rest.split("), ")[0]
            operands = _OPERAND_RE.findall(operand_str)
            op = Op(name, kind, _parse_shapes(typestr), operands, raw)
            cur.ops[name] = op
            cur.order.append(name)
        if raw.strip() == "}":
            cur = None
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    res = 1
    for _, dims in op.result_shapes:
        for d in dims:
            res *= d
    m = _CONTRACT_RE.search(op.line)
    k = 1
    if m and op.operands:
        lhs = comp.ops.get(op.operands[0])
        if lhs is not None and lhs.result_shapes:
            dims = lhs.result_shapes[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * res * k


def _conv_flops(op: Op, comp: Computation) -> float:
    res = 1
    for _, dims in op.result_shapes:
        for d in dims:
            res *= d
    kern = 1
    out_feat = 1
    if len(op.operands) >= 2:
        k = comp.ops.get(op.operands[1])
        if k is not None and k.result_shapes:
            dims = k.result_shapes[0][1]
            for d in dims:
                kern *= d
            dm = re.search(r"dim_labels=\w+_(\w+)->", op.line)
            if dm and "o" in dm.group(1):
                out_feat = dims[dm.group(1).index("o")]
    return 2.0 * res * kern / max(out_feat, 1)


def _collective_bytes(op: Op) -> int:
    rb = _shape_bytes(op.result_shapes)
    g = 1
    m = _GROUP_RE.search(op.line)
    if m:
        g = int(m.group(2))
    else:
        m2 = _GROUP_LIST_RE.search(op.line)
        if m2:
            g = len(m2.group(1).split(","))
    base = op.kind.replace("-start", "")
    if base == "all-gather":
        return rb // max(g, 1)
    if base == "reduce-scatter":
        return rb * g
    return rb


def _trip_count(op: Op, comp: Computation, comps: dict) -> int:
    """Trip bound of a while op.

    jax scan loops compare a counter (init 0, step 1) against a constant
    bound that lives either in the condition computation (as an s32[]
    constant fed to the fused compare) or in the loop-carried init tuple.
    We take the max s32 scalar constant over both — cond computations are
    tiny (counter arithmetic only), so the max is the bound.
    """
    best = 1
    # 1) constants in the condition computation
    mc = _COND_RE.search(op.line)
    if mc:
        cond = comps.get(mc.group(1))
        if cond is not None:
            for o in cond.ops.values():
                cm = _CONST_S32_RE.search(o.line)
                if cm:
                    best = max(best, int(cm.group(1)))
    # 2) constants reachable through the init tuple (fallback)
    seen: set = set()

    def visit(name: str, depth: int):
        nonlocal best
        if depth > 3 or name in seen:
            return
        seen.add(name)
        o = comp.ops.get(name)
        if o is None:
            return
        cm = _CONST_S32_RE.search(o.line)
        if cm:
            best = max(best, int(cm.group(1)))
        for sub in o.operands:
            visit(sub, depth + 1)

    for name in op.operands:
        visit(name, 0)
    return best


def _operand_bytes(op: Op, comp: Computation, idx: int) -> int:
    if idx >= len(op.operands):
        return 0
    src = comp.ops.get(op.operands[idx])
    return _shape_bytes(src.result_shapes) if src is not None else 0


_PASSTHROUGH = {"copy", "convert", "bitcast", "reshape", "transpose"}


def _root_kind(op_name: str, comp: Computation, depth: int = 3) -> str:
    """Kind of the producing op, looking through pass-through ops."""
    o = comp.ops.get(op_name)
    while o is not None and depth > 0 and o.kind in _PASSTHROUGH:
        if not o.operands:
            break
        o = comp.ops.get(o.operands[0])
        depth -= 1
    return o.kind if o is not None else "?"


def _is_dus_fusion(op: Op, comps: dict) -> bool:
    """Fusion whose body performs a dynamic-update-slice (aliased)."""
    if "dynamic_update_slice" in op.line:
        return True
    m = _CALLS_RE.search(op.line)
    if not m:
        return False
    called = comps.get(m.group(1))
    if called is None:
        return False
    return any(
        o.kind == "dynamic-update-slice" for o in called.ops.values()
    )


def _op_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """Bytes-accessed model per op (mirrors XLA's: in-place update ops
    touch only the updated window, not the whole buffer).

    Two fusion corrections (both validated against observed artifacts):
      * fusions whose body contains a dynamic-update-slice alias their
        buffer operand — traffic = the update window (the small
        operands) twice, not buffer-in + buffer-out (otherwise every
        scan iteration is charged the whole stacked residual array —
        a ~1000x overcount observed on rwkv6/decode caches);
      * fusion operands that are loop state (parameter /
        get-tuple-element, looking through copy/convert/bitcast) and
        much larger than the result are sliced inside the fusion
        (XLA fuses the scan's dynamic-slice into consumers) — counted
        at result size.
    """
    k = op.kind
    rb = _shape_bytes(op.result_shapes)
    if k == "dynamic-update-slice":
        # read + write of the updated window only (buffer is aliased)
        return 2.0 * _operand_bytes(op, comp, 1)
    if k == "fusion" and _is_dus_fusion(op, comps):
        small = sum(
            _operand_bytes(op, comp, i)
            for i in range(len(op.operands))
            if 0 < _operand_bytes(op, comp, i) <= max(rb // 4, 1)
        )
        if small:
            return 2.0 * small
        return float(rb)  # conservative fallback
    if k == "dynamic-slice":
        return 2.0 * rb
    if k == "gather":
        return 2.0 * rb + _operand_bytes(op, comp, 1)
    if k == "scatter":
        upd = _operand_bytes(op, comp, 2)
        return 3.0 * upd + _operand_bytes(op, comp, 1)
    ob = 0.0
    for i in range(len(op.operands)):
        b = _operand_bytes(op, comp, i)
        if k == "fusion" and b > 4 * rb:
            if _root_kind(op.operands[i], comp) in (
                "parameter", "get-tuple-element"
            ):
                b = float(rb)  # sliced loop-state access
        ob += b
    return float(ob + rb)


_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_label(op: Op) -> str:
    m = _META_RE.search(op.line)
    if not m:
        return op.kind
    name = m.group(1)
    # strip jit wrappers, keep the semantic tail of the scope path
    parts = [p for p in name.split("/") if not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else op.kind


@dataclasses.dataclass
class WeightedCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_op: dict
    collective_counts: dict
    loops: list  # (computation, trip)
    top_bytes: list  # [(weighted_bytes, kind, label)] descending
    top_flops: list  # [(weighted_flops, kind, label)] descending

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_by_op": dict(self.collective_by_op),
            "collective_counts": dict(self.collective_counts),
            "loops": list(self.loops),
            "top_bytes": [list(t) for t in self.top_bytes],
            "top_flops": [list(t) for t in self.top_flops],
        }


def weighted_cost(text: str) -> WeightedCost:
    comps, entry = parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    # classify fusion-called computations (bytes counted at boundary)
    fusion_comps: set[str] = set()
    for c in comps.values():
        for op in c.ops.values():
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    fusion_comps.add(m.group(1))

    flops = 0.0
    byts = 0.0
    coll_b: dict = defaultdict(float)
    coll_n: dict = defaultdict(float)
    loops: list = []
    by_label_bytes: dict = defaultdict(float)
    by_label_flops: dict = defaultdict(float)

    def walk(comp_name: str, weight: float, in_fusion: bool):
        nonlocal flops, byts
        comp = comps.get(comp_name)
        if comp is None:
            return
        for name in comp.order:
            op = comp.ops[name]
            k = op.kind
            if k == "dot":
                f = weight * _dot_flops(op, comp)
                flops += f
                by_label_flops[(k, _op_label(op))] += f
            elif k == "convolution":
                f = weight * _conv_flops(op, comp)
                flops += f
                by_label_flops[(k, _op_label(op))] += f
            base = k.replace("-start", "")
            if base in COLLECTIVE_OPS and not k.endswith("-done"):
                b = _collective_bytes(op)
                coll_b[base] += weight * b
                coll_n[base] += weight
            if not in_fusion and k not in _SKIP_BYTES_OPS:
                b = weight * _op_bytes(op, comp, comps)
                byts += b
                by_label_bytes[(k, _op_label(op))] += b
            # recursion
            if k == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    walk(m.group(1), weight, True)
            elif k == "while":
                trip = _trip_count(op, comp, comps)
                loops.append((comp_name + "/" + name, trip))
                mb = _BODY_RE.search(op.line)
                mc = _COND_RE.search(op.line)
                if mb:
                    walk(mb.group(1), weight * trip, in_fusion)
                if mc:
                    walk(mc.group(1), weight * trip, True)  # cond: flops only
            elif k == "conditional":
                m = _BRANCHES_RE.search(op.line)
                if m:
                    for b in _OPERAND_RE.findall(m.group(1)):
                        walk(b, weight, in_fusion)
            elif k in ("call", "async-start"):
                m = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m:
                    walk(m.group(1), weight, in_fusion)

    walk(entry, 1.0, False)
    top_b = sorted(
        ((v, k[0], k[1]) for k, v in by_label_bytes.items()),
        reverse=True,
    )[:15]
    top_f = sorted(
        ((v, k[0], k[1]) for k, v in by_label_flops.items()),
        reverse=True,
    )[:10]
    return WeightedCost(
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=sum(coll_b.values()),
        collective_by_op=dict(coll_b),
        collective_counts=dict(coll_n),
        loops=loops,
        top_bytes=top_b,
        top_flops=top_f,
    )
