"""Serving driver: batched generation (LM) or VA diagnosis service.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \\
      --batch 4 --prompt-len 16 --max-new 16 [--quant-bits 8] \\
      [--temperature 0.8 --top-k 40]
  PYTHONPATH=src python -m repro.launch.serve --arch va-cnn --patients 8

Greedy by default; --temperature enables per-request folded-key
sampling (reproducible for a fixed --seed, optionally top-k-truncated)
on both the single-device and mesh-sharded paths.

Sharded multi-device decode (`repro.serve.sharded`): pass --mesh D or
DxM to place the decode cache/params on a ("data", "model") mesh; on a
CPU container force host devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.serve --arch qwen3-8b --reduced \\
      --batch 8 --mesh 4x2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.launch.mesh import make_serving_mesh
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH


def serve_lm(args) -> None:
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    max_seq = args.prompt_len + args.max_new + 1
    model = api.build_model(cfg, tp=1, max_seq=max_seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.quant_bits:
        params = E.quantize_for_serving(params, args.quant_bits)
        print(f"[serve] weights quantized to {args.quant_bits} bits")
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab
    )
    sampling = dict(
        greedy=args.temperature is None,
        key=jax.random.fold_in(key, 1),  # decouple from init/prompts
        # keep an explicit 0.0 (sample_tokens' documented degenerate-
        # to-greedy case) instead of `or`-defaulting it to 1.0
        temperature=1.0 if args.temperature is None
        else args.temperature,
        top_k=args.top_k,
    )
    if args.temperature is not None:
        print(f"[serve] sampling: temperature={args.temperature} "
              f"top_k={args.top_k or 'off'} (per-request folded keys)")
    if args.mesh:
        mesh = make_serving_mesh(args.mesh)
        plan = SH.plan_decode(model, params, mesh, batch_size=args.batch)
        print(
            f"[serve] mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}: "
            f"cache {plan.cache_bytes_per_device / 1e3:.1f} kB/device "
            f"(replicated would be {plan.cache_bytes_total / 1e3:.1f} kB), "
            f"params {plan.param_bytes_per_device / 1e3:.1f} kB/device"
        )
        t0 = time.monotonic()
        with obs.get().span("serve/generate", cat="serve",
                            batch=args.batch, max_new=args.max_new,
                            mesh=args.mesh):
            out = SH.sharded_generate(
                model, params, prompts, mesh=mesh, max_new=args.max_new,
                plan=plan, **sampling,
            )
            out.block_until_ready()
    else:
        t0 = time.monotonic()
        with obs.get().span("serve/generate", cat="serve",
                            batch=args.batch, max_new=args.max_new):
            out = E.generate(
                model, params, prompts, max_new=args.max_new, **sampling
            )
            out.block_until_ready()
    dt = time.monotonic() - t0
    n_tok = args.batch * args.max_new
    print(f"[serve] {cfg.name}: {out.shape} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0][:12].tolist())


def serve_load_sweep(args) -> None:
    """Open-loop offered-load sweep over the slot engine (wall time):
    measure the closed-loop capacity, then drive Poisson/trace-driven
    arrival schedules at fractions of it and report tail latency from
    *intended* arrival times, the saturation knee, TTFT SLO burn, and
    the overload verdict (see `repro.obs.loadlab`)."""
    import json as _json

    from repro.obs import loadlab

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    max_seq = args.prompt_len + args.max_new + 2
    model = api.build_model(cfg, tp=1, max_seq=max_seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    mesh = make_serving_mesh(args.mesh) if args.mesh else None

    def make_engine():
        if mesh is not None:
            return SH.ShardedEngine(
                model, params, batch_size=args.batch, mesh=mesh
            )
        return E.Engine(model, params, batch_size=args.batch)

    def make_prompts(n):
        toks = jax.random.randint(
            jax.random.fold_in(key, 2), (n, args.prompt_len), 0,
            cfg.vocab,
        )
        return [jnp.asarray(toks[i], jnp.int32) for i in range(n)]

    cap = loadlab.run_serve_point(
        make_engine,
        make_prompts(max(2 * args.batch, 8)),
        rate_rps=1e5,  # everything intended at ~t=0: drain throughput
        max_new=args.max_new,
        key=jax.random.fold_in(key, 3),
    )["achieved_rps"]
    fractions = tuple(
        float(f) for f in args.load_fractions.split(",")
    )
    out = loadlab.sweep_serve(
        make_engine,
        make_prompts,
        capacity_rps=cap,
        load_fractions=fractions,
        n_requests=args.load_requests,
        max_new=args.max_new,
        seed=args.seed,
        process=args.arrival_process,
    )
    print(
        f"[serve] open-loop sweep: capacity ~{cap:.0f} req/s, "
        f"{args.arrival_process} arrivals, "
        f"{args.load_requests} requests/point"
    )
    for p in out["points"]:
        print(
            f"[serve]   {p['load_fraction']:>5.2f}x  "
            f"offered {p['offered_load']:8.1f}/s  "
            f"p50 {p['p50_s'] * 1e3:7.1f}ms  "
            f"p99 {p['p99_s'] * 1e3:7.1f}ms  "
            f"p99.9 {p['p999_s'] * 1e3:7.1f}ms"
        )
    k = out["knee"]
    if k.get("detected"):
        print(
            f"[serve] saturation knee @ {k['knee_rate']:.1f} req/s "
            f"(p99 grows {k['post_knee_growth']:.1f}x past it)"
        )
    slo = out["slo"]
    print(
        f"[serve] SLO {slo['declared']['name']} "
        f"(bound {slo['declared']['bound'] * 1e3:.1f}ms): "
        f"met sub-saturated = {slo['met_sub_saturated']}; "
        f"overload verdict = {out['overload']['verdict']}"
    )
    if args.json:
        print(_json.dumps(out, indent=1, default=float))


def serve_va(args) -> None:
    from repro.configs import va_cnn
    from repro.core import compiler, vadetect
    from repro.data import iegm
    from repro.serve.va_service import VAService

    key = jax.random.PRNGKey(args.seed)
    params = vadetect.init(key, va_cnn.CONFIG)
    program = compiler.compile_model(params, va_cnn.CONFIG)
    svc = VAService(program, va_cnn.CONFIG)
    batch = iegm.synth_diagnosis_batch(key, args.patients)
    out = svc.diagnose_batch(batch["signal"])
    correct = sum(
        int(d.is_va) == int(batch["label"][i]) for i, d in enumerate(out)
    )
    rep = svc.report.summary()
    print(f"[serve] va-cnn: {args.patients} diagnoses, "
          f"{correct}/{args.patients} match labels (untrained weights)")
    print(f"[serve] chip model: {rep['latency_us']:.1f}us/inference, "
          f"{rep['effective_GOPS']:.1f} GOPS, "
          f"{rep['avg_power_uW']:.2f} uW")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quant-bits", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=None,
                    help="enable sampling at this temperature "
                         "(default: greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full "
                         "distribution); needs --temperature")
    ap.add_argument("--mesh", default=None,
                    help="shard decode on a device mesh: 'D' or 'DxM' "
                         "(data x model), e.g. --mesh 8 or --mesh 4x2")
    ap.add_argument("--patients", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load-sweep", action="store_true",
                    help="run the open-loop offered-load sweep "
                         "(repro.obs.loadlab) instead of one batch")
    ap.add_argument("--load-fractions",
                    default="0.25,0.5,0.75,1.0,2.0",
                    help="offered load as fractions of measured "
                         "capacity (comma-separated)")
    ap.add_argument("--load-requests", type=int, default=24,
                    help="requests per offered-load point")
    ap.add_argument("--arrival-process", default="poisson",
                    choices=["poisson", "trace"],
                    help="interarrival process for --load-sweep")
    ap.add_argument("--json", action="store_true",
                    help="with --load-sweep: dump the full sweep "
                         "record as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="enable telemetry; on exit write PREFIX.jsonl "
                         "(event log) and PREFIX.json (Chrome/Perfetto "
                         "trace)")
    args = ap.parse_args()
    if args.top_k and args.temperature is None:
        ap.error("--top-k only applies when sampling; pass "
                 "--temperature too (e.g. --temperature 1.0)")
    if args.trace_out:
        obs.configure(enabled=True)
    if args.load_sweep:
        if args.arch == "va-cnn":
            ap.error("--load-sweep drives the LM slot engine; for the "
                     "fleet sweep use repro.launch.stream --load-sweep")
        serve_load_sweep(args)
    elif args.arch == "va-cnn":
        serve_va(args)
    else:
        serve_lm(args)
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")


if __name__ == "__main__":
    main()
