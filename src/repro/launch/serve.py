"""Serving driver: batched generation (LM) or VA diagnosis service.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \\
      --batch 4 --prompt-len 16 --max-new 16 [--quant-bits 8] \\
      [--temperature 0.8 --top-k 40]
  PYTHONPATH=src python -m repro.launch.serve --arch va-cnn --patients 8

Greedy by default; --temperature enables per-request folded-key
sampling (reproducible for a fixed --seed, optionally top-k-truncated)
on both the single-device and mesh-sharded paths.

Async serving frontend (`repro.serve.frontend`): --listen HOST:PORT
serves the length-prefixed JSON transport with admission control at
--admission-rate; --connect HOST:PORT drives it open-loop from another
process; --frontend-sweep runs the loopback-socket offered-load sweep
(shed-rate curve, URGENT survival, graceful-degradation verdict).

Sharded multi-device decode (`repro.serve.sharded`): pass --mesh D or
DxM to place the decode cache/params on a ("data", "model") mesh; on a
CPU container force host devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.serve --arch qwen3-8b --reduced \\
      --batch 8 --mesh 4x2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.launch.mesh import make_serving_mesh
from repro.models import api
from repro.serve import engine as E
from repro.serve import sharded as SH


def serve_lm(args) -> None:
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    max_seq = args.prompt_len + args.max_new + 1
    model = api.build_model(cfg, tp=1, max_seq=max_seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.quant_bits:
        params = E.quantize_for_serving(params, args.quant_bits)
        print(f"[serve] weights quantized to {args.quant_bits} bits")
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab
    )
    sampling = dict(
        greedy=args.temperature is None,
        key=jax.random.fold_in(key, 1),  # decouple from init/prompts
        # keep an explicit 0.0 (sample_tokens' documented degenerate-
        # to-greedy case) instead of `or`-defaulting it to 1.0
        temperature=1.0 if args.temperature is None
        else args.temperature,
        top_k=args.top_k,
    )
    if args.temperature is not None:
        print(f"[serve] sampling: temperature={args.temperature} "
              f"top_k={args.top_k or 'off'} (per-request folded keys)")
    if args.mesh:
        mesh = make_serving_mesh(args.mesh)
        plan = SH.plan_decode(model, params, mesh, batch_size=args.batch)
        print(
            f"[serve] mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}: "
            f"cache {plan.cache_bytes_per_device / 1e3:.1f} kB/device "
            f"(replicated would be {plan.cache_bytes_total / 1e3:.1f} kB), "
            f"params {plan.param_bytes_per_device / 1e3:.1f} kB/device"
        )
        t0 = time.monotonic()
        with obs.get().span("serve/generate", cat="serve",
                            batch=args.batch, max_new=args.max_new,
                            mesh=args.mesh):
            out = SH.sharded_generate(
                model, params, prompts, mesh=mesh, max_new=args.max_new,
                plan=plan, **sampling,
            )
            out.block_until_ready()
    else:
        t0 = time.monotonic()
        with obs.get().span("serve/generate", cat="serve",
                            batch=args.batch, max_new=args.max_new):
            out = E.generate(
                model, params, prompts, max_new=args.max_new, **sampling
            )
            out.block_until_ready()
    dt = time.monotonic() - t0
    n_tok = args.batch * args.max_new
    print(f"[serve] {cfg.name}: {out.shape} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0][:12].tolist())


def serve_load_sweep(args) -> None:
    """Open-loop offered-load sweep over the slot engine (wall time):
    measure the closed-loop capacity, then drive Poisson/trace-driven
    arrival schedules at fractions of it and report tail latency from
    *intended* arrival times, the saturation knee, TTFT SLO burn, and
    the overload verdict (see `repro.obs.loadlab`)."""
    import json as _json

    from repro.obs import loadlab

    _, make_engine, make_prompts = _build_lm_engine(args)
    key = jax.random.PRNGKey(args.seed)
    cap = loadlab.run_serve_point(
        make_engine,
        make_prompts(max(2 * args.batch, 8)),
        rate_rps=1e5,  # everything intended at ~t=0: drain throughput
        max_new=args.max_new,
        key=jax.random.fold_in(key, 3),
    )["achieved_rps"]
    fractions = tuple(
        float(f) for f in args.load_fractions.split(",")
    )
    out = loadlab.sweep_serve(
        make_engine,
        make_prompts,
        capacity_rps=cap,
        load_fractions=fractions,
        n_requests=args.load_requests,
        max_new=args.max_new,
        seed=args.seed,
        process=args.arrival_process,
    )
    print(
        f"[serve] open-loop sweep: capacity ~{cap:.0f} req/s, "
        f"{args.arrival_process} arrivals, "
        f"{args.load_requests} requests/point"
    )
    for p in out["points"]:
        print(
            f"[serve]   {p['load_fraction']:>5.2f}x  "
            f"offered {p['offered_load']:8.1f}/s  "
            f"p50 {p['p50_s'] * 1e3:7.1f}ms  "
            f"p99 {p['p99_s'] * 1e3:7.1f}ms  "
            f"p99.9 {p['p999_s'] * 1e3:7.1f}ms"
        )
    k = out["knee"]
    if k.get("detected"):
        print(
            f"[serve] saturation knee @ {k['knee_rate']:.1f} req/s "
            f"(p99 grows {k['post_knee_growth']:.1f}x past it)"
        )
    slo = out["slo"]
    print(
        f"[serve] SLO {slo['declared']['name']} "
        f"(bound {slo['declared']['bound'] * 1e3:.1f}ms): "
        f"met sub-saturated = {slo['met_sub_saturated']}; "
        f"overload verdict = {out['overload']['verdict']}"
    )
    if args.json:
        print(_json.dumps(out, indent=1, default=float))


def _hostport(s: str) -> tuple[str, int]:
    host, _, port = s.rpartition(":")
    return host or "127.0.0.1", int(port)


def _build_lm_engine(args):
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    max_seq = args.prompt_len + args.max_new + 2
    page = getattr(args, "page_size", None)
    if page:
        # paged pools need page_size | every attention capacity; round
        # the derived max_seq up instead of bouncing the run
        max_seq += (-max_seq) % page
    model = api.build_model(cfg, tp=1, max_seq=max_seq)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    mesh = make_serving_mesh(args.mesh) if args.mesh else None

    paging = None
    chunk_tokens = getattr(args, "chunk_tokens", None)
    if page:
        from repro.dist import sharding as shd
        from repro.serve.paging import PagingConfig, validate_page_size

        n_data = (
            shd._axis_size(shd.data_axes(cfg, mesh), mesh)
            if mesh is not None else 1
        )
        per_dev = getattr(args, "pages_per_device", None)
        if per_dev is None:
            # default: the dense pool's worth of pages (+1 scratch) —
            # paged then never rejects what dense would have seated
            span = validate_page_size(page, model.attn_capacities())
            per_dev = (args.batch // max(n_data, 1)) * span + 1
        paging = PagingConfig(page, per_dev * max(n_data, 1))

    def make_engine():
        if mesh is not None:
            return SH.ShardedEngine(
                model, params, batch_size=args.batch, mesh=mesh,
                paging=paging, chunk_tokens=chunk_tokens,
            )
        return E.Engine(
            model, params, batch_size=args.batch,
            paging=paging, chunk_tokens=chunk_tokens,
        )

    def make_prompts(n):
        toks = jax.random.randint(
            jax.random.fold_in(key, 2), (n, args.prompt_len), 0,
            cfg.vocab,
        )
        return [jnp.asarray(toks[i], jnp.int32) for i in range(n)]

    return cfg, make_engine, make_prompts


def serve_listen(args) -> None:
    """Serve LM requests over the length-prefixed JSON socket
    transport (`repro.serve.frontend`), with admission control at
    --admission-rate (shed with typed rejections past it)."""
    import asyncio

    from repro.serve.frontend import Frontend, FrontendConfig

    _, make_engine, _ = _build_lm_engine(args)
    fe = Frontend(
        engine=make_engine(),
        cfg=FrontendConfig(admission_rate_rps=args.admission_rate),
    )
    fe.warm(args.prompt_len)
    host, port = _hostport(args.listen)

    async def amain() -> None:
        bound = await fe.start(host, port)
        print(f"[serve] frontend listening on {bound[0]}:{bound[1]} "
              f"(admission rate: "
              f"{args.admission_rate or 'unbounded'})")
        try:
            await asyncio.Event().wait()
        finally:
            await fe.stop()

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        print("[serve] frontend stopped")


def serve_connect(args) -> None:
    """Open-loop socket client: offer --load-requests LM requests at
    --offered-rate req/s and report terminal outcomes."""
    import asyncio

    from repro.obs import loadlab
    from repro.serve.frontend import SocketClient

    host, port = _hostport(args.connect)
    key = jax.random.PRNGKey(args.seed)
    intended = loadlab.arrival_times(
        key, 0, rate_hz=args.offered_rate, n=args.load_requests,
        process=args.arrival_process,
    )

    async def amain() -> dict:
        import time

        client = await SocketClient.connect(host, port)
        futs = []
        t0 = time.perf_counter()
        for i in range(args.load_requests):
            delay = intended[i] - (time.perf_counter() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
            prompt = [int(x) for x in jax.random.randint(
                jax.random.fold_in(key, 100 + i),
                (args.prompt_len,), 0, 1000,
            )]
            futs.append(await client.send_lm(
                uid=i, prompt=prompt, max_new=args.max_new
            ))
        results = [await asyncio.wait_for(f, 120.0) for f in futs]
        await client.close()
        return results

    results = asyncio.run(amain())
    done = sum(1 for r in results if r["status"] == "completed")
    reasons: dict = {}
    for r in results:
        if r["status"] == "rejected":
            reasons[r["reason"]] = reasons.get(r["reason"], 0) + 1
    print(f"[serve] {args.load_requests} offered at "
          f"{args.offered_rate:.1f} req/s -> {done} completed, "
          f"{len(results) - done} rejected {reasons or ''}")


def serve_frontend_sweep(args) -> None:
    """Loopback-socket offered-load sweep through the frontend:
    measure engine capacity closed-loop (or take --admission-rate),
    then offer --load-fractions of it over a real socket with active
    admission control — shed-rate curve, URGENT segment survival, and
    the overload verdict (see `loadlab.sweep_frontend`)."""
    import json as _json

    from repro.core import compiler, vadetect
    from repro.obs import loadlab
    from repro.serve.frontend import Frontend
    from repro.stream.runner import FleetRunner

    _, make_engine, make_prompts = _build_lm_engine(args)
    rate = args.admission_rate
    if rate is None:
        rate = loadlab.run_serve_point(
            make_engine,
            make_prompts(max(2 * args.batch, 8)),
            rate_rps=1e5,
            max_new=args.max_new,
            key=jax.random.PRNGKey(args.seed + 3),
        )["achieved_rps"]
        print(f"[serve] closed-loop capacity ~{rate:.0f} req/s -> "
              f"admission rate")
    runner = FleetRunner(
        compiler.compile_model(
            vadetect.init(jax.random.PRNGKey(args.seed))
        )
    )

    def make_frontend(fcfg):
        fe = Frontend(engine=make_engine(), n_patients=args.patients,
                      runner=runner, cfg=fcfg)
        fe.warm(args.prompt_len)
        return fe

    out = loadlab.sweep_frontend(
        make_frontend,
        make_prompts,
        admission_rate_rps=rate,
        load_fractions=tuple(
            float(f) for f in args.load_fractions.split(",")
        ),
        n_requests=args.load_requests,
        max_new=args.max_new,
        seed=args.seed,
        n_patients=args.patients,
        process=args.arrival_process,
    )
    for p in out["points"]:
        print(
            f"[serve]   {p['load_fraction']:>5.2f}x  "
            f"offered {p['offered_load']:8.1f}/s  "
            f"completed {p['completed']:3d}  "
            f"shed {p['shed_rate']:5.1%}  "
            f"p99 {(p['p99_s'] or float('nan')) * 1e3:7.1f}ms  "
            f"seg-deferred {p['segments']['deferred']}"
        )
    ov = out["overload"]
    print(f"[serve] frontend verdict = {ov['verdict']} "
          f"(accounting_exact={ov['accounting_exact']}, "
          f"urgent_survived={ov['urgent_survived']})")
    to = out.get("transport_overhead")
    if to:
        print(f"[serve] socket - inproc p99: "
              f"{to['socket_minus_inproc_p99_s'] * 1e3:.2f}ms at "
              f"{to['load_fraction']}x")
    if args.json:
        print(_json.dumps(out, indent=1, default=float))


def serve_va(args) -> None:
    from repro.configs import va_cnn
    from repro.core import compiler, vadetect
    from repro.data import iegm
    from repro.serve.va_service import VAService

    key = jax.random.PRNGKey(args.seed)
    params = vadetect.init(key, va_cnn.CONFIG)
    program = compiler.compile_model(params, va_cnn.CONFIG)
    svc = VAService(program, va_cnn.CONFIG)
    batch = iegm.synth_diagnosis_batch(key, args.patients)
    out = svc.diagnose_batch(batch["signal"])
    correct = sum(
        int(d.is_va) == int(batch["label"][i]) for i, d in enumerate(out)
    )
    rep = svc.report.summary()
    print(f"[serve] va-cnn: {args.patients} diagnoses, "
          f"{correct}/{args.patients} match labels (untrained weights)")
    print(f"[serve] chip model: {rep['latency_us']:.1f}us/inference, "
          f"{rep['effective_GOPS']:.1f} GOPS, "
          f"{rep['avg_power_uW']:.2f} uW")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quant-bits", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=None,
                    help="enable sampling at this temperature "
                         "(default: greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full "
                         "distribution); needs --temperature")
    ap.add_argument("--mesh", default=None,
                    help="shard decode on a device mesh: 'D' or 'DxM' "
                         "(data x model), e.g. --mesh 8 or --mesh 4x2")
    ap.add_argument("--patients", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load-sweep", action="store_true",
                    help="run the open-loop offered-load sweep "
                         "(repro.obs.loadlab) instead of one batch")
    ap.add_argument("--frontend-sweep", action="store_true",
                    help="offered-load sweep through the async "
                         "serving frontend over a loopback socket, "
                         "with knee-aware admission control")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve LM requests over the frontend's "
                         "length-prefixed JSON socket transport")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="open-loop socket client against a --listen "
                         "frontend (sends --load-requests at "
                         "--offered-rate)")
    ap.add_argument("--offered-rate", type=float, default=50.0,
                    help="with --connect: offered load in req/s")
    ap.add_argument("--admission-rate", type=float, default=None,
                    help="admission-control rate in req/s for "
                         "--listen/--frontend-sweep (default: "
                         "unbounded for --listen; measured capacity "
                         "for --frontend-sweep)")
    ap.add_argument("--load-fractions",
                    default="0.25,0.5,0.75,1.0,2.0",
                    help="offered load as fractions of measured "
                         "capacity (comma-separated)")
    ap.add_argument("--load-requests", type=int, default=24,
                    help="requests per offered-load point")
    ap.add_argument("--arrival-process", default="poisson",
                    choices=["poisson", "trace"],
                    help="interarrival process for --load-sweep")
    ap.add_argument("--json", action="store_true",
                    help="with --load-sweep: dump the full sweep "
                         "record as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="enable telemetry; on exit write PREFIX.jsonl "
                         "(event log) and PREFIX.json (Chrome/Perfetto "
                         "trace)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV cache: positions per page (must "
                         "divide every attention window; max_seq is "
                         "rounded up to a multiple)")
    ap.add_argument("--pages-per-device", type=int, default=None,
                    help="with --page-size: physical pages per data "
                         "shard incl. 1 scratch (default: the dense "
                         "pool equivalent, batch/shard x span + 1)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill: split prompts longer than "
                         "this into page-sized chunks interleaved "
                         "with decode ticks")
    args = ap.parse_args()
    if args.pages_per_device and not args.page_size:
        ap.error("--pages-per-device requires --page-size")
    if args.top_k and args.temperature is None:
        ap.error("--top-k only applies when sampling; pass "
                 "--temperature too (e.g. --temperature 1.0)")
    if args.trace_out:
        obs.configure(enabled=True)
    if (args.frontend_sweep or args.listen) and args.arch == "va-cnn":
        ap.error("the serving frontend fronts the LM slot engine; "
                 "for the stream side use repro.launch.stream "
                 "--listen/--connect")
    if args.load_sweep:
        if args.arch == "va-cnn":
            ap.error("--load-sweep drives the LM slot engine; for the "
                     "fleet sweep use repro.launch.stream --load-sweep")
        serve_load_sweep(args)
    elif args.frontend_sweep:
        serve_frontend_sweep(args)
    elif args.listen:
        serve_listen(args)
    elif args.connect:
        serve_connect(args)
    elif args.arch == "va-cnn":
        serve_va(args)
    else:
        serve_lm(args)
    if args.trace_out:
        jsonl, chrome = obs.get().finish(args.trace_out)
        print(f"[obs] trace written: {jsonl} + {chrome}")


if __name__ == "__main__":
    main()
