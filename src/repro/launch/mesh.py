"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import so `jax.make_mesh` can build these meshes on the CPU container;
on real hardware the same call lays the mesh over the pod slices.

Axes:
  pod    — 2 pods (multi-pod only): pure DP; cross-pod traffic is DCN,
           which is where `dist.compression` applies.
  data   — 16-way in-pod: DP + FSDP (params/optimizer sharded, ZeRO-3).
  model  — 16-way in-pod: TP (heads / ffn columns / vocab) and the MoE
           expert-hidden dim. Pure-DP profiles (whisper-tiny) fold this
           axis into data parallelism instead.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_smoke_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many real devices the host has (tests)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """CLI mesh spec -> (n_data, n_model). "8" means 8-way data
    parallel; "4x2" means data=4, model=2."""
    parts = spec.lower().split("x")
    if len(parts) == 1:
        return int(parts[0]), 1
    if len(parts) == 2:
        return int(parts[0]), int(parts[1])
    raise ValueError(f"mesh spec {spec!r}: expected 'D' or 'DxM'")


def parse_multipod_spec(spec: str) -> tuple[int, int, int]:
    """CLI multi-pod mesh spec -> (n_pod, n_data, n_model). "2x4" means
    2 pods x 4-way in-pod data parallel; "2x2x2" adds a 2-way in-pod
    model (TP) axis."""
    parts = spec.lower().split("x")
    if len(parts) == 2:
        return int(parts[0]), int(parts[1]), 1
    if len(parts) == 3:
        return int(parts[0]), int(parts[1]), int(parts[2])
    raise ValueError(
        f"multi-pod spec {spec!r}: expected 'PxD' or 'PxDxM' "
        f"(pods x data [x model])"
    )


def make_multipod_mesh(spec: str) -> jax.sharding.Mesh:
    """('PxD' | 'PxDxM') -> a ("pod", "data", "model") mesh over the
    first P*D*M host devices — the nested-mesh shape
    `trainer.make_multipod_train_step` composes over: the pod axis is
    pure DP through `dist.compression`, the in-pod axes keep XLA
    collectives. On a CPU container, force host devices before any jax
    import: XLA_FLAGS=--xla_force_host_platform_device_count=<P*D*M>."""
    n_pod, n_data, n_model = parse_multipod_spec(spec)
    need, avail = n_pod * n_data * n_model, jax.device_count()
    if need > avail:
        raise SystemExit(
            f"--multi-pod {spec} needs {need} devices but only {avail} "
            f"available; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}"
        )
    return jax.make_mesh(
        (n_pod, n_data, n_model), ("pod", "data", "model"),
        devices=jax.devices()[:need],
        axis_types=(jax.sharding.AxisType.Auto,) * 3,
    )


def make_serving_mesh(spec: str) -> jax.sharding.Mesh:
    """('D' | 'DxM') -> a ("data", "model") mesh over the first D*M host
    devices. On a CPU container, force host devices before any jax
    import: XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    n_data, n_model = parse_mesh_spec(spec)
    need, avail = n_data * n_model, jax.device_count()
    if need > avail:
        raise SystemExit(
            f"--mesh {spec} needs {need} devices but only {avail} "
            f"available; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}"
        )
    return make_smoke_mesh(n_data, n_model)
