import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the *production* step function (the same
jitted train_step / prefill / decode_step the trainer and server run),
lowers it against ShapeDtypeStruct inputs (no allocation), compiles it for
the mesh, and records:

  * memory_analysis()  — per-device argument/output/temp bytes (fits?)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective stats   — parsed from the optimized HLO (hlo_analysis)
  * MODEL_FLOPS        — 6*N*D (train) / 2*N*D (inference), N_active for MoE

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; roofline.py
renders EXPERIMENTS.md tables from them. A cell failing to compile is a
bug in the framework's sharding — the suite is green only when all 40
cells pass on the single-pod (16,16) mesh AND the 2x16x16 multi-pod mesh.

NOTE: the two XLA_FLAGS lines above MUST precede any jax import (jax locks
the device count at first init). Nothing else in the repo sets this flag —
smoke tests and benchmarks see the host's real single device.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ArchConfig, ShapeCell, applicable_shapes
from repro.dist import sharding as shd
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import adamw, linear_warmup_cosine
from repro.train import trainer


def count_params(cfg: ArchConfig) -> tuple[float, float]:
    """(N_total, N_active) from the *unpadded* (tp=1) parameter tree."""
    import math

    model = api.build_model(cfg, tp=1, max_seq=8)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_total = float(
        sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    )
    n_active = n_total
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        n_active -= cfg.n_layers * (e - k) * per_expert
    return n_total, n_active


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    n_total, n_active = count_params(cfg)
    tokens = cell.global_batch * (
        cell.seq_len if cell.kind in ("train", "prefill") else 1
    )
    per_token = 6.0 * n_active if cell.kind == "train" else 2.0 * n_active
    return per_token * tokens


def _bf16_params(shapes: Any) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
        ),
        shapes,
    )


def lower_cell(
    cfg: ArchConfig,
    cell: ShapeCell,
    mesh: jax.sharding.Mesh,
    *,
    serve_quant_bits: Optional[int] = None,
):
    """Build + lower + compile one cell. Returns (compiled, lowered)."""
    tp = mesh.shape["model"] if cfg.use_tp else 1
    model = api.build_model(cfg, tp=tp, max_seq=cell.seq_len)
    specs = api.input_specs(cfg, cell, tp=tp)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    with mesh, shd.activation_context(cfg, mesh):
        if cell.kind == "train":
            opt = adamw(linear_warmup_cosine(3e-4, 200, 10_000))
            state_shapes = {
                "params": p_shapes,
                "opt": jax.eval_shape(opt.init, p_shapes),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            jitted, s_shard, b_shard = trainer.make_sharded_train_step(
                model.loss, opt, cfg, mesh, state_shapes, specs["batch"],
                n_micro=cfg.train_microbatches,
            )
            lowered = jitted.lower(state_shapes, specs["batch"])
        elif cell.kind == "prefill":
            serve_params = _bf16_params(p_shapes)
            if serve_quant_bits:
                from repro.serve.engine import quantize_for_serving

                serve_params = jax.eval_shape(
                    lambda p: quantize_for_serving(p, serve_quant_bits),
                    p_shapes,
                )
            p_specs = shd.param_specs(serve_params, cfg, mesh)
            args = (
                (specs["tokens"], specs["frames"])
                if cfg.is_enc_dec else (specs["tokens"],)
            )
            arg_specs = shd.batch_specs(list(args), cfg, mesh)
            in_sh = (
                shd.named(p_specs, mesh),
                *[jax.sharding.NamedSharding(mesh, s) for s in arg_specs],
            )
            lowered = jax.jit(
                model.prefill, in_shardings=in_sh
            ).lower(serve_params, *args)
        else:  # decode
            serve_params = _bf16_params(p_shapes)
            if serve_quant_bits:
                from repro.serve.engine import quantize_for_serving

                serve_params = jax.eval_shape(
                    lambda p: quantize_for_serving(p, serve_quant_bits),
                    p_shapes,
                )
            p_specs = shd.param_specs(serve_params, cfg, mesh)
            c_specs = shd.cache_specs(specs["cache"], cfg, mesh)
            tok_specs = shd.batch_specs(
                {"token": specs["token"], "pos": specs["pos"]}, cfg, mesh
            )
            in_sh = (
                shd.named(p_specs, mesh),
                shd.named(c_specs, mesh),
                jax.sharding.NamedSharding(mesh, tok_specs["token"]),
                jax.sharding.NamedSharding(mesh, tok_specs["pos"]),
            )
            out_sh = (None, shd.named(c_specs, mesh))
            lowered = jax.jit(
                model.decode_step,
                in_shardings=in_sh,
                out_shardings=out_sh,
                donate_argnums=(1,),
            ).lower(
                serve_params, specs["cache"], specs["token"], specs["pos"]
            )
        compiled = lowered.compile()
    return compiled, lowered


_CONVERT_RE = None


def _bf16_emulation_bytes(text: str) -> int:
    """Bytes of large f32 buffers produced by bf16->f32 `convert`s.

    The CPU backend emulates bf16 dots in f32 and hoists the conversion
    of loop-carried bf16 stacks (KV caches, residual saves) out of the
    loop, materializing an f32 twin of the whole stack. On TPU bf16 is
    native and these buffers do not exist; we quantify them so the
    fits-in-HBM check can be read both raw (CPU artifact included) and
    adjusted (TPU-realistic).
    """
    import re as _re

    total = 0
    pat = _re.compile(
        r"= f32\[([\d,]+)\][^ ]* (?:convert|fusion)\("
    )
    seen = set()
    for line in text.splitlines():
        if "convert" not in line:
            continue
        m = pat.search(line)
        if not m:
            continue
        dims = [int(x) for x in m.group(1).split(",")]
        n = 4
        for d in dims:
            n *= d
        if n >= 1 << 28 and m.group(1) not in seen:
            seen.add(m.group(1))
            total += n
    return total


def analyze(compiled, cfg: ArchConfig, cell: ShapeCell, mesh) -> dict:
    from repro.launch.hlo_count import weighted_cost

    n_dev = mesh.size
    from repro._compat import cost_analysis_dict

    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    wc = weighted_cost(compiled.as_text())  # loop-aware (hlo_count.py)
    mf = model_flops(cfg, cell)
    terms = H.roofline_terms(
        per_device_flops=wc.flops,
        per_device_bytes=wc.bytes_accessed,
        per_device_collective_bytes=wc.collective_bytes,
        model_flops_total=mf,
        n_devices=n_dev,
        per_device_arg_bytes=float(ma.argument_size_in_bytes),
    )
    return {
        "arch": cfg.name,
        "shape": cell.name,
        "kind": cell.kind,
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in
                                           mesh.axis_names])),
        "n_devices": n_dev,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_per_device_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
            # CPU-backend bf16-emulation f32 twins (absent on TPU):
            "bf16_emulation_bytes": int(
                _bf16_emulation_bytes(compiled.as_text())
            ),
        },
        "cost": {
            "per_device_flops": wc.flops,
            "per_device_bytes_accessed": wc.bytes_accessed,
            "xla_cost_analysis_flops_unscaled": float(
                ca.get("flops", 0.0)
            ),
            "loops": wc.loops,
            "top_bytes": [list(t) for t in wc.top_bytes],
            "top_flops": [list(t) for t in wc.top_flops],
        },
        "collectives": {
            "bytes_by_op": wc.collective_by_op,
            "count_by_op": wc.collective_counts,
            "total_bytes": wc.collective_bytes,
        },
        "roofline": terms,
    }


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    out_dir: str,
    *,
    spe_bits: Optional[int] = None,
    spe_sparse: bool = False,
    serve_quant_bits: Optional[int] = None,
    tag: str = "",
    overrides: Optional[dict] = None,
) -> dict:
    cfg = configs.get(arch)
    if spe_bits is not None or spe_sparse:
        cfg = dataclasses.replace(
            cfg, spe_bits=spe_bits, spe_sparse=spe_sparse
        )
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = configs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    compiled, lowered = lower_cell(
        cfg, cell, mesh, serve_quant_bits=serve_quant_bits
    )
    dt = time.monotonic() - t0
    rec = analyze(compiled, cfg, cell, mesh)
    rec["compile_s"] = dt
    rec["serve_quant_bits"] = serve_quant_bits
    rec["spe_bits"] = spe_bits
    rec["spe_sparse"] = spe_sparse
    mesh_name = "multipod_2x16x16" if multi_pod else "singlepod_16x16"
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    stem = f"{cfg.name.replace('/', '_')}__{shape}{tag}"
    with open(os.path.join(d, stem + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    # persist the optimized HLO so analyzer improvements can re-analyze
    # without recompiling (`--reanalyze`)
    import gzip

    with gzip.open(os.path.join(d, stem + ".hlo.gz"), "wt") as f:
        f.write(compiled.as_text())
    adj = (
        rec["memory"]["total_per_device_bytes"]
        - rec["memory"]["bf16_emulation_bytes"]
    )
    print(
        f"[dryrun] {cfg.name:24s} {shape:12s} {mesh_name:18s} "
        f"compile={dt:6.1f}s mem/dev={rec['memory']['total_per_device_bytes']/2**30:6.2f}GiB "
        f"(tpu-adj {adj/2**30:6.2f}) "
        f"dominant={rec['roofline']['dominant']:10s} "
        f"frac={rec['roofline']['roofline_fraction']:.3f}"
    )
    return rec


def reanalyze(out_dir: str) -> None:
    """Re-run the HLO analysis over stored .hlo.gz artifacts (no
    compilation) and refresh the roofline/collective fields in place."""
    import glob
    import gzip

    from repro.launch.hlo_count import weighted_cost

    n = 0
    for fn in sorted(glob.glob(os.path.join(out_dir, "*", "*.hlo.gz"))):
        jf = fn[: -len(".hlo.gz")] + ".json"
        if not os.path.exists(jf):
            continue
        with open(jf) as f:
            rec = json.load(f)
        with gzip.open(fn, "rt") as f:
            wc = weighted_cost(f.read())
        mf = rec["roofline"]["model_flops_total"]
        rec["cost"].update({
            "per_device_flops": wc.flops,
            "per_device_bytes_accessed": wc.bytes_accessed,
            "loops": wc.loops,
            "top_bytes": [list(t) for t in wc.top_bytes],
            "top_flops": [list(t) for t in wc.top_flops],
        })
        rec["collectives"] = {
            "bytes_by_op": wc.collective_by_op,
            "count_by_op": wc.collective_counts,
            "total_bytes": wc.collective_bytes,
        }
        rec["roofline"] = H.roofline_terms(
            per_device_flops=wc.flops,
            per_device_bytes=wc.bytes_accessed,
            per_device_collective_bytes=wc.collective_bytes,
            model_flops_total=mf,
            n_devices=rec["n_devices"],
            per_device_arg_bytes=float(rec["memory"]["argument_bytes"]),
        )
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"[dryrun] reanalyzed {n} cells")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", type=str, default=None,
                    help="arch id (e.g. qwen3-8b); default: all")
    ap.add_argument("--shape", type=str, default=None,
                    help="shape cell; default: all applicable")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--spe-bits", type=int, default=None)
    ap.add_argument("--spe-sparse", action="store_true")
    ap.add_argument("--serve-quant-bits", type=int, default=None)
    ap.add_argument("--kv-quant-bits", type=int, default=None)
    ap.add_argument("--moe-shard", type=str, default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="re-analyze stored .hlo.gz without compiling")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.out)
        return

    overrides = {}
    if args.kv_quant_bits is not None:
        overrides["kv_quant_bits"] = args.kv_quant_bits
    if args.moe_shard is not None:
        overrides["moe_shard"] = args.moe_shard
    if args.loss_chunk is not None:
        overrides["loss_chunk"] = args.loss_chunk
    if args.microbatches is not None:
        overrides["train_microbatches"] = args.microbatches

    archs = (
        [args.arch] if args.arch else list(configs.CLI_IDS.keys())
    )
    meshes = {
        "single": [False], "multi": [True], "both": [False, True],
    }[args.mesh]

    todo = []
    for a in archs:
        cfg = configs.get(a)
        cells = (
            [configs.SHAPES[args.shape]] if args.shape
            else applicable_shapes(cfg)
        )
        for c in cells:
            for mp in meshes:
                todo.append((a, c.name, mp))
    if args.list:
        for a, s, mp in todo:
            print(a, s, "multi" if mp else "single")
        print(f"{len(todo)} cells")
        return

    failures = []
    for a, s, mp in todo:
        try:
            run_cell(
                a, s, mp, args.out,
                spe_bits=args.spe_bits, spe_sparse=args.spe_sparse,
                serve_quant_bits=args.serve_quant_bits, tag=args.tag,
                overrides=overrides,
            )
        except Exception as e:  # noqa: BLE001 — report all failures at end
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] FAIL {a} {s} {'multi' if mp else 'single'}: {e}")
            traceback.print_exc()
    print(f"\n[dryrun] {len(todo) - len(failures)}/{len(todo)} cells passed")
    if failures:
        for f in failures:
            print("  FAIL:", *f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
