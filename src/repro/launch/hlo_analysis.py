"""Post-SPMD HLO analysis: collective bytes + roofline term extraction.

`cost_analysis()` gives per-device FLOPs / bytes-accessed but nothing
about collectives, so we parse the optimized HLO text
(`compiled.as_text()`) and classify every collective op.

Byte convention (per device, per executed step):
  all-reduce          result_bytes            (ring sends ~2x(n-1)/n ~ 2x;
                                               we count operand size per
                                               the assignment and apply
                                               ring factors in roofline)
  all-gather          result_bytes / group    (operand = one shard)
  reduce-scatter      result_bytes * group    (operand = full tensor)
  all-to-all          result_bytes
  collective-permute  result_bytes

'-start'/'-done' async pairs are counted once (on '-start').
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)"
    r"(-start)?\b"
)
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict
    total_bytes: int

    def to_dict(self) -> dict:
        return {
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
            "total_bytes": int(self.total_bytes),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by_op: dict = defaultdict(int)
    count_by_op: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # result shape(s): everything before the op token on the lhs
        lhs = line[: m.start()] + line[m.start(): m.end()]
        result_bytes = _shape_bytes(line[: m.end()])
        g = _group_size(line)
        if op == "all-gather":
            b = result_bytes // max(g, 1)
        elif op == "reduce-scatter":
            b = result_bytes * g
        else:
            b = result_bytes
        bytes_by_op[op] += b
        count_by_op[op] += 1
    return CollectiveStats(
        bytes_by_op=dict(bytes_by_op),
        count_by_op=dict(count_by_op),
        total_bytes=sum(bytes_by_op.values()),
    )


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e single-chip constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


def roofline_terms(
    *,
    per_device_flops: float,
    per_device_bytes: float,
    per_device_collective_bytes: float,
    model_flops_total: float,
    n_devices: int,
    per_device_arg_bytes: float = 0.0,
) -> dict:
    """The three roofline terms in seconds (per step, per device — the
    SPMD program is identical on every device, so per-device == critical
    path under perfect overlap).

    roofline_fraction = ideal time / binding term, where ideal is the
    LARGER of (a) useful MODEL_FLOPS at peak and (b) reading every live
    input byte (params + caches) exactly once at HBM bandwidth — (b) is
    the honest floor for memory-bound decode, where MODEL_FLOPS alone
    would make any KV-dominated step look like 0."""
    t_compute = per_device_flops / PEAK_FLOPS_BF16
    t_memory = per_device_bytes / HBM_BW
    t_coll = per_device_collective_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_coll), key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    useful = model_flops_total / max(per_device_flops * n_devices, 1.0)
    t_useful = (model_flops_total / n_devices) / PEAK_FLOPS_BF16
    t_ideal = max(t_useful, per_device_arg_bytes / HBM_BW)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops_total": model_flops_total,
        "hlo_flops_total": per_device_flops * n_devices,
        "useful_flops_ratio": useful,
        "t_ideal_s": t_ideal,
        "roofline_fraction": (t_ideal / bound) if bound > 0 else 0.0,
    }
