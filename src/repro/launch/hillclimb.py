import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower one cell under a named variant and
print the roofline-term deltas vs the recorded baseline.

    python -m repro.launch.hillclimb --arch qwen3-8b --shape decode_32k \\
        --variant kv8 --tag _kv8

Variants are (cfg overrides, serve_quant_bits) pairs; results are written
next to the baselines with the tag suffix so EXPERIMENTS.md §Perf can
cite both.
"""

import argparse
import json

from repro.launch.dryrun import run_cell

VARIANTS = {
    # bf16 blockwise scores/softmax (halves score-tensor traffic;
    # accumulators stay f32) — applied via attention.score_dtype
    "smbf16": dict(score_bf16=True),
    # paper-faithful transfer: packed int8/int4 weights (CMUL storage)
    "w8": dict(serve_quant_bits=8),
    "w4": dict(serve_quant_bits=4),
    # beyond-paper: int8 KV cache (quantized storage on the decode-
    # dominant tensor)
    "kv8": dict(overrides={"kv_quant_bits": 8}),
    "kv8w8": dict(serve_quant_bits=8, overrides={"kv_quant_bits": 8}),
    # MoE expert sharding: replicate experts over data (kill the
    # D-contraction all-reduce)
    "moe_tp": dict(overrides={"moe_shard": "tp_only"}),
    # chunked CE (live-logits memory)
    "ce512": dict(overrides={"loss_chunk": 512}),
    "ce512_moe_tp": dict(
        overrides={"loss_chunk": 512, "moe_shard": "tp_only"}
    ),
    # attention block-size sweep
    "blk1024": dict(overrides={"attn_block": 1024}),
    "blk2048": dict(overrides={"attn_block": 2048}),
    # microbatching sweep
    "mb2": dict(overrides={"train_microbatches": 2}),
    "mb4": dict(overrides={"train_microbatches": 4}),
    "mb8": dict(overrides={"train_microbatches": 8}),
    # SPE QAT knobs on the train path (paper technique in training)
    "spe8": dict(spe_bits=8),
    "spe8s": dict(spe_bits=8, spe_sparse=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    v = VARIANTS[args.variant]
    ctx = None
    if v.get("score_bf16"):
        import contextlib

        import jax.numpy as jnp

        from repro.models import attention as _A

        ctx = _A.score_dtype(jnp.bfloat16)
        ctx.__enter__()
    rec = run_cell(
        args.arch, args.shape, args.mesh == "multi", args.out,
        spe_bits=v.get("spe_bits"), spe_sparse=v.get("spe_sparse", False),
        serve_quant_bits=v.get("serve_quant_bits"),
        overrides=v.get("overrides"), tag=f"_{args.variant}",
    )
    # print the before/after against the untagged baseline
    mesh_name = (
        "multipod_2x16x16" if args.mesh == "multi" else "singlepod_16x16"
    )
    base_fn = os.path.join(
        args.out, mesh_name, f"{rec['arch']}__{args.shape}.json"
    )
    if os.path.exists(base_fn):
        base = json.load(open(base_fn))
        b, n = base["roofline"], rec["roofline"]
        print(f"\n{'term':<16}{'baseline':>12}{'variant':>12}{'delta':>9}")
        for key in ("t_compute_s", "t_memory_s", "t_collective_s",
                    "bound_s", "roofline_fraction"):
            bv, nv = b[key], n[key]
            d = (nv - bv) / bv * 100 if bv else float("nan")
            print(f"{key:<16}{bv:>12.4g}{nv:>12.4g}{d:>8.1f}%")


if __name__ == "__main__":
    main()
