"""SGD / Adam / AdamW as pure pytree transforms.

Each optimizer is an `Optimizer(init, update)` pair:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = jax.tree.map(lambda p, u: p + u, params, updates)

Design points for the distributed trainer:
  * optimizer state mirrors the param pytree leaf-for-leaf, so the same
    PartitionSpecs shard it (ZeRO-1 falls out of FSDP for free);
  * `step` is passed in (not carried) so state is pure per-leaf moments —
    checkpoint/reshard logic stays shape-generic;
  * learning rate is a schedule callable evaluated inside `update`, so
    one jitted train_step serves the whole run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
PyTree = object


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr, *, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {"mu": None}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr_t = sched(step)
        if momentum == 0.0:
            upd = jax.tree.map(lambda g: -lr_t * g, grads)
            return upd, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g, state["mu"], grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: -lr_t * (momentum * m + g), mu, grads
            )
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, {"mu": mu}

    return Optimizer(init, update)


def adam(
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    mask: Optional[Callable[[tuple], bool]] = None,
) -> Optimizer:
    """AdamW with bias correction; `mask(path)` gates weight decay
    (norms/biases are excluded by the trainer's default mask)."""
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        lr_t = sched(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )

        if mask is None and weight_decay != 0.0:
            decay_tree = jax.tree.map(lambda p: True, params)
        elif weight_decay != 0.0:
            decay_tree = jax.tree.map_with_path(
                lambda path, p: bool(mask(path)), params
            )
        else:
            decay_tree = jax.tree.map(lambda p: False, params)

        def upd(mm, vv, p, do_decay):
            step_dir = (mm / c1) / (jnp.sqrt(vv / c2) + eps)
            if weight_decay != 0.0:
                wd = jnp.where(do_decay, weight_decay, 0.0)
                step_dir = step_dir + wd * p.astype(jnp.float32)
            return (-lr_t * step_dir).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params, decay_tree)
        return updates, {"m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
