"""Optimizers from scratch (pytree transforms, no optax dependency)."""

from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    sgd,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
)
from repro.optim.clip import clip_by_global_norm, global_norm

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "sgd",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
    "clip_by_global_norm",
    "global_norm",
]
