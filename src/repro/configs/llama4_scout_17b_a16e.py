"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) head_dim=128, MoE 16 experts top-1
(d_ff_expert=8192) + shared expert; chunked attention (8192) on 3/4
layers, global on 1/4 -> long_500k runs (global KV at B=1 is linear).
"""

from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    pattern=("chunked", "chunked", "chunked", "global"),
    window=8192,
    moe=MoESpec(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        shared_expert_ff=8192,
    ),
    rope_theta=5e5,
    supports_decode=True,
    supports_long=True,
)

REDUCED = ArchConfig(
    name="llama4-reduced",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("chunked", "chunked", "chunked", "global"),
    window=8,
    moe=MoESpec(
        num_experts=4, top_k=1, d_ff_expert=128, shared_expert_ff=128,
        capacity_factor=8.0,  # dropless at smoke scale: decode==train exact
    ),
    rope_theta=5e5,
    supports_decode=True,
    supports_long=True,
)
