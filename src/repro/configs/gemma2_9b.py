"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000, head_dim=256,
window 4096, attn softcap 50, final softcap 30, GeGLU, sandwich norms,
sqrt(d) embedding scale.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256_000,
    pattern=("local", "global"),
    window=4096,
    act="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    rope_theta=1e4,
    supports_decode=True,
    supports_long=False,  # half the layers are global full attention
)

REDUCED = ArchConfig(
    name="gemma2-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("local", "global"),
    window=8,
    act="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    supports_decode=True,
    supports_long=False,
)
