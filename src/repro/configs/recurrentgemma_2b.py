"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000. Pattern
(rglru, rglru, local) x 8 + (rglru, rglru) tail = 26 layers. Window 2048,
head_dim 256 (Griffin-2B). O(1)/windowed state -> runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    act="geglu",
    norm="rmsnorm",
    scale_embed=True,
    lru_width=2560,
    conv_width=4,
    kv_mode="replicate",  # kv=1 (MQA): replicate over TP
    supports_decode=True,
    supports_long=True,
)

REDUCED = ArchConfig(
    name="recurrentgemma-reduced",
    family="hybrid",
    n_layers=5,  # 1 full period + (r, r) tail — exercises tail path
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("rglru", "rglru", "local"),
    window=8,
    act="geglu",
    norm="rmsnorm",
    scale_embed=True,
    lru_width=64,
    conv_width=4,
    kv_mode="replicate",
    supports_decode=True,
    supports_long=True,
)
