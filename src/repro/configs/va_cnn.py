"""va-cnn — the paper's own workload: 8-layer 1-D FCN VA detector.

Not an LM; selectable via --arch va-cnn in the launchers. The model lives
in `core.vadetect`; this module only exposes the operating points
(paper point = 16:8 balanced sparsity + 8-bit quantization, and the
mixed-precision demo point).
"""

from repro.core.spe import SPEConfig
from repro.core.vadetect import VAConfig

# Paper operating point: 50% balanced sparsity, 8-bit weights.
CONFIG = VAConfig(
    spe=SPEConfig(bits=8, group_size=16, keep=8, sparse=True,
                  quantized=True)
)

# Mixed-precision demo: early layers 8-bit, middle 4-bit, late 8-bit —
# the CMUL's raison d'être.
MIXED = VAConfig(
    spe=SPEConfig(bits=8, group_size=16, keep=8, sparse=True,
                  quantized=True),
    layer_bits=(8, 8, 4, 4, 4, 4, 8, 8),
)

# Dense float baseline (paper's implicit comparison point).
DENSE = VAConfig(spe=None)

REDUCED = CONFIG  # already CPU-sized (~31k params)
