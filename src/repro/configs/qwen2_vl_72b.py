"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) head_dim=128 d_ff=29568 vocab=152064.
Backbone only (assignment): the vision frontend is a stub — M-RoPE
consumes (t, h, w) position grids; the text stub feeds equal rows, which
reduces exactly to 1-D RoPE. Sections (16, 24, 24) of hd/2=64.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
    # 72B at 1M tokens/step on 256 chips: 4 microbatches bound the
    # activation residency (saved scan carries + logits CE) under HBM.
    train_microbatches=4,
)

REDUCED = ArchConfig(
    name="qwen2vl-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    mrope_sections=(4, 2, 2),
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
)
