"""codeqwen1.5-7b [dense] — qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (MHA, kv=32) d_ff=13440 vocab=92416. QKV biases
(qwen1.5 family). Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
)

REDUCED = ArchConfig(
    name="codeqwen-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
)
