"""whisper-tiny [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

4L (enc) + 4L (dec) d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
Modality frontend is a stub: input_specs() provides precomputed frame
embeddings (B, 1500, 384). Tiny model -> pure-DP parallelism profile
(use_tp=False): the 'model' mesh axis joins data parallelism instead of
fragmenting 6 heads over 16 shards.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    use_tp=False,
    fsdp=False,
    supports_decode=True,
    supports_long=False,  # decoder context is architecturally 448
)

REDUCED = ArchConfig(
    name="whisper-reduced",
    family="audio",
    n_layers=2,
    enc_layers=2,
    enc_seq=16,
    d_model=48,
    n_heads=3,
    n_kv_heads=3,
    d_ff=96,
    vocab=256,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    use_tp=False,
    fsdp=False,
    supports_decode=True,
    supports_long=False,
)
