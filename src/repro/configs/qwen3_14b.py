"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, head_dim=128.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
)

REDUCED = ArchConfig(
    name="qwen3-14b-reduced",
    family="dense",
    n_layers=3,
    d_model=80,
    n_heads=5,
    n_kv_heads=1,
    head_dim=16,
    d_ff=160,
    vocab=512,
    qk_norm=True,
    rope_theta=1e6,
    supports_decode=True,
    supports_long=False,
)
