"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16) d_ff=1024 (per expert) vocab=50304,
MoE 64e top-8, qk_norm (OLMoE uses QK-norm).
"""

from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    qk_norm=True,
    moe=MoESpec(num_experts=64, top_k=8, d_ff_expert=1024),
    rope_theta=1e4,
    supports_decode=True,
    supports_long=False,
)

REDUCED = ArchConfig(
    name="olmoe-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=512,
    qk_norm=True,
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=64,
                capacity_factor=8.0),  # dropless at smoke scale
    supports_decode=True,
    supports_long=False,
)
