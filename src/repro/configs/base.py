"""Config schema: architectures × input-shape cells.

`ArchConfig` is the single description every layer of the framework reads:
model building (`models.api.build_model`), sharding (`dist.sharding`),
the dry-run (`launch.dryrun`) and the roofline report all consume it.

The paper's technique is the `spe_bits` / `spe_sparse` knobs: setting them
swaps dense projections for `core.spe` sparse-quantized operators (QAT in
training, compressed storage in serving). The dry-run baseline keeps them
off (dense bf16 = paper-faithful baseline for the LM substrate); §Perf
turns them on as the beyond-paper memory-roofline optimization.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert_ff: int = 0  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Block pattern, repeated every len(pattern) layers; a tail of
    # n_layers % len(pattern) layers is unrolled after the scan.
    # Kinds: global | local | chunked | rglru | rwkv
    pattern: tuple[str, ...] = ("global",)
    window: int = 0  # local window / chunk size (elements)

    act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qk_norm: bool = False
    qkv_bias: bool = False  # qwen1.5-style attention biases
    sandwich_norm: bool = False  # gemma2 pre+post block norms
    scale_embed: bool = False  # gemma-family sqrt(d) embedding scale
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w)
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None

    # ssm / hybrid details
    rwkv_head_dim: int = 64
    lru_width: int = 0  # rglru recurrence width (0 -> d_model)
    conv_width: int = 4

    # encoder-decoder (whisper): decoder uses the main fields
    enc_layers: int = 0
    enc_seq: int = 0  # stub-frontend frame count

    # shape-cell applicability
    supports_decode: bool = True
    supports_long: bool = False  # sub-quadratic decode at 500k

    # --- the paper's technique, as a first-class knob -------------------
    spe_bits: Optional[int] = None  # 8/4/2/1 weight bits (None = bf16)
    spe_sparse: bool = False  # 50% balanced (16:8) pruning
    spe_group: int = 16
    spe_keep: int = 8

    # parallelism profile (consumed by dist.sharding)
    use_tp: bool = True  # False -> pure DP over all mesh axes
    fsdp: bool = True
    train_microbatches: int = 1  # gradient-accumulation chunks per step

    # --- beyond-paper optimization knobs (§Perf hillclimb) --------------
    kv_quant_bits: Optional[int] = None  # int8 KV cache (decode memory)
    moe_shard: str = "tp_fsdp"  # tp_fsdp | tp_only (experts replicated
    #                             over data: kills the D-contraction
    #                             all-reduce for small-expert models)
    loss_chunk: int = 0  # chunked CE over S (0 = off): bounds live
    #                      logits to (B, chunk, V)
    attn_block: int = 512  # blockwise-attention q/kv tile size
    kv_mode: str = "pad"  # pad | replicate (kv heads vs TP degree)
    remat: str = "block"  # none | block
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.period

    @property
    def tail(self) -> tuple[str, ...]:
        return self.pattern[: self.n_layers % self.period]

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> list[str]:
        return [
            self.pattern[i % self.period] for i in range(self.n_layers)
        ]

    def validate(self) -> None:
        assert self.family in (
            "dense", "moe", "ssm", "hybrid", "audio", "vlm",
        ), self.family
        if self.family == "moe":
            assert self.moe is not None
        for k in self.pattern:
            assert k in ("global", "local", "chunked", "rglru", "rwkv"), k
        if any(k in ("local", "chunked") for k in self.pattern):
            assert self.window > 0
        if self.head_dim == 0:
            assert self.d_model % self.n_heads == 0


# ---------------------------------------------------------------------------
# Input-shape cells (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeCell]:
    """The assignment's skip rules (documented in DESIGN.md §4)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.supports_decode:
        out.append(SHAPES["decode_32k"])
        if cfg.supports_long:
            out.append(SHAPES["long_500k"])
    return out


def pad_up(x: int, m: int) -> int:
    return -(-x // m) * m
