"""Config registry: the paper's va_cnn + 10 assigned LM architectures.

Every module exposes CONFIG (the exact assigned dims) and REDUCED (a
same-family small config for CPU smoke tests). `get(name)` / `reduced(name)`
look them up; `ALL_ARCHS` lists the assigned ten.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    ArchConfig,
    MoESpec,
    ShapeCell,
    applicable_shapes,
)

ALL_ARCHS = (
    "rwkv6_3b",
    "recurrentgemma_2b",
    "whisper_tiny",
    "codeqwen15_7b",
    "qwen3_8b",
    "qwen3_14b",
    "gemma2_9b",
    "llama4_scout_17b_a16e",
    "olmoe_1b_7b",
    "qwen2_vl_72b",
)

# CLI ids (--arch) use dashes, matching the assignment sheet.
CLI_IDS = {
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-tiny": "whisper_tiny",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-8b": "qwen3_8b",
    "qwen3-14b": "qwen3_14b",
    "gemma2-9b": "gemma2_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def _module(name: str):
    mod = CLI_IDS.get(name, name).replace("-", "_").replace(".", "")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def reduced(name: str) -> ArchConfig:
    return _module(name).REDUCED


__all__ = [
    "ALL_ARCHS",
    "CLI_IDS",
    "SHAPES",
    "ArchConfig",
    "MoESpec",
    "ShapeCell",
    "applicable_shapes",
    "get",
    "reduced",
]
