"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536. O(1) decode state ->
runs the long_500k cell.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # wkv heads = d_model / 64 (bookkeeping; attn-free)
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    pattern=("rwkv",),
    norm="layernorm",
    rwkv_head_dim=64,
    supports_decode=True,
    supports_long=True,
)

REDUCED = ArchConfig(
    name="rwkv6-reduced",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    pattern=("rwkv",),
    norm="layernorm",
    rwkv_head_dim=16,
    supports_decode=True,
    supports_long=True,
)
