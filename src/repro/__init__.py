"""repro — mixed-bit-width sparse CNN accelerator reproduction grown
into a jax LM training/serving substrate.

Importing the package installs small jax forward-compat shims (see
`repro._compat`) so every entry point — tests, launchers, benchmarks —
sees the same mesh API regardless of the installed jax version.
"""

from repro import _compat as _compat

_compat.install()
