"""Data substrate: synthetic IEGM pipeline + LM token pipeline."""

from repro.data import iegm, lm

__all__ = ["iegm", "lm"]
