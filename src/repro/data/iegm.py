"""Synthetic IEGM data pipeline matching the paper's acquisition spec.

The paper's dataset (SingularMedical, single-lead RVA-Bi intracardiac
electrograms) is proprietary; we synthesize morphologically-plausible
recordings with the same front-end spec so the *pipeline* — 512 samples
@ 250 Hz, 15–55 Hz band-pass, 6-segment majority vote — is reproduced
end-to-end and the accuracy numbers are honestly labelled "synthetic".

Classes:
  0  non-VA : normal sinus rhythm (NSR) — periodic sharp ventricular
              depolarizations at 60–100 bpm + baseline wander + noise.
  1  VA     : ventricular tachycardia (VT: fast monomorphic, 150–250 bpm)
              or ventricular fibrillation (VF: disorganized, drifting
              frequency content 3–8 Hz, no discrete beats).

The band-pass filter is a windowed-sinc FIR (no scipy dependency); the
same filter is applied to every class, as the front-end hardware would.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

SAMPLE_RATE_HZ = 250.0
RECORD_LEN = 512
BAND_LO_HZ = 15.0
BAND_HI_HZ = 55.0
VOTE_SEGMENTS = 6


# ---------------------------------------------------------------------------
# 15–55 Hz FIR band-pass (windowed sinc, Hamming), as a fixed conv.
# ---------------------------------------------------------------------------


def bandpass_taps(
    num_taps: int = 101,
    lo_hz: float = BAND_LO_HZ,
    hi_hz: float = BAND_HI_HZ,
    fs: float = SAMPLE_RATE_HZ,
) -> np.ndarray:
    """Linear-phase FIR band-pass taps (difference of low-passes)."""
    assert num_taps % 2 == 1, "odd taps for zero-phase-delay symmetry"
    m = np.arange(num_taps) - (num_taps - 1) / 2
    def lp(fc):
        h = np.sinc(2 * fc / fs * m) * (2 * fc / fs)
        return h * np.hamming(num_taps)
    taps = lp(hi_hz) - lp(lo_hz)
    return taps.astype(np.float32)


_TAPS = jnp.asarray(bandpass_taps())


def bandpass(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T) zero-padded 'same' FIR filtering."""
    lead = x.shape[:-1]
    t = x.shape[-1]
    xf = x.reshape(-1, 1, t)  # (B, C=1, T)
    y = jax.lax.conv_general_dilated(
        xf,
        _TAPS.reshape(1, 1, -1),
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NCH", "IOH", "NCH"),
    )
    return y.reshape(*lead, t)


def filter_response_db(freq_hz: np.ndarray) -> np.ndarray:
    """|H(f)| in dB for test assertions on the pass/stop bands."""
    taps = bandpass_taps()
    w = 2j * np.pi * freq_hz[:, None] / SAMPLE_RATE_HZ
    h = np.exp(-w * np.arange(len(taps))[None, :]) @ taps
    return 20 * np.log10(np.maximum(np.abs(h), 1e-12))


# ---------------------------------------------------------------------------
# Morphology synthesis
# ---------------------------------------------------------------------------


def _nsr(key: jax.Array, n: int) -> jax.Array:
    """Normal sinus rhythm: discrete beats at 60–100 bpm."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    t = jnp.arange(RECORD_LEN) / SAMPLE_RATE_HZ  # (T,)
    bpm = jax.random.uniform(k1, (n, 1), minval=60.0, maxval=100.0)
    phase = jax.random.uniform(k2, (n, 1), minval=0.0, maxval=1.0)
    beat_phase = (t[None, :] * bpm / 60.0 + phase) % 1.0
    # sharp biphasic depolarization spike (narrow gaussian derivative)
    width = jax.random.uniform(k3, (n, 1), minval=0.012, maxval=0.022)
    z = (beat_phase - 0.5) / width
    spike = -z * jnp.exp(-0.5 * z * z)  # biphasic
    amp = jax.random.uniform(k4, (n, 1), minval=0.8, maxval=1.4)
    return amp * spike


def _vt(key: jax.Array, n: int) -> jax.Array:
    """Monomorphic VT: fast (150–250 bpm) wide-complex oscillation."""
    k1, k2, k3 = jax.random.split(key, 3)
    t = jnp.arange(RECORD_LEN) / SAMPLE_RATE_HZ
    bpm = jax.random.uniform(k1, (n, 1), minval=150.0, maxval=250.0)
    phase = jax.random.uniform(k2, (n, 1), minval=0.0, maxval=1.0)
    f = bpm / 60.0
    base = jnp.sin(2 * jnp.pi * (f * t[None, :] + phase))
    # wide complexes: add 2nd harmonic w/ fixed relation (monomorphic)
    amp = jax.random.uniform(k3, (n, 1), minval=0.9, maxval=1.5)
    return amp * (base + 0.45 * jnp.sin(4 * jnp.pi * (f * t[None, :] + phase)))


def _vf(key: jax.Array, n: int) -> jax.Array:
    """VF: disorganized — sum of drifting 3–8 Hz components, random walk."""
    keys = jax.random.split(key, 5)
    t = jnp.arange(RECORD_LEN) / SAMPLE_RATE_HZ
    out = jnp.zeros((n, RECORD_LEN))
    for i in range(3):
        kf, ka = jax.random.split(keys[i], 2)
        f0 = jax.random.uniform(kf, (n, 1), minval=3.0, maxval=8.0)
        drift = jnp.cumsum(
            jax.random.normal(ka, (n, RECORD_LEN)) * 0.4, axis=1
        ) / SAMPLE_RATE_HZ
        amp = jax.random.uniform(keys[3], (n, 1), minval=0.3, maxval=0.8)
        out = out + amp * jnp.sin(2 * jnp.pi * (f0 * t[None, :] + drift))
    return out


def _noise(key: jax.Array, n: int) -> jax.Array:
    k1, k2 = jax.random.split(key)
    white = jax.random.normal(k1, (n, RECORD_LEN)) * 0.08
    # baseline wander (respiration ~0.3 Hz) — removed by the band-pass
    t = jnp.arange(RECORD_LEN) / SAMPLE_RATE_HZ
    wander_f = jax.random.uniform(k2, (n, 1), minval=0.15, maxval=0.45)
    wander = 0.6 * jnp.sin(2 * jnp.pi * wander_f * t[None, :])
    return white + wander


def synth_batch(
    key: jax.Array, batch: int, *, filtered: bool = True
) -> dict[str, jax.Array]:
    """Balanced batch of {signal (B, 512) f32, label (B,) i32}."""
    k_lab, k_nsr, k_vt, k_vf, k_noise, k_mix = jax.random.split(key, 6)
    labels = jax.random.bernoulli(k_lab, 0.5, (batch,)).astype(jnp.int32)
    nsr = _nsr(k_nsr, batch)
    vt = _vt(k_vt, batch)
    vf = _vf(k_vf, batch)
    is_vf = jax.random.bernoulli(k_mix, 0.5, (batch, 1))
    va = jnp.where(is_vf, vf, vt)
    sig = jnp.where(labels[:, None] == 1, va, nsr) + _noise(k_noise, batch)
    if filtered:
        sig = bandpass(sig)
    # per-record normalization (front-end AGC)
    sig = sig / (jnp.std(sig, axis=1, keepdims=True) + 1e-6)
    return {"signal": sig.astype(jnp.float32), "label": labels}


def synth_diagnosis_batch(
    key: jax.Array, batch: int, *, segments: int = VOTE_SEGMENTS
) -> dict[str, jax.Array]:
    """Per-patient batches of `segments` recordings sharing one diagnosis."""
    k_lab, k_sig = jax.random.split(key)
    labels = jax.random.bernoulli(k_lab, 0.5, (batch,)).astype(jnp.int32)
    seg_labels = jnp.repeat(labels, segments)
    flat = synth_batch(k_sig, batch * segments)
    # overwrite labels so all segments of one patient agree
    k_nsr, k_vt, k_vf, k_noise, k_mix = jax.random.split(k_sig, 5)
    nsr = _nsr(k_nsr, batch * segments)
    vt = _vt(k_vt, batch * segments)
    vf = _vf(k_vf, batch * segments)
    is_vf = jax.random.bernoulli(k_mix, 0.5, (batch * segments, 1))
    va = jnp.where(is_vf, vf, vt)
    sig = jnp.where(seg_labels[:, None] == 1, va, nsr) + _noise(
        k_noise, batch * segments
    )
    sig = bandpass(sig)
    sig = sig / (jnp.std(sig, axis=1, keepdims=True) + 1e-6)
    return {
        "signal": sig.reshape(batch, segments, RECORD_LEN).astype(
            jnp.float32
        ),
        "label": labels,
    }


# ---------------------------------------------------------------------------
# Per-patient deterministic segment streams (the fleet-monitoring feed)
# ---------------------------------------------------------------------------

# Distinguishes the per-patient *condition* draw from per-segment draws:
# segment keys are fold_in(patient_key, seq), so the label fold constant
# must sit outside any reachable seq (seqs are segment counters).
_LABEL_FOLD = 0x7FFFFFFF


def _segment_one(key: jax.Array, label: jax.Array) -> jax.Array:
    """One raw (unfiltered) 512-sample segment for a given class label."""
    k_nsr, k_vt, k_vf, k_mix, k_noise = jax.random.split(key, 5)
    nsr = _nsr(k_nsr, 1)[0]
    vt = _vt(k_vt, 1)[0]
    vf = _vf(k_vf, 1)[0]
    is_vf = jax.random.bernoulli(k_mix, 0.5)
    va = jnp.where(is_vf, vf, vt)
    return jnp.where(label == 1, va, nsr) + _noise(k_noise, 1)[0]


def _patient_keys(seed: int, patient_ids: jax.Array) -> jax.Array:
    root = jax.random.PRNGKey(seed)
    pids = jnp.asarray(patient_ids, jnp.uint32)
    return jax.vmap(lambda p: jax.random.fold_in(root, p))(pids)


def _labels_from_keys(pkeys: jax.Array, va_fraction: float) -> jax.Array:
    return jax.vmap(
        lambda k: jax.random.bernoulli(
            jax.random.fold_in(k, _LABEL_FOLD), va_fraction
        )
    )(pkeys).astype(jnp.int32)


def patient_labels(
    seed: int, patient_ids: jax.Array, va_fraction: float = 0.5
) -> jax.Array:
    """Persistent per-patient condition (0 non-VA / 1 VA), drawn once per
    patient from fold_in(PRNGKey(seed), patient_id) so every view of the
    fleet (sources, tests, benchmarks) agrees on the ground truth."""
    return _labels_from_keys(
        _patient_keys(seed, patient_ids), va_fraction
    )


def segment_batch(
    seed: int,
    patient_ids: jax.Array,
    seqs: jax.Array,
    *,
    va_fraction: float = 0.5,
) -> dict[str, jax.Array]:
    """Batched deterministic segments for (patient, seq) pairs.

    Every row is keyed fold_in(fold_in(PRNGKey(seed), patient), seq) —
    the same (seed, patient, seq) triple regenerates bit-identical
    telemetry regardless of batch composition, which is what makes the
    fleet scheduler tests reproducible. Returns {signal (B, 512) f32,
    label (B,) i32} with the label persistent per patient.
    """
    sqs = jnp.asarray(seqs, jnp.uint32)
    pkeys = _patient_keys(seed, patient_ids)
    labels = _labels_from_keys(pkeys, va_fraction)
    skeys = jax.vmap(jax.random.fold_in)(pkeys, sqs)
    sig = jax.vmap(_segment_one)(skeys, labels)
    sig = bandpass(sig)
    sig = sig / (jnp.std(sig, axis=1, keepdims=True) + 1e-6)
    return {"signal": sig.astype(jnp.float32), "label": labels}


# one compiled program shared by every stream_segments iterator (a
# fleet demo opens one iterator per implant; per-iterator jit closures
# would each pay their own identical compile). seed folds in as data.
@jax.jit
def _stream_one(seed, p, s, va_fraction):
    return segment_batch(seed, p[None], s[None], va_fraction=va_fraction)


def stream_segments(
    patient_id: int,
    *,
    seed: int = 0,
    start: int = 0,
    va_fraction: float = 0.5,
) -> Iterator[dict]:
    """Infinite per-patient segment iterator (the device's view of one
    implant's telemetry). Deterministic: two iterators for the same
    (seed, patient_id) yield identical segments; restarting at `start=k`
    regenerates segment k exactly."""
    seq = start
    while True:
        out = _stream_one(
            jnp.uint32(seed),
            jnp.uint32(patient_id),
            jnp.uint32(seq),
            jnp.float32(va_fraction),
        )
        yield {
            "signal": out["signal"][0],
            "label": int(out["label"][0]),
            "seq": seq,
        }
        seq += 1


@dataclasses.dataclass
class IEGMStream:
    """Deterministic, host-shardable stream of training batches.

    Sharding is by folding (host_id, step) into the key — every host
    draws a disjoint, reproducible slice; restart at step k regenerates
    the identical batch (the checkpoint/restart contract).
    """

    batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def batch_at(self, step: int) -> dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), self.host_id),
            step,
        )
        return synth_batch(key, self.batch)

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
