"""Synthetic LM token pipeline (deterministic, host-sharded).

For the datacenter-scale substrate we need a data pipeline with the same
*contract* as a production one: deterministic batch-at-step addressing
(exact restart after failure), disjoint per-host shards, and a schema the
trainer consumes ({tokens, targets} next-token pairs). Content is a
synthetic Markov-ish token stream — structured enough that a real model's
loss falls during the example runs, cheap enough for CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


def synth_tokens(
    key: jax.Array, batch: int, seq_len: int, vocab: int
) -> jax.Array:
    """Structured token stream: a random walk over a banded vocabulary
    with periodic resets — has learnable local statistics (bigram-ish)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    steps = jax.random.randint(k1, (batch, seq_len), -8, 9)
    start = jax.random.randint(k2, (batch, 1), 0, vocab)
    walk = (start + jnp.cumsum(steps, axis=1)) % vocab
    # sprinkle 5% uniform-random tokens (noise floor for the loss);
    # mask and values take distinct keys so they stay uncorrelated
    noise = jax.random.randint(k3, (batch, seq_len), 0, vocab)
    is_noise = jax.random.bernoulli(k4, 0.05, (batch, seq_len))
    return jnp.where(is_noise, noise, walk).astype(jnp.int32)


def batch_at(
    seed: int, step: int, *, batch: int, seq_len: int, vocab: int,
    host_id: int = 0,
) -> dict[str, jax.Array]:
    """{tokens (B, S), targets (B, S)} — targets are next-token shifted."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), host_id), step
    )
    toks = synth_tokens(key, batch, seq_len + 1, vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


@dataclasses.dataclass
class TokenStream:
    """Deterministic host-sharded stream. `batch` is the *per-host* size."""

    batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host_id: int = 0

    def batch_at(self, step: int) -> dict[str, jax.Array]:
        return batch_at(
            self.seed, step, batch=self.batch, seq_len=self.seq_len,
            vocab=self.vocab, host_id=self.host_id,
        )

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
