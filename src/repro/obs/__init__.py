"""repro.obs — unified telemetry: metrics, traces, compile visibility.

One `Telemetry` object per process bundles the three probes every
subsystem shares:

  * `registry` — counters / gauges / mergeable log-bucket histograms
    (`obs.registry`): O(buckets) tail latency (p50/p99/p99.9) at fleet
    scale;
  * `tracer`   — structured spans with a JSONL event log and a
    Chrome/Perfetto export (`obs.trace`), virtual-time aware;
  * `probe`    — per-compiled-cell jit recompile tracking, bounded step
    timing, device-memory gauges (`obs.jaxprobe`).

The hot paths (trainer step loop, stream fleet loop, serve engine
admission/tick) call `obs.get()` each time and emit unconditionally;
the **default telemetry is disabled** and every emission is a no-op
costing nanoseconds (asserted in `tests/test_obs.py`), so the
instrumentation has no off-switch to forget and no measurable tax when
off. Launchers enable it behind `--trace-out`, benchmarks always
enable it and attach `telemetry_section()` to their BENCH records.

Usage:

    from repro import obs

    tel = obs.configure(enabled=True)        # launchers / benchmarks
    with obs.get().span("train/step", step=i):
        ...
    obs.get().registry.histogram("train.step_latency_s").observe(dt)
    tel.finish("/tmp/run")   # -> /tmp/run.jsonl + /tmp/run.json
    obs.reset()              # back to the disabled default
"""

from __future__ import annotations

from repro.obs.jaxprobe import (
    NULL_PROBE,
    JitProbe,
    device_memory_bytes,
    jit_cache_size,
    observe_memory,
    timed_call,
)
from repro.obs.registry import (
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    Counter,
    Gauge,
    Histogram,
    Registry,
    latency_bounds,
    signed_bounds,
)
from repro.obs.lineage import (
    assert_joined,
    critical_path,
    join_lineage,
    serve_rid,
    stream_rid,
)
from repro.obs.trace import (
    NULL_SPAN,
    NULL_TRACER,
    Tracer,
    validate_chrome,
    validate_event,
    validate_jsonl,
)

SCHEMA_VERSION = 1


class Telemetry:
    """Registry + tracer + jit probe with one shared enabled flag."""

    __slots__ = ("enabled", "registry", "tracer", "probe")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.registry = Registry(enabled=enabled)
        self.tracer = Tracer(enabled=enabled) if enabled else NULL_TRACER
        self.probe = JitProbe(enabled=enabled) if enabled else NULL_PROBE

    # hot-path conveniences ---------------------------------------------

    def span(self, name: str, cat: str = "app", **attrs):
        return self.tracer.span(name, cat, **attrs)

    def block(self, x):
        """`jax.block_until_ready(x)` only when telemetry is enabled —
        span durations then bound device work, while the disabled path
        never serializes the async pipeline."""
        if self.enabled:
            import jax

            jax.block_until_ready(x)
        return x

    # lifecycle ----------------------------------------------------------

    def finish(self, out_prefix: str) -> tuple[str, str]:
        """Write the JSONL event log and the Chrome/Perfetto export:
        `<out_prefix>.jsonl` + `<out_prefix>.json`. Returns the two
        paths."""
        jsonl = out_prefix + ".jsonl"
        chrome = out_prefix + ".json"
        self.tracer.write_jsonl(jsonl)
        self.tracer.export_chrome(chrome)
        return jsonl, chrome


_DISABLED = Telemetry(enabled=False)
_current = _DISABLED


def get() -> Telemetry:
    """The process-wide telemetry (disabled no-op by default)."""
    return _current


def configure(enabled: bool = True) -> Telemetry:
    """Install (and return) a fresh process-wide Telemetry. Call
    *before* constructing engines/runners so their compiled cells
    register with the probe."""
    global _current
    _current = Telemetry(enabled=enabled)
    return _current


def install(tel: Telemetry) -> Telemetry:
    """Re-install a previously captured Telemetry (e.g. after an A/B
    overhead measurement swapped in throwaway instances)."""
    global _current
    _current = tel
    return _current


def reset() -> None:
    """Back to the shared disabled default (test teardown)."""
    global _current
    _current = _DISABLED


def telemetry_section(tel: Telemetry | None = None) -> dict:
    """The shared BENCH `telemetry` schema — identical across
    BENCH_dist / BENCH_stream / BENCH_decode:

      {
        "schema_version": 1,
        "enabled": bool,
        "counters":   {name: int},
        "gauges":     {name: {"value", "peak"}},
        "histograms": {name: {count,sum,min,max,mean,
                              p50,p90,p99,p999,layout,
                              n_buckets,nonzero_buckets}},
        "recompiles": {cell name: compiled-variant count},
        "peak_device_memory_bytes": int,
      }

    Benchmarks may add an "overhead" sub-record (the stream benchmark
    records its measured enabled-vs-disabled wall delta there)."""
    tel = tel or get()
    if tel.enabled:
        observe_memory(tel.registry)
    snap = tel.registry.snapshot()
    mem = snap["gauges"].get("jax.device_bytes", {})
    return {
        "schema_version": SCHEMA_VERSION,
        "enabled": tel.enabled,
        **snap,
        "recompiles": tel.probe.cache_sizes(),
        "peak_device_memory_bytes": int(mem.get("peak") or 0),
    }


__all__ = [
    "SCHEMA_VERSION",
    "Telemetry",
    "configure",
    "get",
    "install",
    "reset",
    "telemetry_section",
    # registry
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "latency_bounds",
    "signed_bounds",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
    # lineage
    "assert_joined",
    "critical_path",
    "join_lineage",
    "serve_rid",
    "stream_rid",
    # trace
    "Tracer",
    "NULL_SPAN",
    "NULL_TRACER",
    "validate_chrome",
    "validate_event",
    "validate_jsonl",
    # jaxprobe
    "JitProbe",
    "NULL_PROBE",
    "device_memory_bytes",
    "jit_cache_size",
    "observe_memory",
    "timed_call",
]
