"""Structured span tracer: JSONL event log + Chrome/Perfetto export.

One `Tracer` per process collects begin/end spans and instant events
under a lock. Events are plain dicts with a fixed schema
(`validate_event`), streamed to a JSONL file on `write_jsonl` and
exported as a Chrome trace-event JSON (`export_chrome`) that
chrome://tracing and https://ui.perfetto.dev load directly.

Span nesting: every span/instant gets a process-unique `span_id` and
the `parent_id` of the innermost span open *on its own thread* — the
open-span stack lives in thread-local storage, so spans opened from
worker threads parent to their own thread's enclosing span, never to
whatever the main thread happens to have open (a process-global stack
would cross-wire parent edges the moment two threads trace at once;
`repro.obs.lineage` joins per-request critical paths along these edges,
so they must be right). `parent_id == 0` marks a root span.

Virtual time: subsystems that model time (the stream fleet's
virtual-time loop) pass `v_ts_s`/`v_dur_s` span attributes; the Chrome
export then mirrors those spans onto a second process track named
"virtual time" with the modeled timestamps, so one trace shows the wall
timeline and the modeled fleet timeline side by side.

A disabled tracer returns one shared no-op context manager from
`span()` — the hot-path cost of an un-traced span is a dict miss and a
`with` statement, nanoseconds per call.

CLI (the CI trace smoke): validate a JSONL event log and a Chrome
export in one call —

    python -m repro.obs.trace TRACE.jsonl TRACE.json
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Optional

EVENT_TYPES = ("span", "instant", "counter")

ROOT_SPAN_ID = 0  # parent_id of a span with no enclosing span

# chrome trace-event pids: wall-clock events vs virtual-time mirrors
WALL_PID = 0
VIRTUAL_PID = 1


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        """No-op twin of `_Span.set` (late attrs on a disabled span)."""
        return self


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "cat", "attrs", "_t0",
                 "span_id", "parent_id")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attrs discovered mid-span (e.g. the request ids a
        pack decided on) — recorded at `__exit__` with the rest."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self.tracer._open_stack()
        self.span_id = self.tracer._next_id()
        self.parent_id = stack[-1] if stack else ROOT_SPAN_ID
        stack.append(self.span_id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        stack = self.tracer._open_stack()
        # tolerate a mis-nested exit rather than corrupting the stack
        if stack and stack[-1] == self.span_id:
            stack.pop()
        elif self.span_id in stack:
            del stack[stack.index(self.span_id):]
        self.tracer._record(
            type="span",
            name=self.name,
            cat=self.cat,
            ts_us=(self._t0 - self.tracer._t0) * 1e6,
            dur_us=(t1 - self._t0) * 1e6,
            span_id=self.span_id,
            parent_id=self.parent_id,
            attrs=self.attrs,
        )
        return False


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        # span ids are process-unique (itertools.count.__next__ is a
        # single C call — atomic under the GIL); the OPEN-span stack is
        # per-thread so parent edges never cross threads
        self._ids = itertools.count(1)
        self._tls = threading.local()

    def _next_id(self) -> int:
        return next(self._ids)

    def _open_stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    # -- emission -----------------------------------------------------------

    def span(self, name: str, cat: str = "app", **attrs):
        """Context manager timing one named region. Extra kwargs become
        the event's `attrs`; `v_ts_s`/`v_dur_s` (virtual-time seconds)
        additionally place the span on the virtual-time track of the
        Chrome export."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, attrs)

    def instant(self, name: str, cat: str = "app", **attrs) -> None:
        if not self.enabled:
            return
        stack = self._open_stack()
        self._record(
            type="instant",
            name=name,
            cat=cat,
            ts_us=(time.perf_counter() - self._t0) * 1e6,
            dur_us=0.0,
            span_id=self._next_id(),
            parent_id=stack[-1] if stack else ROOT_SPAN_ID,
            attrs=attrs,
        )

    def counter(self, name: str, value: float, cat: str = "app") -> None:
        """Chrome 'C'-phase counter sample (renders as an area chart)."""
        if not self.enabled:
            return
        self._record(
            type="counter",
            name=name,
            cat=cat,
            ts_us=(time.perf_counter() - self._t0) * 1e6,
            dur_us=0.0,
            attrs={"value": float(value)},
        )

    def _record(self, **event) -> None:
        event["tid"] = threading.get_ident() & 0xFFFF
        with self._lock:
            self._events.append(event)

    # -- introspection ------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    # -- export -------------------------------------------------------------

    def write_jsonl(self, path: str) -> int:
        """One event per line, schema per `validate_event`. Returns the
        event count."""
        evs = self.events()
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e) + "\n")
        return len(evs)

    def export_chrome(self, path: str) -> int:
        """Chrome trace-event format (the JSON-object flavor Perfetto
        and chrome://tracing both accept). Returns the traceEvent
        count."""
        out = [
            {"ph": "M", "pid": WALL_PID, "name": "process_name",
             "args": {"name": "wall clock"}},
            {"ph": "M", "pid": VIRTUAL_PID, "name": "process_name",
             "args": {"name": "virtual time (modeled)"}},
        ]
        for e in self.events():
            base = {
                "name": e["name"],
                "cat": e["cat"],
                "pid": WALL_PID,
                "tid": e["tid"],
                "ts": e["ts_us"],
                "args": e["attrs"],
            }
            if e["type"] == "span":
                out.append({**base, "ph": "X", "dur": e["dur_us"]})
                v_ts = e["attrs"].get("v_ts_s")
                if v_ts is not None:
                    out.append({
                        **base,
                        "ph": "X",
                        "pid": VIRTUAL_PID,
                        "ts": float(v_ts) * 1e6,
                        "dur": float(
                            e["attrs"].get("v_dur_s") or 0.0
                        ) * 1e6,
                    })
            elif e["type"] == "instant":
                out.append({**base, "ph": "i", "s": "t"})
            elif e["type"] == "counter":
                out.append({
                    **base, "ph": "C",
                    "args": {"value": e["attrs"].get("value", 0.0)},
                })
        with open(path, "w") as f:
            json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, f)
        return len(out)


class _NullTracer:
    __slots__ = ()
    enabled = False

    def span(self, name, cat="app", **attrs):
        return NULL_SPAN

    def instant(self, name, cat="app", **attrs):
        pass

    def counter(self, name, value, cat="app"):
        pass

    def events(self):
        return []

    def write_jsonl(self, path):
        with open(path, "w"):
            pass
        return 0

    def export_chrome(self, path):
        with open(path, "w") as f:
            json.dump({"traceEvents": []}, f)
        return 0


NULL_TRACER = _NullTracer()


# ---------------------------------------------------------------------------
# schema validation (the CI trace smoke)
# ---------------------------------------------------------------------------


def validate_event(e: dict) -> None:
    """Raise ValueError if `e` is not a well-formed trace event."""
    if not isinstance(e, dict):
        raise ValueError(f"event is not an object: {e!r}")
    for key, typ in (
        ("type", str), ("name", str), ("cat", str),
        ("ts_us", (int, float)), ("dur_us", (int, float)),
        ("tid", int), ("attrs", dict),
    ):
        if key not in e:
            raise ValueError(f"event missing {key!r}: {e!r}")
        if not isinstance(e[key], typ):
            raise ValueError(
                f"event field {key!r} has type "
                f"{type(e[key]).__name__}, wanted {typ}: {e!r}"
            )
    if e["type"] not in EVENT_TYPES:
        raise ValueError(f"unknown event type {e['type']!r}")
    if e["ts_us"] < 0 or e["dur_us"] < 0:
        raise ValueError(f"negative timestamp/duration: {e!r}")
    # span_id/parent_id: optional (absent in pre-lineage traces) but
    # typed when present; a span must never parent itself
    for key in ("span_id", "parent_id"):
        if key in e and not isinstance(e[key], int):
            raise ValueError(
                f"event field {key!r} has type "
                f"{type(e[key]).__name__}, wanted int: {e!r}"
            )
    if "span_id" in e and e.get("parent_id") == e["span_id"]:
        raise ValueError(f"self-parenting span: {e!r}")


def validate_jsonl(path: str) -> int:
    """Validate every line of a JSONL event log; returns event count."""
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError as err:
                raise ValueError(
                    f"{path}:{lineno}: not JSON: {err}"
                ) from err
            try:
                validate_event(e)
            except ValueError as err:
                raise ValueError(f"{path}:{lineno}: {err}") from err
            n += 1
    return n


def validate_chrome(path: str) -> int:
    """Validate a Chrome trace export is well-formed; returns the
    traceEvent count."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: missing traceEvents")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    for i, e in enumerate(evs):
        if not isinstance(e, dict) or "ph" not in e or "name" not in e:
            raise ValueError(f"{path}: traceEvents[{i}] malformed: {e!r}")
        if e["ph"] in ("X", "i", "C") and "ts" not in e:
            raise ValueError(f"{path}: traceEvents[{i}] missing ts")
    return len(evs)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a telemetry JSONL event log and/or a "
                    "Chrome trace export (CI trace smoke)"
    )
    ap.add_argument("jsonl", help="JSONL event log path")
    ap.add_argument("chrome", nargs="?", default=None,
                    help="Chrome trace.json path")
    args = ap.parse_args()
    n = validate_jsonl(args.jsonl)
    print(f"[obs.trace] {args.jsonl}: {n} events valid")
    if n == 0:
        raise SystemExit(f"{args.jsonl}: no events — tracing was off?")
    if args.chrome:
        m = validate_chrome(args.chrome)
        print(f"[obs.trace] {args.chrome}: {m} traceEvents well-formed")
        if m == 0:
            raise SystemExit(f"{args.chrome}: empty trace")


if __name__ == "__main__":
    main()
