"""Standalone HTML report for a load-lab BENCH record.

`render_report(record, out_path)` turns a `BENCH_load.json` dict (the
output of `benchmarks/load_sweep.py` — serve + stream sweeps, knee,
SLO burn, lineage samples) into one self-contained HTML file: inline
SVG, no external assets, no JS dependencies, light/dark via CSS custom
properties. Open it in any browser; nothing to install.

Charts rendered per engine:

  * tail-latency-vs-offered-load curves (p50 / p99 / p99.9) with the
    located saturation knee marked and the SLO bound drawn as a
    critical-status reference line;
  * an SLO burn table (ok fraction, error-budget burn rate, verdict
    per offered-load point);
  * a per-request critical-path waterfall from the lineage join
    (queue wait vs per-phase compute).

Every chart is paired with its data table — the numbers are never
color-alone, and the tables are the screen-reader/print fallback.
"""

from __future__ import annotations

import html
import json
import math
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# palette — categorical slots in fixed order, status-critical for SLO
# bounds, muted ink for queue-wait. Values validated for CVD separation
# against both surfaces; dark mode is its own selected steps, not a flip.
# ---------------------------------------------------------------------------

_CSS = """
:root {
  --surface: #fcfcfb;
  --ink: #1a1a19;
  --ink-2: #5f5c58;
  --ink-3: #8a8783;
  --grid: #e8e6e3;
  --edge: #d9d6d2;
  --s1: #2a78d6;  /* p50  */
  --s2: #eb6834;  /* p99  */
  --s3: #1baf7a;  /* p99.9 */
  --crit: #d03b3b;
  --wait: #b9b5af; /* queue wait in waterfalls — muted, not a series hue */
  --card: #ffffff;
}
@media (prefers-color-scheme: dark) {
  :root {
    --surface: #1a1a19;
    --ink: #f0efed;
    --ink-2: #a8a5a0;
    --ink-3: #7a7772;
    --grid: #2e2d2b;
    --edge: #3a3936;
    --s1: #3987e5;
    --s2: #d95926;
    --s3: #199e70;
    --crit: #e25555;
    --wait: #55524e;
    --card: #222120;
  }
}
* { box-sizing: border-box; }
body {
  margin: 0; padding: 24px; background: var(--surface); color: var(--ink);
  font: 14px/1.5 ui-sans-serif, system-ui, -apple-system, "Segoe UI", sans-serif;
}
main { max-width: 960px; margin: 0 auto; }
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 16px; margin: 32px 0 8px; }
h3 { font-size: 14px; margin: 20px 0 6px; color: var(--ink-2); }
p.sub { color: var(--ink-2); margin: 0 0 16px; }
.verdict { display: inline-block; padding: 2px 10px; border-radius: 999px;
  font-size: 12px; font-weight: 600; border: 1px solid var(--edge); }
.verdict.ok { color: var(--s3); }
.verdict.bad { color: var(--crit); }
figure { margin: 12px 0; padding: 12px; background: var(--card);
  border: 1px solid var(--edge); border-radius: 8px; }
figcaption { font-size: 12px; color: var(--ink-2); margin-top: 6px; }
svg text { fill: var(--ink-2); font: 11px ui-sans-serif, system-ui, sans-serif; }
svg .title { fill: var(--ink); font-size: 12px; font-weight: 600; }
svg .lbl { fill: var(--ink); }
table { border-collapse: collapse; width: 100%; font-size: 12px;
  font-variant-numeric: tabular-nums; }
th, td { text-align: right; padding: 4px 8px; border-bottom: 1px solid var(--grid); }
th { color: var(--ink-2); font-weight: 600; }
th:first-child, td:first-child { text-align: left; }
td.ok { color: var(--s3); font-weight: 600; }
td.bad { color: var(--crit); font-weight: 600; }
.legend { display: flex; gap: 16px; font-size: 12px; color: var(--ink);
  margin: 2px 0 6px; flex-wrap: wrap; }
.legend span::before { content: ""; display: inline-block; width: 10px;
  height: 10px; border-radius: 3px; margin-right: 5px; vertical-align: -1px;
  background: var(--sw); }
circle.pt:hover { stroke-width: 3; }
rect.seg:hover { opacity: 0.8; }
footer { margin-top: 40px; font-size: 12px; color: var(--ink-3); }
"""

_SERIES = (  # (record key, label, css var) — fixed categorical order
    ("p50_s", "p50", "--s1"),
    ("p99_s", "p99", "--s2"),
    ("p999_s", "p99.9", "--s3"),
)

_PHASE_VARS = {  # waterfall phases follow the same fixed slot order
    "queue_wait": "--wait",
    "prefill": "--s1",
    "seat": "--s2",
    "decode": "--s3",
    "classify": "--s1",
    "vote": "--s2",
}


# ---------------------------------------------------------------------------
# formatting + scales
# ---------------------------------------------------------------------------


def _fmt_s(x: float) -> str:
    """Latency with a human unit (µs / ms / s)."""
    if x != x:  # nan
        return "–"
    ax = abs(x)
    if ax < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if ax < 1.0:
        return f"{x * 1e3:.2g}ms" if ax < 0.01 else f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_rate(x: float) -> str:
    if abs(x) >= 1e6:
        return f"{x / 1e6:.3g}M/s"
    if abs(x) >= 1e3:
        return f"{x / 1e3:.3g}k/s"
    return f"{x:.3g}/s"


def _ticks(lo: float, hi: float, n: int = 5) -> list[float]:
    if not (hi > lo):
        hi = lo + 1.0
    span = hi - lo
    step = 10.0 ** math.floor(math.log10(span / max(n, 1)))
    for m in (1.0, 2.0, 2.5, 5.0, 10.0):
        if span / (step * m) <= n:
            step *= m
            break
    t = math.ceil(lo / step) * step
    out = []
    while t <= hi + 1e-9 * span:
        out.append(round(t, 12))
        t += step
    return out


class _Lin:
    def __init__(self, lo, hi, a, b):
        self.lo, self.hi, self.a, self.b = lo, hi, a, b
        self.span = (hi - lo) or 1.0

    def __call__(self, x: float) -> float:
        return self.a + (x - self.lo) / self.span * (self.b - self.a)


# ---------------------------------------------------------------------------
# charts
# ---------------------------------------------------------------------------


def tail_curve_svg(
    points: Sequence[dict],
    *,
    rate_key: str = "offered_load",
    knee: Optional[dict] = None,
    slo_bound: Optional[float] = None,
    title: str = "tail latency vs offered load",
) -> str:
    """Percentile-vs-load line chart: one y-axis, three fixed-slot
    series, the knee as a dashed marker, the SLO bound in status
    critical. Every point carries a hover <title> tooltip."""
    pts = sorted(points, key=lambda p: p[rate_key])
    if not pts:
        return "<p>no points</p>"
    W, H, L, R, T, B = 640, 300, 64, 16, 30, 44
    xs = [p[rate_key] for p in pts]
    ys = [p[k] for p in pts for k, _, _ in _SERIES if p.get(k) is not None]
    if slo_bound is not None:
        ys.append(slo_bound)
    y_hi = max(ys) * 1.08
    sx = _Lin(min(xs), max(xs), L, W - R)
    sy = _Lin(0.0, y_hi, H - B, T)
    out = [
        f'<svg viewBox="0 0 {W} {H}" role="img" '
        f'aria-label="{html.escape(title)}">',
        f'<text class="title" x="{L}" y="16">{html.escape(title)}</text>',
    ]
    for t in _ticks(0.0, y_hi):
        y = sy(t)
        out.append(
            f'<line x1="{L}" x2="{W - R}" y1="{y:.1f}" y2="{y:.1f}" '
            f'stroke="var(--grid)" stroke-width="1"/>'
            f'<text x="{L - 6}" y="{y + 3.5:.1f}" '
            f'text-anchor="end">{_fmt_s(t)}</text>'
        )
    for t in _ticks(min(xs), max(xs), 6):
        if not (min(xs) <= t <= max(xs)):
            continue
        x = sx(t)
        out.append(
            f'<text x="{x:.1f}" y="{H - B + 16}" '
            f'text-anchor="middle">{_fmt_rate(t)}</text>'
        )
    out.append(
        f'<text x="{(L + W - R) / 2:.0f}" y="{H - 8}" '
        f'text-anchor="middle">offered load</text>'
    )
    if slo_bound is not None:
        y = sy(slo_bound)
        out.append(
            f'<line x1="{L}" x2="{W - R}" y1="{y:.1f}" y2="{y:.1f}" '
            f'stroke="var(--crit)" stroke-width="1.5" '
            f'stroke-dasharray="2 4"/>'
            f'<text x="{W - R}" y="{y - 5:.1f}" text-anchor="end" '
            f'fill="var(--crit)" style="fill:var(--crit)">SLO bound '
            f'{_fmt_s(slo_bound)}</text>'
        )
    if knee and knee.get("detected"):
        x = sx(knee["knee_rate"])
        out.append(
            f'<line x1="{x:.1f}" x2="{x:.1f}" y1="{T}" y2="{H - B}" '
            f'stroke="var(--ink-3)" stroke-width="1.5" '
            f'stroke-dasharray="5 4"/>'
            f'<text class="lbl" x="{x + 5:.1f}" y="{T + 12}">knee '
            f'{_fmt_rate(knee["knee_rate"])}</text>'
        )
    for key, label, var in _SERIES:
        coords = [
            (sx(p[rate_key]), sy(p[key]))
            for p in pts
            if p.get(key) is not None
        ]
        if not coords:
            continue
        d = " ".join(f"{x:.1f},{y:.1f}" for x, y in coords)
        out.append(
            f'<polyline points="{d}" fill="none" '
            f'stroke="var({var})" stroke-width="2" '
            f'stroke-linejoin="round"/>'
        )
        for p in pts:
            if p.get(key) is None:
                continue
            x, y = sx(p[rate_key]), sy(p[key])
            tip = (
                f"{label} = {_fmt_s(p[key])} @ "
                f"{_fmt_rate(p[rate_key])} "
                f"({p.get('load_fraction', '?')}× capacity)"
            )
            out.append(
                f'<circle class="pt" cx="{x:.1f}" cy="{y:.1f}" r="4" '
                f'fill="var({var})" stroke="var(--card)" '
                f'stroke-width="2"><title>{html.escape(tip)}</title>'
                f"</circle>"
            )
        # direct label at the last point
        x, y = coords[-1]
        out.append(
            f'<text class="lbl" x="{min(x + 7, W - 4):.1f}" '
            f'y="{y + 3.5:.1f}" style="fill:var({var})">{label}</text>'
        )
    out.append("</svg>")
    legend = "".join(
        f'<span style="--sw:var({var})">{label}</span>'
        for _, label, var in _SERIES
    )
    return f'<div class="legend">{legend}</div>' + "".join(out)


def waterfall_svg(samples: Sequence[dict], *, title: str) -> str:
    """Per-request critical-path waterfall: queue wait then per-phase
    compute as 2px-gapped horizontal segments, one row per request."""
    rows = [s for s in samples if s.get("total_s")]
    if not rows:
        return "<p>no lineage samples</p>"
    rows = rows[:12]
    ROW, GAP = 18, 6
    W, L, R, T = 640, 170, 16, 30
    H = T + len(rows) * (ROW + GAP) + 34
    total_hi = max(s["total_s"] for s in rows) or 1.0
    sx = _Lin(0.0, total_hi, L, W - R)
    out = [
        f'<svg viewBox="0 0 {W} {H}" role="img" '
        f'aria-label="{html.escape(title)}">',
        f'<text class="title" x="{L}" y="16">{html.escape(title)}</text>',
    ]
    for t in _ticks(0.0, total_hi, 5):
        x = sx(t)
        out.append(
            f'<line x1="{x:.1f}" x2="{x:.1f}" y1="{T}" '
            f'y2="{H - 30}" stroke="var(--grid)"/>'
            f'<text x="{x:.1f}" y="{H - 16}" '
            f'text-anchor="middle">{_fmt_s(t)}</text>'
        )
    seen_phases: list[str] = []
    for i, s in enumerate(rows):
        y = T + i * (ROW + GAP)
        rid = str(s.get("request_id", f"req {i}"))
        out.append(
            f'<text x="{L - 6}" y="{y + ROW - 5}" '
            f'text-anchor="end">{html.escape(rid)}</text>'
        )
        cursor = 0.0
        segs = [("queue_wait", s.get("queue_wait_s", 0.0))]
        segs += list((s.get("phases_s") or {}).items())
        for name, dur in segs:
            if not dur or dur <= 0:
                continue
            if name not in seen_phases:
                seen_phases.append(name)
            x0, x1 = sx(cursor), sx(cursor + dur)
            w = max(x1 - x0 - 2, 1.0)  # 2px surface gap between fills
            var = _PHASE_VARS.get(name, "--ink-3")
            tip = f"{rid}: {name} {_fmt_s(dur)}"
            out.append(
                f'<rect class="seg" x="{x0:.1f}" y="{y}" '
                f'width="{w:.1f}" height="{ROW - 4}" rx="3" '
                f'fill="var({var})"><title>{html.escape(tip)}</title>'
                f"</rect>"
            )
            cursor += dur
    out.append("</svg>")
    legend = "".join(
        f'<span style="--sw:var({_PHASE_VARS.get(n, "--ink-3")})">'
        f"{html.escape(n)}</span>"
        for n in seen_phases
    )
    return f'<div class="legend">{legend}</div>' + "".join(out)


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------


def _points_table(points: Sequence[dict], rate_key: str) -> str:
    head = (
        "<tr><th>load ×cap</th><th>offered</th><th>achieved</th>"
        "<th>n</th><th>p50</th><th>p99</th><th>p99.9</th>"
        "<th>max</th></tr>"
    )
    body = []
    for p in sorted(points, key=lambda p: p[rate_key]):
        ach = p.get("achieved_rps") or p.get("achieved_rate")
        lat = p.get("latency") or {}
        n = p.get("count") or p.get("n_segments") or p.get("n_requests")
        mx = p.get("max_s", lat.get("max_s", float("nan")))
        body.append(
            "<tr>"
            f"<td>{p.get('load_fraction', '–')}</td>"
            f"<td>{_fmt_rate(p[rate_key])}</td>"
            f"<td>{_fmt_rate(ach) if ach else '–'}</td>"
            f"<td>{n if n is not None else '–'}</td>"
            f"<td>{_fmt_s(p.get('p50_s', float('nan')))}</td>"
            f"<td>{_fmt_s(p.get('p99_s', float('nan')))}</td>"
            f"<td>{_fmt_s(p.get('p999_s', float('nan')))}</td>"
            f"<td>{_fmt_s(mx if mx is not None else float('nan'))}</td>"
            "</tr>"
        )
    return f"<table>{head}{''.join(body)}</table>"


def _slo_table(slo: dict) -> str:
    decl = slo.get("declared", {})
    head = (
        "<tr><th>offered</th><th>total</th><th>ok</th>"
        "<th>ok fraction</th><th>burn rate</th><th>met</th></tr>"
    )
    body = []
    for p in slo.get("points", ()):
        cls = "ok" if p.get("met") else "bad"
        mark = "✓" if p.get("met") else "✗"
        body.append(
            "<tr>"
            f"<td>{_fmt_rate(p['offered_load'])}</td>"
            f"<td>{p['total']}</td><td>{p['ok']}</td>"
            f"<td>{p['ok_fraction']:.4f}</td>"
            f"<td>{p['burn_rate']:.2f}</td>"
            f'<td class="{cls}">{mark}</td>'
            "</tr>"
        )
    name = html.escape(str(decl.get("name", "slo")))
    bound = decl.get("bound")
    target = decl.get("target")
    cap = (
        f"{name}: metric {html.escape(str(decl.get('metric', '?')))}, "
        f"bound {_fmt_s(bound) if bound is not None else '?'}, "
        f"target {target}"
    )
    return (
        f"<table>{head}{''.join(body)}</table>"
        f"<figcaption>{cap}. Burn rate = (1 − ok fraction) / error "
        f"budget; ≤ 1 sustains the target.</figcaption>"
    )


def _verdict_badge(overload: dict) -> str:
    v = str(overload.get("verdict", "unknown"))
    cls = "ok" if v == "graceful_degradation" else "bad"
    return f'<span class="verdict {cls}">{html.escape(v)}</span>'


# ---------------------------------------------------------------------------
# report assembly
# ---------------------------------------------------------------------------


def _engine_section(name: str, sweep: dict, lineage: Optional[dict]) -> str:
    if not sweep:
        return ""
    rate_key = "offered_load"
    knee = sweep.get("knee") or {}
    slo = sweep.get("slo") or {}
    bound = (slo.get("declared") or {}).get("bound")
    # the serve bound is a TTFT latency (plottable); the stream bound is
    # slack >= 0, which has no home on a latency axis
    plot_bound = bound if name == "serve" and bound else None
    co = sweep.get("coordinated_omission_guard") or {}
    overload = sweep.get("overload") or {}
    parts = [
        f"<h2>{name} — open loop "
        f"({html.escape(str(sweep.get('timebase', '?')))} time) "
        f"{_verdict_badge(overload)}</h2>",
        "<figure>",
        tail_curve_svg(
            sweep.get("points", ()),
            rate_key=rate_key,
            knee=knee,
            slo_bound=plot_bound,
            title=f"{name}: tail latency vs offered load",
        ),
    ]
    if knee.get("detected"):
        parts.append(
            f"<figcaption>Saturation knee at "
            f"{_fmt_rate(knee['knee_rate'])} "
            f"(p99 grows {knee['post_knee_growth']:.1f}× past it; "
            f"baseline p99 {_fmt_s(knee['baseline_s'])}).</figcaption>"
        )
    parts += ["</figure>", "<h3>Points</h3>",
              _points_table(sweep.get("points", ()), rate_key)]
    if slo:
        parts += ["<h3>SLO burn</h3>", _slo_table(slo)]
    if co:
        ok = co.get("intended_ge_dequeue")
        parts.append(
            f"<h3>Coordinated-omission guard</h3>"
            f"<p class='sub'>latency measured from <b>intended</b> "
            f"arrival; intended ≥ dequeue held: "
            f"<b>{'yes' if ok else 'NO'}</b>; mean queue excess "
            f"{_fmt_s(co.get('mean_queue_excess_s', float('nan')))}"
            f" over {co.get('samples', '?')} samples at the highest "
            f"load.</p>"
        )
    if lineage and lineage.get("samples"):
        parts += [
            "<h3>Request lineage (critical paths)</h3>",
            "<figure>",
            waterfall_svg(
                lineage["samples"],
                title=f"{name}: queue wait vs compute per request",
            ),
            f"<figcaption>{lineage.get('requests', '?')} requests "
            f"joined; {lineage.get('min_distinct_hops', '?')}–"
            f"{lineage.get('max_distinct_hops', '?')} distinct hops "
            f"each.</figcaption>",
            "</figure>",
        ]
    return "\n".join(parts)


def render_report(record: dict, out_path: str) -> str:
    """Write the self-contained HTML report; returns `out_path`."""
    sections = []
    for name in ("serve", "stream"):
        sweep = record.get(name) or {}
        lin = (record.get("lineage") or {}).get(name)
        sections.append(_engine_section(name, sweep, lin))
    created = record.get("created_unix")
    meta = []
    if record.get("smoke"):
        meta.append("smoke run")
    if created:
        meta.append(f"created_unix {created}")
    tel = record.get("telemetry") or {}
    if tel:
        meta.append(f"telemetry schema v{tel.get('schema_version', '?')}")
    doc = f"""<!doctype html>
<html lang="en">
<meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>Load lab report</title>
<style>{_CSS}</style>
<body>
<main>
<h1>Load lab — open-loop tail latency, saturation knees, SLO burn</h1>
<p class="sub">Latencies are measured from each request's
<em>intended</em> arrival time (open loop), so queue delay under
overload is charged to the system — coordinated omission is
structurally impossible. {html.escape("; ".join(meta))}</p>
{"".join(sections)}
<footer>Generated by <code>python -m repro.obs.loadlab</code> from a
BENCH_load record. Single file, no external assets; dark mode follows
the OS preference.</footer>
</main>
</body>
</html>
"""
    with open(out_path, "w") as f:
        f.write(doc)
    return out_path


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Render a load-lab HTML report from BENCH_load.json"
    )
    ap.add_argument("bench", help="path to BENCH_load.json")
    ap.add_argument("-o", "--out", default="load_report.html")
    args = ap.parse_args(argv)
    with open(args.bench) as f:
        record = json.load(f)
    out = render_report(record, args.out)
    print(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
