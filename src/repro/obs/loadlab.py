"""Open-loop load lab: offered-load sweeps, saturation knees, SLO burn.

The closed-loop numbers the benchmarks report ("1000 patients
sustained") measure a system that is never asked to do more than it
can: a closed-loop driver waits for the previous response before
issuing the next request, so when the server slows down the *offered
load drops with it* and the tail you measure is the tail of a polite
workload. Real arrival processes are open-loop — implants close a
segment every 2.048 s whether or not the fleet is keeping up — and the
classic measurement bug under open loop is **coordinated omission**:
timing each request from when the load generator got around to
*sending* it (dequeue) instead of when it was *supposed to arrive*,
which silently excises exactly the queueing delay you were trying to
measure.

This module makes that bug structurally impossible:

  * arrival schedules are generated up front (`arrival_times`) on
    `fold_in`-derived keys — Poisson or trace-driven interarrivals,
    bitwise deterministic in (key, uid, rate, n) — so every request has
    an *intended* arrival time that exists before the system under
    test runs;
  * every latency is `completion − intended_arrival`. The sweep
    records the dequeue-based number too, but only to power the guard:
    intended-based latency ≥ dequeue-based latency always, strictly
    greater once a backlog forms (`co_guard`), and BENCH_load.json
    self-asserts that inequality.

Sweeps drive both engines across an offered-load grid (virtual time
for the stream fleet, wall time for the serve engine), locate the
saturation knee (`locate_knee`), and evaluate declared SLOs
(`SLO.evaluate`) with error-budget burn accounting: burn rate
`(1 − ok_fraction) / (1 − target)` — 1.0 spends the error budget
exactly as fast as the SLO allows, >1 burns it faster.

CLI — render the standalone HTML report from a BENCH_load.json:

    python -m repro.obs.loadlab BENCH_load.json -o load_report.html
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

ARRIVAL_PROCESSES = ("poisson", "trace")

# default trace-driven interarrival template: a bursty diurnal-ish
# pattern (mean 1 by construction after normalization) — callers pass
# their own recorded gaps for real trace replay
DEFAULT_TRACE_TEMPLATE = (
    0.2, 0.15, 0.3, 2.5, 0.2, 0.25, 1.8, 0.2, 0.3, 0.2, 3.0, 0.9,
)


def interarrival_gaps(
    key,
    uid: int,
    *,
    rate_hz: float,
    n: int,
    process: str = "poisson",
    template: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """(n,) interarrival gaps in seconds with mean 1/rate_hz, bitwise
    deterministic in (key, uid, rate_hz, n, process, template).

    `poisson` draws exponential gaps on `fold_in(key, uid)` — the same
    keying discipline as `data.iegm` signal content, so arrival
    processes and signal content never share randomness. `trace`
    replays `template` (normalized to mean 1, scaled to the rate) from
    a fold_in-derived cyclic offset, so different uids replay the same
    empirical shape out of phase.
    """
    import jax

    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be positive, got {rate_hz}")
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    k = jax.random.fold_in(key, uid)
    if process == "poisson":
        gaps = jax.random.exponential(k, (n,), dtype=np.float32)
        return np.asarray(gaps, np.float64) / rate_hz
    if process == "trace":
        tpl = np.asarray(
            template if template is not None else DEFAULT_TRACE_TEMPLATE,
            np.float64,
        )
        if tpl.size == 0 or (tpl <= 0).any():
            raise ValueError("trace template must be positive gaps")
        tpl = tpl / tpl.mean()  # mean-1 shape; rate sets the scale
        offset = int(
            np.asarray(jax.random.randint(k, (), 0, tpl.size))
        )
        idx = (offset + np.arange(n)) % tpl.size
        return tpl[idx] / rate_hz
    raise ValueError(
        f"unknown arrival process {process!r} "
        f"(want one of {ARRIVAL_PROCESSES})"
    )


def arrival_times(
    key,
    uid: int,
    *,
    rate_hz: float,
    n: int,
    process: str = "poisson",
    template: Optional[Sequence[float]] = None,
    start_s: float = 0.0,
) -> np.ndarray:
    """(n,) intended absolute arrival times (cumsum of the gaps)."""
    return start_s + np.cumsum(
        interarrival_gaps(
            key, uid, rate_hz=rate_hz, n=n,
            process=process, template=template,
        )
    )


# ---------------------------------------------------------------------------
# percentiles / knee
# ---------------------------------------------------------------------------


def tail_summary(samples: Sequence[float]) -> dict:
    """Exact p50/p99/p99.9 over raw samples (the sweep keeps raw
    latencies per point — point counts are bounded by the grid, so no
    histogram bucketing error enters the knee/SLO math)."""
    xs = np.asarray(list(samples), np.float64)
    if xs.size == 0:
        return {"count": 0, "p50_s": None, "p99_s": None,
                "p999_s": None, "max_s": None, "mean_s": None}
    return {
        "count": int(xs.size),
        "p50_s": float(np.quantile(xs, 0.50)),
        "p99_s": float(np.quantile(xs, 0.99)),
        "p999_s": float(np.quantile(xs, 0.999)),
        "max_s": float(xs.max()),
        "mean_s": float(xs.mean()),
    }


def locate_knee(
    points: list[dict],
    *,
    metric: str = "p99_s",
    rate_key: str = "offered_load",
    growth_factor: float = 3.0,
) -> dict:
    """Find the saturation knee on a sweep: the last offered-load point
    whose `metric` is still within `growth_factor` of the lowest-rate
    baseline. Everything past it is post-knee (queueing delay
    dominates and the tail grows with the backlog, not the service
    time).

    Returns {detected, knee_rate, baseline, post_knee_growth, ...};
    `detected` requires both sides of the knee to exist in the grid —
    at least one bounded sub-saturated point and at least one
    post-knee point with real growth.
    """
    pts = sorted(points, key=lambda p: p[rate_key])
    if len(pts) < 2:
        return {"detected": False, "reason": "fewer than 2 points"}
    # baseline: the *fastest* point (certainly sub-saturated) — robust
    # to a host hiccup landing on the lowest-rate point's p99
    baseline = min(
        (p[metric] for p in pts if p[metric] is not None),
        default=None,
    )
    if baseline is None or baseline <= 0:
        return {"detected": False, "reason": "no baseline"}
    bound = growth_factor * baseline
    below = [p for p in pts if p[metric] is not None and p[metric] <= bound]
    knee = below[-1] if below else pts[0]
    # post-knee points must lie *beyond* the knee rate — an outlier at
    # a low rate (host hiccup) is noise, not saturation
    above = [
        p for p in pts
        if p[metric] is not None
        and p[metric] > bound
        and p[rate_key] > knee[rate_key]
    ]
    detected = bool(below) and bool(above)
    worst = max(
        (p[metric] for p in above), default=baseline
    )
    return {
        "detected": detected,
        "metric": metric,
        "growth_factor": growth_factor,
        "baseline_s": float(baseline),
        "bound_s": float(bound),
        "knee_rate": float(knee[rate_key]),
        "first_post_knee_rate": (
            float(above[0][rate_key]) if above else None
        ),
        "post_knee_growth": float(worst / baseline),
        "n_sub_saturated": len(below),
        "n_post_knee": len(above),
    }


# ---------------------------------------------------------------------------
# SLOs + error-budget burn
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLO:
    """A declared objective: `target` fraction of requests must meet
    `bound` on `metric` (metric semantics live with the caller; this
    class only does the budget arithmetic)."""

    name: str
    metric: str  # e.g. "ttft_from_intended_s", "deadline_slack_s"
    bound: float  # good <=> sample meets the bound (caller-defined side)
    target: float  # e.g. 0.99 -> "p99 within bound"

    def evaluate(self, ok: int, total: int) -> dict:
        """Budget accounting for `ok` conforming samples out of
        `total`. burn_rate 1.0 consumes the error budget exactly at the
        allowed rate; >1 is over-budget (the SLO would page)."""
        if total <= 0:
            return {"slo": self.name, "total": 0, "met": None}
        ok_fraction = ok / total
        budget = 1.0 - self.target
        bad_fraction = 1.0 - ok_fraction
        burn = bad_fraction / budget if budget > 0 else math.inf
        return {
            "slo": self.name,
            "metric": self.metric,
            "bound": self.bound,
            "target": self.target,
            "total": int(total),
            "ok": int(ok),
            "ok_fraction": float(ok_fraction),
            "error_budget": float(budget),
            "burn_rate": float(burn),
            "met": bool(burn <= 1.0),
        }


def co_guard(
    from_intended: Sequence[float],
    from_dequeue: Sequence[float],
    *,
    saturated: bool,
) -> dict:
    """The coordinated-omission guard record. Latency measured from
    intended arrival can never be below the same request's latency
    measured from dequeue/submit (dequeue happens at or after the
    intended instant); once a backlog forms (`saturated`), it must be
    strictly greater on average — if the two agree under overload, the
    generator was closed-loop after all and the sweep is invalid."""
    a = np.asarray(list(from_intended), np.float64)
    b = np.asarray(list(from_dequeue), np.float64)
    if a.size == 0 or a.size != b.size:
        raise ValueError(
            f"guard needs paired samples, got {a.size} vs {b.size}"
        )
    # per-sample tolerance: the two clocks read the same completion,
    # so the inequality is exact up to timer quantization
    holds = bool(np.all(a >= b - 1e-9))
    excess = float((a - b).mean())
    record = {
        "measured_from": "intended_arrival",
        "samples": int(a.size),
        "intended_ge_dequeue": holds,
        "mean_queue_excess_s": excess,
        "saturated": bool(saturated),
        "strictly_greater_at_overload": bool(excess > 0)
        if saturated
        else None,
    }
    if not holds:
        raise AssertionError(
            "coordinated-omission guard violated: some latency "
            "measured from intended arrival is below the dequeue-based "
            "one — arrival schedule was not open-loop"
        )
    if saturated and excess <= 0:
        raise AssertionError(
            "coordinated-omission guard: no queueing excess at "
            "overload — the generator is coordinating with the server"
        )
    return record


# ---------------------------------------------------------------------------
# serve sweep (wall time)
# ---------------------------------------------------------------------------


def warm_engine(eng, prompt_len: int, *, vocab: int = 8) -> None:
    """Compile every cell an open-loop point can hit before its clock
    starts: admission groups of width 1..pool (each width retraces the
    shared prefill/seat jit) plus the pool decode step. Without this,
    the first mid-run retrace (~seconds) lands inside one request's
    latency and fabricates a tail at whatever rate it happened to hit.
    """
    import jax.numpy as jnp

    from repro.serve.engine import Request

    uid = 1_000_000  # out of the sweep's uid range
    for k in range(1, eng.batch + 1):
        for j in range(k):
            eng.submit(Request(
                uid=uid,
                prompt=jnp.full((prompt_len,), (uid + j) % vocab,
                                jnp.int32),
                # 2 tokens: the first comes from prefill at admission,
                # so the request must survive into a slot to compile
                # the width-k seat cell and the pool decode
                max_new=2,
            ))
            uid += 1
        eng.run(max_ticks=16)


def run_serve_point(
    make_engine,
    prompts,
    *,
    rate_rps: float,
    max_new: int,
    key,
    process: str = "poisson",
    template=None,
    max_wall_s: float = 120.0,
    warm: bool = True,
) -> dict:
    """Drive one fresh engine at one offered load (requests/s, wall
    time). `make_engine()` builds the engine; `prompts[i]` is request
    i's prompt. Latencies are measured from the *intended* arrival
    times; submit-based twins ride along for the CO guard."""
    import time

    from repro.serve.engine import Request

    eng = make_engine()
    n = len(prompts)
    if warm:
        warm_engine(eng, int(prompts[0].shape[0]))
    intended = arrival_times(
        key, 0, rate_hz=rate_rps, n=n, process=process, template=template
    )
    reqs = [
        Request(uid=i, prompt=prompts[i], max_new=max_new)
        for i in range(n)
    ]
    t_submit = np.zeros(n)
    t_first = np.full(n, np.nan)
    t_done = np.full(n, np.nan)
    seen_first = [False] * n
    submitted = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        if now > max_wall_s:
            raise RuntimeError(
                f"serve load point rate={rate_rps:.3f} exceeded "
                f"{max_wall_s}s wall budget"
            )
        while submitted < n and intended[submitted] <= now:
            eng.submit(reqs[submitted])
            t_submit[submitted] = time.perf_counter() - t0
            submitted += 1
        busy = eng.tick() > 0 or bool(eng._queue)
        now = time.perf_counter() - t0
        for i, r in enumerate(reqs[:submitted]):
            if r.output and not seen_first[i]:
                seen_first[i] = True
                t_first[i] = now
            if r.done and math.isnan(t_done[i]):
                t_done[i] = now
        if all(r.done for r in reqs):
            break
        if not busy and submitted < n:
            # idle until the next intended arrival (open loop: we do
            # NOT pull it forward)
            time.sleep(
                min(max(intended[submitted] - now, 0.0), 0.01)
            )
    ttft_intended = t_first - intended
    ttft_submit = t_first - t_submit
    lat_intended = t_done - intended
    achieved = n / max(float(t_done.max() - intended[0]), 1e-9)
    return {
        "offered_load": float(rate_rps),
        "n_requests": int(n),
        "achieved_rps": float(achieved),
        "ttft": tail_summary(ttft_intended),
        "ttft_from_submit": tail_summary(ttft_submit),
        "latency": tail_summary(lat_intended),
        "max_queue_delay_s": float((t_submit - intended).max()),
        "_raw": {
            "ttft_intended": ttft_intended,
            "ttft_submit": ttft_submit,
        },
        # the sweep's knee detector reads p99 of the intended-based
        # end-to-end latency
        "p50_s": tail_summary(lat_intended)["p50_s"],
        "p99_s": tail_summary(lat_intended)["p99_s"],
        "p999_s": tail_summary(lat_intended)["p999_s"],
    }


def sweep_serve(
    make_engine,
    make_prompts,
    *,
    capacity_rps: float,
    load_fractions: Sequence[float] = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0),
    n_requests: int = 24,
    max_new: int = 8,
    seed: int = 0,
    process: str = "poisson",
    ttft_slo: Optional[SLO] = None,
) -> dict:
    """Offered-load sweep for the serve engine. `capacity_rps` anchors
    the grid (measure it closed-loop first); fractions > 1 are the
    overload points the verdict is judged on."""
    import jax

    key = jax.random.PRNGKey(seed)
    points = []
    for j, frac in enumerate(sorted(load_fractions)):
        rate = max(frac * capacity_rps, 1e-3)
        pt = run_serve_point(
            make_engine,
            make_prompts(n_requests),
            rate_rps=rate,
            max_new=max_new,
            key=jax.random.fold_in(key, j),
            process=process,
        )
        pt["load_fraction"] = float(frac)
        points.append(pt)
    knee = locate_knee(points)
    overload = [p for p in points if p["load_fraction"] > 1.0]
    sub = [p for p in points if p["load_fraction"] <= 0.75]
    # CO guard is judged at the highest-load point, where the backlog
    # is guaranteed
    worst = max(points, key=lambda p: p["offered_load"])
    guard = co_guard(
        worst["_raw"]["ttft_intended"],
        worst["_raw"]["ttft_submit"],
        saturated=bool(overload),
    )
    slo = ttft_slo
    if slo is None:
        # calibrate the TTFT bound from the least-loaded point: an
        # order of magnitude above its p50 is comfortably met below
        # the knee and hopeless past it
        base = points[0]["ttft"]["p50_s"] or 0.01
        slo = SLO(
            name="serve.ttft.p99",
            metric="ttft_from_intended_s",
            bound=max(10.0 * base, 0.05),
            target=0.99,
        )
    slo_points = []
    for p in points:
        tt = p["_raw"]["ttft_intended"]
        slo_points.append({
            "offered_load": p["offered_load"],
            "load_fraction": p["load_fraction"],
            **slo.evaluate(int((tt <= slo.bound).sum()), len(tt)),
        })
    for p in points:
        del p["_raw"]  # raw arrays stay out of the JSON record
    sub_ok = [s for s in slo_points if s["load_fraction"] <= 0.75]
    # wall-clock noise robustness: open-loop tail latency is monotone
    # non-decreasing in offered load for a work-conserving server, so a
    # sub-saturated violation contradicted by a clean pass at STRICTLY
    # higher offered load is a host hiccup, not load — discount it (the
    # per-point burn rates still record it; only the aggregate verdict
    # ignores it)
    def _met_or_noise(s) -> bool:
        if s["met"]:
            return True
        return any(
            t["met"] and t["offered_load"] > s["offered_load"]
            for t in slo_points
        )
    verdict = "graceful_degradation"
    if overload:
        retention = min(
            p["achieved_rps"] for p in overload
        ) / max(capacity_rps, 1e-9)
        if retention < 0.5:
            verdict = "queue_collapse"
    else:
        retention = None
    return {
        "engine": "serve",
        "timebase": "wall",
        "capacity_rps": float(capacity_rps),
        "points": points,
        "knee": knee,
        "slo": {
            "declared": dataclasses.asdict(slo),
            "points": slo_points,
            "met_sub_saturated": all(_met_or_noise(s) for s in sub_ok)
            if sub_ok
            else None,
        },
        "coordinated_omission_guard": guard,
        "overload": {
            "verdict": verdict,
            "throughput_retention": retention,
        },
        "_sub_saturated_points": len(sub),
    }


# ---------------------------------------------------------------------------
# stream sweep (virtual time)
# ---------------------------------------------------------------------------


def poisson_segment_refs(
    *,
    n_patients: int,
    rate_segments_per_s: float,
    horizon_s: float,
    deadline_s: float,
    seed: int = 0,
    process: str = "poisson",
    template=None,
) -> list:
    """Open-loop per-patient arrival schedules for the stream fleet:
    patient p's segments arrive as a Poisson (or trace-driven) process
    at `rate_segments_per_s / n_patients`, keyed by `fold_in(key, p)`
    — deterministic, and independent across patients. Deadlines are
    arrival-relative, as in the periodic source."""
    import jax

    from repro.stream.sources import SegmentRef

    key = jax.random.PRNGKey(seed)
    per_patient = rate_segments_per_s / n_patients
    # draw enough gaps to cover the horizon with margin, then clip
    n_draw = max(int(per_patient * horizon_s * 2) + 8, 8)
    refs = []
    for p in range(n_patients):
        t = arrival_times(
            key, p, rate_hz=per_patient, n=n_draw,
            process=process, template=template,
        )
        t = t[t <= horizon_s]
        refs.extend(
            SegmentRef(
                patient=p,
                seq=int(s),
                arrival_s=float(ts),
                deadline_s=float(ts) + deadline_s,
            )
            for s, ts in enumerate(t)
        )
    refs.sort(key=lambda r: (r.arrival_s, r.patient, r.seq))
    return refs


def sweep_stream(
    *,
    n_patients: int = 64,
    buckets: tuple = (8, 32),
    load_fractions: Sequence[float] = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0),
    segments_at_capacity: int = 2048,
    seed: int = 0,
    urgent_fraction: float = 0.125,
    process: str = "poisson",
    runner=None,
) -> dict:
    """Offered-load sweep for the stream fleet in virtual time. The
    capacity anchor is the modeled fleet rate for the largest bucket
    (bucket / `runner.batch_service_s(bucket)`); latency is modeled
    completion − intended arrival, so the sweep is exactly
    reproducible on any host. Every point runs the same virtual
    horizon (`segments_at_capacity / capacity` — so the 2x point
    offers ~2x the segments), the deadline is a fixed multiple of the
    largest bucket's service time, and a pinned URGENT cohort
    (`urgent_fraction` of patients) checks class survival under
    overload — preemption must keep their p99.9 deadline slack
    non-negative even when routine traffic is drowning."""
    from repro.stream.fleet import FleetConfig, simulate
    from repro.stream.runner import FleetRunner

    if runner is None:
        import jax

        from repro.core import compiler, vadetect

        params = vadetect.init(jax.random.PRNGKey(seed))
        runner = FleetRunner(compiler.compile_model(params))

    service = runner.batch_service_s(buckets[-1])
    cap = buckets[-1] / service
    horizon_s = segments_at_capacity / cap
    # sub-saturated latency ~ bucket-fill wait + one service; overload
    # latency grows with the backlog toward the horizon scale. 12
    # service times comfortably covers the former and is far below the
    # latter, so violations appear exactly past the knee.
    deadline_s = 12.0 * service
    n_urgent = max(1, int(round(urgent_fraction * n_patients)))
    pinned = np.zeros(n_patients, bool)
    pinned[:n_urgent] = True

    points = []
    slack_urgent_overload_ok = 0
    slack_urgent_overload_total = 0
    urgent_slo = SLO(
        name="stream.urgent.deadline_slack.p999",
        metric="deadline_slack_s",
        bound=0.0,
        target=0.999,
    )
    slo_points = []
    for frac in sorted(load_fractions):
        rate = frac * cap
        refs = poisson_segment_refs(
            n_patients=n_patients,
            rate_segments_per_s=rate,
            horizon_s=horizon_s,
            deadline_s=deadline_s,
            seed=seed,
            process=process,
        )
        cfg = FleetConfig(
            n_patients=n_patients,
            segments_per_patient=1,  # unused: arrivals are explicit
            seed=seed,
            buckets=buckets,
            # signal content is irrelevant to the latency model; an
            # all-normal fleet keeps the synthetic generator cheap
            va_fraction=0.0,
        )
        out = simulate(
            cfg,
            runner=runner,
            arrivals=refs,
            pinned_urgent=pinned,
            collect_latency=True,
        )
        lat = out["latency"]
        latency = np.asarray(lat["latency_s"])
        slack = np.asarray(lat["slack_s"])
        # class membership by pinned cohort (stable across the sweep);
        # pack-time priority can additionally include vote-driven
        # urgency, which the lab deliberately doesn't score on
        prio = pinned[np.asarray(lat["patient"], int)]
        # intended-based vs dequeue-based: dequeue here is the pack
        # instant; completion − formed_at is the "polite" number the
        # CO guard forbids using
        from_dequeue = np.asarray(lat["latency_from_pack_s"])
        pt = {
            "offered_load": float(rate),
            "load_fraction": float(frac),
            "n_segments": int(latency.size),
            **{
                k: tail_summary(latency)[k]
                for k in ("p50_s", "p99_s", "p999_s", "count")
            },
            "latency": tail_summary(latency),
            "latency_urgent": tail_summary(latency[prio]),
            "latency_routine": tail_summary(latency[~prio]),
            "slack_ok_fraction": float((slack >= 0).mean()),
            "dropped": int(out["metrics"]["dropped_total"]),
            "queue_depth_max": int(out["metrics"]["queue_depth_max"]),
            "_raw": {
                "latency_intended": latency,
                "latency_dequeue": from_dequeue,
            },
        }
        points.append(pt)
        u_ok = int((slack[prio] >= 0).sum())
        u_tot = int(prio.sum())
        slo_points.append({
            "offered_load": pt["offered_load"],
            "load_fraction": float(frac),
            **urgent_slo.evaluate(u_ok, u_tot),
        })
        if frac > 1.0:
            slack_urgent_overload_ok += u_ok
            slack_urgent_overload_total += u_tot
    knee = locate_knee(points)
    worst = max(points, key=lambda p: p["offered_load"])
    guard = co_guard(
        worst["_raw"]["latency_intended"],
        worst["_raw"]["latency_dequeue"],
        saturated=any(p["load_fraction"] > 1.0 for p in points),
    )
    for p in points:
        del p["_raw"]
    overload_eval = urgent_slo.evaluate(
        slack_urgent_overload_ok, slack_urgent_overload_total
    )
    survived = bool(overload_eval.get("met"))
    no_drops = all(p["dropped"] == 0 for p in points)
    verdict = (
        "graceful_degradation"
        if survived and no_drops
        else "queue_collapse"
    )
    return {
        "engine": "stream",
        "timebase": "virtual",
        "capacity_segments_per_s": float(cap),
        "n_patients": int(n_patients),
        "urgent_patients": int(n_urgent),
        "points": points,
        "knee": knee,
        "slo": {
            "declared": dataclasses.asdict(urgent_slo),
            "points": slo_points,
            "urgent_overload": overload_eval,
        },
        "coordinated_omission_guard": guard,
        "overload": {
            "verdict": verdict,
            "urgent_survived": survived,
            "never_dropped": no_drops,
        },
    }


# ---------------------------------------------------------------------------
# frontend sweep (wall time, through the serving transport)
# ---------------------------------------------------------------------------


async def _frontend_point_async(
    make_frontend,
    prompts,
    *,
    rate_rps: float,
    max_new: int,
    key,
    transport: str,
    n_patients: int,
    segs_per_patient: int,
    urgent_patients,
    seg_deadline_rel_s: float,
    process: str,
    max_wall_s: float,
) -> dict:
    import asyncio
    import time

    from repro.serve.frontend import InProcClient, SocketClient

    fe = make_frontend()
    addr = await fe.start(
        host="127.0.0.1" if transport == "socket" else None, port=0
    )
    client = (
        await SocketClient.connect(*addr)
        if transport == "socket"
        else InProcClient(fe)
    )
    n = len(prompts)
    intended = arrival_times(
        key, 0, rate_hz=rate_rps, n=n, process=process
    )
    horizon = float(intended[-1])
    # per-patient segment schedules over the same wall horizon, on
    # fold_in keys disjoint from the LM schedule's uid
    seg_events = []
    if n_patients > 0 and segs_per_patient > 0:
        per_rate = segs_per_patient / max(horizon, 1e-3)
        for p in range(n_patients):
            ts = arrival_times(
                key, 10_000 + p, rate_hz=per_rate,
                n=segs_per_patient, process=process,
            )
            seg_events.extend(
                (float(t), p, s, bool(urgent_patients[p]))
                for s, t in enumerate(ts)
            )
        seg_events.sort()
    t_send = np.zeros(n)
    lm_futs: list = [None] * n
    seg_futs: list = []
    t0 = time.perf_counter()

    async def drive_lm() -> None:
        for i in range(n):
            delay = intended[i] - (time.perf_counter() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
            lm_futs[i] = await client.send_lm(
                uid=i, prompt=[int(x) for x in prompts[i]],
                max_new=max_new,
            )
            t_send[i] = time.perf_counter() - t0

    async def drive_segs() -> None:
        for t, p, s, urg in seg_events:
            delay = t - (time.perf_counter() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
            seg_futs.append((p, s, urg, await client.send_segment(
                p, s, deadline_rel_s=seg_deadline_rel_s, urgent=urg,
            )))

    try:
        # both generators are truly open-loop: they send on schedule
        # whether or not replies have come back
        await asyncio.wait_for(
            asyncio.gather(drive_lm(), drive_segs()), max_wall_s
        )
        results = [
            await asyncio.wait_for(f, max_wall_s) for f in lm_futs
        ]
        acks = [
            (p, s, urg, await asyncio.wait_for(f, max_wall_s))
            for p, s, urg, f in seg_futs
        ]
        stats = (await client.drain(timeout=max_wall_s))["stats"]
    finally:
        await client.close()
        await fe.stop()

    completed = [r for r in results if r["status"] == "completed"]
    rejected = [r for r in results if r["status"] == "rejected"]
    by_reason: dict[str, int] = {}
    for r in rejected:
        by_reason[r["reason"]] = by_reason.get(r["reason"], 0) + 1
    done_idx = [
        i for i, r in enumerate(results) if r["status"] == "completed"
    ]
    from_intended = np.asarray([
        results[i]["_t_recv"] - t0 - intended[i] for i in done_idx
    ])
    from_send = np.asarray([
        results[i]["_t_recv"] - t0 - t_send[i] for i in done_idx
    ])
    lat = tail_summary(from_intended)
    span = (
        max(
            results[i]["_t_recv"] - t0 for i in done_idx
        ) - float(intended[0])
        if done_idx else None
    )
    urgent_bad = sum(
        1 for _, _, urg, a in acks
        if urg and a["status"] != "enqueued"
    )
    deferred = sum(1 for *_, a in acks if a["status"] == "deferred")
    seg_rejected = sum(
        1 for *_, a in acks if a["status"] == "rejected"
    )
    # after a drain every enqueued segment must have been packed —
    # anything else is a silent scheduler drop
    dropped = int(
        stats.get("sched_enqueued_total", 0)
        - stats.get("sched_packed_total", 0)
    )
    return {
        "transport": transport,
        "offered_load": float(rate_rps),
        "n_requests": int(n),
        "submitted": int(n),
        "completed": len(completed),
        "rejected": len(rejected),
        "rejected_by_reason": by_reason,
        "shed_rate": len(rejected) / n,
        "accounting_exact": len(completed) + len(rejected) == n,
        "completed_rps": (
            len(completed) / max(span, 1e-9) if span else 0.0
        ),
        "latency": lat,
        "p50_s": lat["p50_s"],
        "p99_s": lat["p99_s"],
        "p999_s": lat["p999_s"],
        "segments": {
            "sent": len(acks),
            "urgent_sent": sum(1 for *_, u, _a in acks if u),
            "deferred": deferred,
            "rejected": seg_rejected,
            "urgent_not_enqueued": urgent_bad,
            "dropped": dropped,
        },
        "frontend_stats": stats,
        "_raw": {
            "from_intended": from_intended,
            "from_send": from_send,
        },
    }


def run_frontend_point(
    make_frontend,
    prompts,
    *,
    rate_rps: float,
    max_new: int,
    key,
    transport: str = "socket",
    n_patients: int = 0,
    segs_per_patient: int = 0,
    urgent_patients=None,
    seg_deadline_rel_s: float = 0.5,
    process: str = "poisson",
    max_wall_s: float = 120.0,
) -> dict:
    """One offered-load point through the serving frontend
    (`serve.frontend`): an open-loop asyncio client sends LM requests
    at `rate_rps` (intended arrival schedule generated up front) and
    per-patient segment arrivals over the same horizon, over a
    loopback socket or the in-process transport. Every request's
    terminal outcome is collected — completed XOR an explicit typed
    rejection — along with shed/deferral/urgent accounting from the
    acks and the frontend's drain stats.

    Unlike `run_serve_point`, the generator here is a separate async
    task from the server, so sends stay on schedule even at overload:
    the queueing excess lives server-side and shows up as shed rate
    and reply latency, not send lag. The CO twins (`from_intended` vs
    `from_send`) therefore agree to scheduler jitter — recorded, but
    the strict-inequality overload check is not applicable on this
    path."""
    import asyncio

    if urgent_patients is None:
        urgent_patients = np.zeros(max(n_patients, 1), bool)
    return asyncio.run(_frontend_point_async(
        make_frontend,
        prompts,
        rate_rps=rate_rps,
        max_new=max_new,
        key=key,
        transport=transport,
        n_patients=n_patients,
        segs_per_patient=segs_per_patient,
        urgent_patients=urgent_patients,
        seg_deadline_rel_s=seg_deadline_rel_s,
        process=process,
        max_wall_s=max_wall_s,
    ))


def sweep_frontend(
    make_frontend,
    make_prompts,
    *,
    admission_rate_rps: float,
    load_fractions: Sequence[float] = (0.25, 1.0, 3.0),
    n_requests: int = 24,
    max_new: int = 8,
    seed: int = 0,
    transport: str = "socket",
    n_patients: int = 8,
    segs_per_patient: int = 3,
    urgent_fraction: float = 0.25,
    seg_deadline_rel_s: float = 0.5,
    process: str = "poisson",
    compare_transports: bool = True,
) -> dict:
    """Offered-load sweep THROUGH the frontend transport, with active
    admission control at `admission_rate_rps` (wire it to
    `sweep_serve`'s measured knee). `make_frontend(cfg)` builds a
    fresh, warmed frontend from the per-sweep `FrontendConfig`.

    The verdict is judged on robust, deterministic signals — exact
    terminal accounting (submitted == completed + rejected, every shed
    an explicit typed rejection), URGENT segment survival (never
    deferred, never shed, never dropped, at any load), and completed-
    throughput retention at overload vs the best sub-knee point —
    rather than on wall-clock latency ratios, which a noisy host can
    fake either way. Tail latencies and the shed-rate curve past the
    knee are recorded alongside for the report."""
    import jax

    from repro.serve.frontend import FrontendConfig

    n_urgent = max(1, int(round(urgent_fraction * n_patients)))
    urgent_patients = np.zeros(n_patients, bool)
    urgent_patients[:n_urgent] = True
    # ROUTINE segment bucket: sized so the 1.0x point's segment rate
    # is exactly at the admission rate — overload points defer routine
    # traffic, demonstrating shed-vs-defer policy divergence
    seg_rate = (
        n_patients * segs_per_patient * admission_rate_rps
        / max(n_requests, 1)
    )
    fcfg = FrontendConfig(
        lm_queue_limit=max(4 * n_requests, 64),
        admission_rate_rps=admission_rate_rps,
        admission_burst=8.0,
        stream_rate_rps=seg_rate if n_patients > 0 else None,
        stream_burst=4.0,
        stream_buckets=(4, 8),
        stream_max_wait_s=0.02,
        seg_deadline_rel_s=seg_deadline_rel_s,
    )
    key = jax.random.PRNGKey(seed)
    points = []
    for j, frac in enumerate(sorted(load_fractions)):
        pt = run_frontend_point(
            lambda: make_frontend(fcfg),
            make_prompts(n_requests),
            rate_rps=max(frac * admission_rate_rps, 1e-3),
            max_new=max_new,
            key=jax.random.fold_in(key, j),
            transport=transport,
            n_patients=n_patients,
            segs_per_patient=segs_per_patient,
            urgent_patients=urgent_patients,
            seg_deadline_rel_s=seg_deadline_rel_s,
            process=process,
        )
        pt["load_fraction"] = float(frac)
        points.append(pt)
    # CO twins at the highest-load point: the async generator sends on
    # schedule, so intended >= send holds but the overload strictness
    # check does not apply (see run_frontend_point)
    worst = max(points, key=lambda p: p["offered_load"])
    guard = (
        co_guard(
            worst["_raw"]["from_intended"],
            worst["_raw"]["from_send"],
            saturated=False,
        )
        if worst["_raw"]["from_intended"].size
        else None
    )
    for p in points:
        del p["_raw"]
    overload = [p for p in points if p["load_fraction"] > 1.0]
    sub = [p for p in points if p["load_fraction"] <= 1.0]
    accounting_exact = all(p["accounting_exact"] for p in points)
    urgent_ok = all(
        p["segments"]["urgent_not_enqueued"] == 0
        and p["segments"]["dropped"] == 0
        for p in points
    )
    typed_only = all(
        sum(p["rejected_by_reason"].values()) == p["rejected"]
        for p in points
    )
    retention = None
    if overload:
        ref = max(
            (p["completed_rps"] for p in sub), default=None
        ) or admission_rate_rps
        retention = min(
            p["completed_rps"] for p in overload
        ) / max(ref, 1e-9)
    verdict = "graceful_degradation"
    if not (accounting_exact and urgent_ok and typed_only):
        verdict = "queue_collapse"
    elif retention is not None and retention < 0.5:
        verdict = "queue_collapse"
    out = {
        "engine": "frontend",
        "timebase": "wall",
        "transport": transport,
        "admission_rate_rps": float(admission_rate_rps),
        "admission_burst": float(fcfg.admission_burst),
        "stream_rate_rps": fcfg.stream_rate_rps,
        "n_patients": int(n_patients),
        "urgent_patients": int(n_urgent),
        "points": points,
        "shed_curve": [
            {"load_fraction": p["load_fraction"],
             "shed_rate": p["shed_rate"]}
            for p in points
        ],
        "coordinated_omission_guard": guard,
        "overload": {
            "verdict": verdict,
            "accounting_exact": accounting_exact,
            "urgent_survived": urgent_ok,
            "typed_rejections_only": typed_only,
            "throughput_retention": retention,
        },
    }
    if compare_transports and sub:
        # matched point on the other transport: the in-process client
        # enters the same handler with no socket hop, so the tail delta
        # prices the transport itself
        base = min(sub, key=lambda p: p["load_fraction"])
        other = "inproc" if transport == "socket" else "socket"
        twin = run_frontend_point(
            lambda: make_frontend(fcfg),
            make_prompts(n_requests),
            rate_rps=base["offered_load"],
            max_new=max_new,
            key=jax.random.fold_in(key, 0),  # same schedule as point 0
            transport=other,
            n_patients=n_patients,
            segs_per_patient=segs_per_patient,
            urgent_patients=urgent_patients,
            seg_deadline_rel_s=seg_deadline_rel_s,
            process=process,
        )
        del twin["_raw"]
        pair = {transport: base, other: twin}
        out["transport_overhead"] = {
            "load_fraction": base["load_fraction"],
            "p50_s": {
                t: pair[t]["p50_s"] for t in pair
            },
            "p99_s": {
                t: pair[t]["p99_s"] for t in pair
            },
            "socket_minus_inproc_p50_s": (
                (pair["socket"]["p50_s"] or 0.0)
                - (pair["inproc"]["p50_s"] or 0.0)
            ),
            "socket_minus_inproc_p99_s": (
                (pair["socket"]["p99_s"] or 0.0)
                - (pair["inproc"]["p99_s"] or 0.0)
            ),
        }
    return out


# ---------------------------------------------------------------------------
# CLI: render the HTML report
# ---------------------------------------------------------------------------


def main() -> None:
    import argparse
    import json

    from repro.obs import report

    ap = argparse.ArgumentParser(
        description="render the standalone load-lab HTML report from a "
                    "BENCH_load.json"
    )
    ap.add_argument("bench", help="path to BENCH_load.json")
    ap.add_argument("-o", "--out", default="load_report.html")
    args = ap.parse_args()
    with open(args.bench) as f:
        record = json.load(f)
    path = report.render_report(record, args.out)
    print(f"[obs.loadlab] wrote {path}")


if __name__ == "__main__":
    main()
