"""Request lineage: join one request's spans across subsystem hops.

PR 6's tracer records *what* happened (spans with wall/virtual
timestamps and thread-correct parent edges); this module recovers *to
whom*. Every hop the serve engine and the stream fleet emit carries a
request id — a string minted at the moment a request enters the system
(`submit` for serve, `enqueue` for stream) and propagated through every
later hop as a span attr:

  * a single-request event tags `request_id="serve:3"`;
  * a batched hop (an admission group, a packed bucket, a pool decode
    tick) tags `request_ids=[...]` — one span, many requests; the span
    is a hop of *each* of them.

Hop vocabularies (the instrumented paths):

  serve   submit → admit (prefill, seat children) → tick/decode → finish
  stream  enqueue → pack → flush (classify, vote children)

When requests arrive through the serving frontend (`serve.frontend`),
the id is minted CLIENT-side and carried across the wire, and the
transport adds hops of its own: `frontend/ingress` before the entry
hop and `frontend/reply` (LM terminal reply, after `serve/finish`) or
`frontend/ack` (segment admission ack) — so a joined lineage spans the
socket hop, not just the in-process path.

`join` inverts the tagging into {request_id: [hop, ...]} with hops in
timestamp order; `critical_path` folds one request's hops into the
queue-wait / compute / seating attribution the load lab reports, and
`assert_joined` is the acceptance gate: every sampled request's spans
must join into one lineage across at least `min_hops` distinct hops.

Timestamps: hops carry both wall (`ts_s`/`dur_s`, seconds from tracer
epoch) and, where the emitting subsystem models time, virtual
(`v_ts_s`/`v_dur_s`) coordinates. Stream lineages are best read in
virtual time (the modeled fleet timeline); serve lineages in wall time.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

# ---------------------------------------------------------------------------
# request-id minting — the one format every hop and the joiner agree on
# ---------------------------------------------------------------------------


def serve_rid(uid: int) -> str:
    """Request id of one LM serving request (engine `Request.uid`)."""
    return f"serve:{uid}"


def stream_rid(patient: int, seq: int) -> str:
    """Request id of one streamed segment — (patient, seq) is the
    fleet-wide unique identity `data.iegm` keys content on."""
    return f"stream:{patient}:{seq}"


@dataclasses.dataclass(frozen=True)
class Hop:
    """One event on one request's path."""

    name: str
    ts_s: float  # wall seconds from tracer epoch
    dur_s: float
    span_id: int
    parent_id: int
    v_ts_s: Optional[float] = None  # virtual (modeled) coordinates
    v_dur_s: Optional[float] = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def end_s(self) -> float:
        return self.ts_s + self.dur_s


def _event_rids(e: dict) -> list[str]:
    attrs = e.get("attrs") or {}
    rid = attrs.get("request_id")
    if rid is not None:
        return [rid]
    return list(attrs.get("request_ids") or ())


def join(events: Iterable[dict]) -> dict[str, list[Hop]]:
    """{request_id: hops in timestamp order} over a tracer event list
    (the in-memory `tracer.events()` or a parsed JSONL log). Events
    with no request tag are simply not lineage hops."""
    out: dict[str, list[Hop]] = {}
    for e in events:
        rids = _event_rids(e)
        if not rids:
            continue
        attrs = e.get("attrs") or {}
        hop = Hop(
            name=e["name"],
            ts_s=e["ts_us"] / 1e6,
            dur_s=e["dur_us"] / 1e6,
            span_id=int(e.get("span_id", 0)),
            parent_id=int(e.get("parent_id", 0)),
            v_ts_s=attrs.get("v_ts_s"),
            v_dur_s=attrs.get("v_dur_s"),
            attrs=attrs,
        )
        for rid in rids:
            out.setdefault(rid, []).append(hop)
    for hops in out.values():
        hops.sort(key=lambda h: (h.ts_s, h.span_id))
    return out


# hop-name → attribution phase. Child spans (prefill/seat under admit,
# classify/vote under flush) refine their parent's interval, so the
# parent hops are deliberately NOT phases of their own.
_PHASE_OF = {
    "serve/prefill": "prefill",
    "serve/seat": "seat",
    "serve/decode": "decode",
    "stream/classify": "classify",
    "stream/vote": "vote",
}
_ENTRY_HOPS = ("frontend/ingress", "serve/submit", "stream/enqueue")
# `frontend/reply` is the LM terminal reply (strictly after
# serve/finish); the segment ack is deliberately NOT an exit hop — it
# precedes the segment's stream hops in wall time
_EXIT_HOPS = ("serve/finish", "frontend/reply")


def critical_path(hops: list[Hop]) -> dict:
    """Fold one request's hops into an end-to-end attribution:

      * `queue_wait_s` — entry (submit/enqueue) until the first span
        that actually works on the request;
      * per-phase compute seconds (prefill / seat / decode for serve,
        classify / vote for stream), summed over every tagged span;
      * `total_s` — entry until the last hop ends (the finish instant
        for serve; the last span end for stream).

    Wall coordinates; a stream lineage additionally reports
    `v_total_s` from the virtual track when every hop carries one."""
    if not hops:
        return {"hops": 0}
    entry = next((h for h in hops if h.name in _ENTRY_HOPS), hops[0])
    worked = [h for h in hops if h.name in _PHASE_OF]
    first_work = min(
        (h.ts_s for h in worked), default=entry.ts_s
    )
    finish = next(
        (h for h in reversed(hops) if h.name in _EXIT_HOPS), None
    )
    end = finish.ts_s if finish is not None else max(
        h.end_s for h in hops
    )
    phases: dict[str, float] = {}
    for h in worked:
        key = _PHASE_OF[h.name]
        phases[key] = phases.get(key, 0.0) + h.dur_s
    out = {
        "hops": len(hops),
        "hop_names": [h.name for h in hops],
        "t_entry_s": entry.ts_s,
        "queue_wait_s": max(first_work - entry.ts_s, 0.0),
        "phases_s": phases,
        "total_s": max(end - entry.ts_s, 0.0),
    }
    v_entry = entry.v_ts_s
    v_ends = [
        h.v_ts_s + (h.v_dur_s or 0.0)
        for h in hops
        if h.v_ts_s is not None
    ]
    if v_entry is not None and v_ends:
        out["v_total_s"] = max(max(v_ends) - v_entry, 0.0)
    return out


def summarize(events: Iterable[dict]) -> dict:
    """Lineage roll-up for a BENCH record: how many requests joined,
    the hop-count distribution, and min/max distinct hops."""
    lineages = join(events)
    if not lineages:
        return {"requests": 0}
    distinct = [len({h.name for h in hops}) for hops in lineages.values()]
    with_transport = sum(
        1 for hops in lineages.values()
        if any(h.name.startswith("frontend/") for h in hops)
    )
    return {
        "requests": len(lineages),
        "min_distinct_hops": min(distinct),
        "max_distinct_hops": max(distinct),
        "mean_hops": sum(len(h) for h in lineages.values())
        / len(lineages),
        "requests_with_transport_hop": with_transport,
    }


def assert_joined(
    events: Iterable[dict], *, min_hops: int = 3,
    expect_prefix: Optional[str] = None,
) -> dict[str, list[Hop]]:
    """The acceptance gate: every request id seen anywhere in `events`
    joins into one lineage with >= `min_hops` *distinct* hop names.
    Returns the join so callers can keep using it."""
    lineages = join(events)
    if not lineages:
        raise AssertionError("no request lineage in trace — "
                             "request-id tagging is dark")
    for rid, hops in lineages.items():
        if expect_prefix and not rid.startswith(expect_prefix):
            continue
        names = {h.name for h in hops}
        if len(names) < min_hops:
            raise AssertionError(
                f"request {rid!r} joined only {sorted(names)} "
                f"(< {min_hops} distinct hops)"
            )
    return lineages


# package-level alias: `obs.join_lineage` reads better than a bare
# `join` next to the other re-exports
join_lineage = join

__all__ = [
    "Hop",
    "assert_joined",
    "critical_path",
    "join",
    "join_lineage",
    "serve_rid",
    "stream_rid",
    "summarize",
]
