"""Metric registry: counters, gauges, mergeable fixed-bucket histograms.

The fleet-scale replacement for the ad-hoc per-subsystem counters
(`stream.metrics.FleetMetrics`' raw slack lists, `Engine.
admission_rowsteps`): one `Registry` per process holds every metric by
name, and every instrument is O(1) memory regardless of sample count —
a histogram is a fixed array of log-spaced buckets, so p50/p99/p99.9
over a million-sample latency stream costs O(buckets), and per-shard
histograms merge by bucket-wise addition (bit-exact: merging shard
histograms equals the histogram of the concatenated samples, which
`tests/test_obs.py` property-tests).

A *disabled* registry hands out shared null instruments whose methods
are no-ops — the hot paths call `obs.get().registry.counter(...)`
unconditionally and pay nanoseconds, not branches, when telemetry is
off (asserted in `tests/test_obs.py::test_disabled_telemetry_is_noop`).

Bucket layouts:

  * `latency` — log-spaced positive edges, `LATENCY_LO`..`LATENCY_HI`
    seconds at `PER_DECADE` buckets per decade (relative quantile error
    bounded by one bucket ratio, 10^(1/PER_DECADE) ≈ 1.21x);
  * `signed`  — the latency edges mirrored through 0 (for deadline
    *slack*, which is negative on a violation): ...-1e-6, 0, 1e-6...
    with 0 an explicit edge so "how many samples were <= 0" is exact.
"""

from __future__ import annotations

import math
import threading
from typing import Optional

import numpy as np

LATENCY_LO = 1e-7  # 100 ns — below one python bytecode dispatch
LATENCY_HI = 1e5  # ~28 h — beyond any single run
PER_DECADE = 12


def latency_bounds(
    lo: float = LATENCY_LO, hi: float = LATENCY_HI,
    per_decade: int = PER_DECADE,
) -> np.ndarray:
    """Log-spaced finite bucket upper edges (ascending, positive)."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return np.geomspace(lo, hi, n + 1)


def signed_bounds(
    lo: float = LATENCY_LO, hi: float = LATENCY_HI,
    per_decade: int = PER_DECADE,
) -> np.ndarray:
    """Symmetric signed-log edges: -latency reversed, 0, +latency."""
    pos = latency_bounds(lo, hi, per_decade)
    return np.concatenate([-pos[::-1], [0.0], pos])


_LAYOUTS = {
    "latency": latency_bounds,
    "signed": signed_bounds,
}


class Histogram:
    """Fixed-bucket histogram. `bounds` are the finite bucket upper
    edges; `counts` has `len(bounds) + 1` entries — sample x lands in
    the first bucket whose edge is >= x, or the overflow bucket past
    the last edge. Exact count/sum/min/max ride along so the summary
    never loses the extremes to bucketing."""

    __slots__ = (
        "name", "layout", "bounds", "counts", "count", "sum",
        "min", "max",
    )

    def __init__(self, name: str = "", layout: str = "latency",
                 bounds: Optional[np.ndarray] = None):
        self.name = name
        self.layout = layout if bounds is None else "custom"
        self.bounds = (
            np.asarray(bounds, np.float64)
            if bounds is not None
            else _LAYOUTS[layout]()
        )
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- observation --------------------------------------------------------

    def observe(self, x: float) -> None:
        x = float(x)
        i = int(np.searchsorted(self.bounds, x, side="left"))
        self.counts[i] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def observe_array(self, xs) -> None:
        xs = np.asarray(xs, np.float64).ravel()
        if xs.size == 0:
            return
        idx = np.searchsorted(self.bounds, xs, side="left")
        self.counts += np.bincount(idx, minlength=len(self.counts))
        self.count += xs.size
        self.sum += float(xs.sum())
        self.min = min(self.min, float(xs.min()))
        self.max = max(self.max, float(xs.max()))

    # -- merge --------------------------------------------------------------

    def merge(self, other: "Histogram") -> "Histogram":
        """Bucket-wise in-place merge; layouts must match exactly."""
        if len(self.bounds) != len(other.bounds) or not np.array_equal(
            self.bounds, other.bounds
        ):
            raise ValueError(
                f"histogram layout mismatch: {self.name!r} vs "
                f"{other.name!r}"
            )
        self.counts += other.counts
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    @classmethod
    def merged(cls, hists) -> "Histogram":
        hists = list(hists)
        if not hists:
            raise ValueError("nothing to merge")
        out = cls(hists[0].name, bounds=hists[0].bounds)
        out.layout = hists[0].layout
        for h in hists:
            out.merge(h)
        return out

    # -- quantiles ----------------------------------------------------------

    def _edges(self, i: int) -> tuple[float, float]:
        """(lower, upper) edge of bucket i, clamped to observed range."""
        lo = -math.inf if i == 0 else float(self.bounds[i - 1])
        hi = math.inf if i >= len(self.bounds) else float(self.bounds[i])
        return max(lo, self.min), min(hi, self.max)

    def quantile(self, q: float) -> float:
        """Rank-interpolated quantile from the buckets: O(buckets),
        error bounded by the width of the bucket holding the rank."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo, hi = self._edges(i)
                frac = min(max((target - cum) / c, 0.0), 1.0)
                return lo + frac * (hi - lo)
            cum += c
        return self.max

    def count_at_or_below(self, x: float) -> int:
        """Exact when `x` is a bucket edge (e.g. 0 in the signed
        layout); otherwise rounds down to the nearest edge."""
        i = int(np.searchsorted(self.bounds, float(x), side="right"))
        return int(self.counts[:i].sum())

    def fraction_at_or_below(self, x: float) -> float:
        """SLO compliance fraction: P(sample <= x). Exact at bucket
        edges — which is why the signed layout keeps 0 explicit (the
        deadline-slack SLO asks exactly 'what fraction was < 0')."""
        if self.count == 0:
            return math.nan
        return self.count_at_or_below(x) / self.count

    # -- report -------------------------------------------------------------

    def summary(self) -> dict:
        """The shared BENCH `telemetry` histogram record."""
        empty = self.count == 0
        return {
            "count": int(self.count),
            "sum": float(self.sum),
            "min": None if empty else float(self.min),
            "max": None if empty else float(self.max),
            "mean": None if empty else float(self.sum / self.count),
            "p50": None if empty else float(self.quantile(0.50)),
            "p90": None if empty else float(self.quantile(0.90)),
            "p99": None if empty else float(self.quantile(0.99)),
            "p999": None if empty else float(self.quantile(0.999)),
            "layout": self.layout,
            "n_buckets": int(len(self.counts)),
            # sparse encoding: only occupied buckets, as
            # [bucket index, count, upper edge] triples
            "nonzero_buckets": [
                [int(i), int(c),
                 None if i >= len(self.bounds)
                 else float(self.bounds[i])]
                for i, c in enumerate(self.counts) if c
            ],
        }


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    add = inc


class Gauge:
    """Last-set value plus the high-water mark (peak) since creation —
    the pair device-memory tracking needs."""

    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0.0
        self.peak = -math.inf

    def set(self, v: float) -> None:
        self.value = float(v)
        if self.value > self.peak:
            self.peak = self.value


class _NullCounter:
    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    add = inc


class _NullGauge:
    __slots__ = ()
    value = 0.0
    peak = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    count = 0

    def observe(self, x: float) -> None:
        pass

    def observe_array(self, xs) -> None:
        pass


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


class Registry:
    """Name -> instrument map. Creation is lazy and idempotent
    (`counter("x")` twice returns the same object); a disabled registry
    returns the shared null instruments without allocating."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str):
        if not self.enabled:
            return NULL_COUNTER
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str):
        if not self.enabled:
            return NULL_GAUGE
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str, layout: str = "latency"):
        if not self.enabled:
            return NULL_HISTOGRAM
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name, Histogram(name, layout)
                )
        return h

    def snapshot(self) -> dict:
        """The registry half of the shared BENCH `telemetry` schema."""
        return {
            "counters": {
                k: int(c.value) for k, c in sorted(self._counters.items())
            },
            "gauges": {
                k: {"value": g.value,
                    "peak": None if g.peak == -math.inf else g.peak}
                for k, g in sorted(self._gauges.items())
            },
            "histograms": {
                k: h.summary()
                for k, h in sorted(self._histograms.items())
            },
        }
