"""JAX-specific telemetry probes: recompiles, step timing, memory.

Three concerns the generic registry/tracer can't see:

  * **Compile/recompile visibility** — every jitted cell the serving
    and training paths compile (decode step, per-width admission
    prefills, stream bucket classify, multipod reduction stages) is
    registered here by name; `cache_sizes()` reads each cell's jit
    cache entry count (`_cache_size`), so "did anything retrace after
    warmup" is one snapshot diff (`new_misses`). This generalizes the
    PR 2 stream-only miss-count check to every compiled cell —
    `tests/test_obs.py` guards both stream buckets and decode
    admission widths with it.
  * **Bounded step timing** — `timed_call` wraps a jitted call in
    `block_until_ready` so the observed duration is device work, not
    dispatch; only used when telemetry is enabled (callers pass the
    enabled flag), so the async pipeline is never serialized silently.
  * **Device memory gauges** — `device_memory_bytes()` prefers the
    platform allocator's `memory_stats()["bytes_in_use"]` and falls
    back to summing `jax.live_arrays()` (the only option on forced
    host-platform devices); `observe_memory` folds it into live/peak
    gauges.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro._compat import cost_analysis_dict


def jit_cache_size(fn) -> Optional[int]:
    """Compiled-variant count of a jitted callable (None if the
    installed jax doesn't expose it or `fn` isn't a jit wrapper)."""
    try:
        return int(fn._cache_size())
    except Exception:  # noqa: BLE001 — probe must never raise
        return None


@dataclasses.dataclass
class CellInfo:
    """Audit metadata for one tracked jit cell.

    `budget` is the cell's declared collective comm budget: HLO
    collective op name -> max occurrences in the optimized module
    (`None` means "undeclared" and the auditor treats it as the empty
    budget — no collectives allowed — so single-device cells need no
    declaration and sharded cells must state theirs). `donate` mirrors
    the jit's `donate_argnums`; the auditor uses it to assert no
    donation was silently dropped. `sharded_outputs` declares that at
    least one output must land sharded (not fully replicated).
    `call_avals` is the (args, kwargs) aval pytree captured from the
    cell's first real call — what the auditor re-lowers with."""

    name: str
    fn: Callable
    budget: Optional[dict] = None
    donate: tuple = ()
    sharded_outputs: bool = False
    call_avals: Optional[tuple] = None


def _aval_of(x):
    """Abstract one call-argument leaf: arrays become
    `ShapeDtypeStruct` (keeping a `NamedSharding` so sharded cells
    re-lower on their mesh; single-device placements stay abstract),
    everything else passes through verbatim so weak-typed Python
    scalars retrace exactly as the real call did. Never holds a buffer
    reference — safe to capture args that are about to be donated."""
    if isinstance(x, jax.Array):
        sh = x.sharding
        if isinstance(sh, jax.sharding.NamedSharding):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    if isinstance(x, np.ndarray):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return x


class TrackedCell:
    """Transparent wrapper an enabled probe returns from `track`:
    records the argument avals of the first call into the cell's
    `CellInfo` (one tree_map, then a plain delegate — far inside the
    disabled-telemetry overhead budget), and forwards attribute access
    to the underlying jit wrapper so `.lower`/`._cache_size` callers
    are unaffected."""

    def __init__(self, info: CellInfo):
        self._info = info
        self._fn = info.fn

    def __call__(self, *args, **kwargs):
        if self._info.call_avals is None:
            self._info.call_avals = jax.tree.map(
                _aval_of, (args, kwargs)
            )
        return self._fn(*args, **kwargs)

    def __getattr__(self, name):
        return getattr(self._fn, name)


class JitProbe:
    """Named registry of jitted cells for recompile accounting.

    A disabled probe drops registrations (no strong refs pinning jit
    caches alive through a long test session); an enabled one keeps
    them for the lifetime of the run — benchmark/launcher scale."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._cells: dict[str, CellInfo] = {}

    def track(self, name: str, fn, *, budget: Optional[dict] = None,
              donate: tuple = (), sharded_outputs: bool = False):
        """Register `fn` under `name` (idempotent; later registrations
        under the same name win — e.g. a rebuilt engine). Returns a
        call-through `TrackedCell` when enabled (disabled probes return
        `fn` unchanged) so call sites can wrap in place. Keyword
        metadata feeds the `repro.analysis` cell auditor."""
        if not self.enabled:
            return fn
        info = CellInfo(
            name=name, fn=fn, budget=budget, donate=tuple(donate),
            sharded_outputs=sharded_outputs,
        )
        self._cells[name] = info
        return TrackedCell(info)

    def cells(self) -> dict:
        """name -> `CellInfo` for every tracked cell (the
        `repro.analysis.cellaudit` walk surface)."""
        return dict(sorted(self._cells.items()))

    def cache_sizes(self) -> dict:
        """name -> compiled-variant count for every tracked cell (the
        BENCH `telemetry.recompiles` section)."""
        return {
            name: jit_cache_size(info.fn)
            for name, info in sorted(self._cells.items())
        }

    def snapshot(self) -> dict:
        return self.cache_sizes()

    def new_misses(self, since: dict) -> dict:
        """Cells that compiled new variants after `since` (a
        `snapshot()`), name -> extra compile count. Empty means zero
        recompiles — the regression-guard condition."""
        out = {}
        for name, n in self.cache_sizes().items():
            before = since.get(name)
            if n is not None and before is not None and n > before:
                out[name] = n - before
        return out


class _NullProbe:
    __slots__ = ()
    enabled = False

    def track(self, name, fn, **meta):
        return fn

    def cells(self):
        return {}

    def cache_sizes(self):
        return {}

    snapshot = cache_sizes

    def new_misses(self, since):
        return {}


NULL_PROBE = _NullProbe()


# ---------------------------------------------------------------------------
# timing / memory
# ---------------------------------------------------------------------------


def timed_call(histogram, fn, *args, **kwargs):
    """Call `fn`, block until its result is ready, and observe the
    bounded duration into `histogram` (a registry histogram or the
    null one). Returns the (ready) result."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    histogram.observe(time.perf_counter() - t0)
    return out


def device_memory_bytes() -> int:
    """Best-effort live device memory: allocator stats when the
    platform reports them, else the sum of live jax array bytes (the
    forced-host-device fallback; it misses internal allocator slack but
    tracks the arrays the program actually holds)."""
    total = 0
    saw_stats = False
    for d in jax.devices():
        try:
            stats = d.memory_stats()
        except Exception:  # noqa: BLE001
            stats = None
        if stats and "bytes_in_use" in stats:
            total += int(stats["bytes_in_use"])
            saw_stats = True
    if saw_stats:
        return total
    return int(sum(a.nbytes for a in jax.live_arrays()))


def observe_memory(registry) -> int:
    """Sample device memory into the live/peak gauges; returns the
    sampled byte count. The `jax.device_bytes` gauge's `.peak` is the
    BENCH `telemetry.peak_device_memory_bytes` value."""
    n = device_memory_bytes()
    registry.gauge("jax.device_bytes").set(n)
    return n


def cost_gauges(registry, name: str, compiled) -> dict:
    """Fold a compiled cell's `cost_analysis` flops/bytes estimates
    into gauges (`<name>.flops`, `<name>.bytes_accessed`); returns the
    normalized cost dict."""
    ca = cost_analysis_dict(compiled)
    if "flops" in ca:
        registry.gauge(f"{name}.flops").set(float(ca["flops"]))
    if "bytes accessed" in ca:
        registry.gauge(f"{name}.bytes_accessed").set(
            float(ca["bytes accessed"])
        )
    return ca
