"""nm_spmm — the SPE array as a Pallas TPU kernel.

Balanced select-index sparse matmul:

    y[m, n] = sum_r values[r, n] * x[m, (r // keep) * G + select[r, n]]

HBM traffic is the *compressed* stream (values + 4-bit-class select
signals), exactly like the chip: the SPE's "select one of 16 registers"
becomes, per VMEM tile, a one-hot in-group scatter that rebuilds a dense
weight tile which the MXU then consumes at full systolic throughput.

TPU adaptation note (vs. the ASIC): the MXU has no per-lane zero-skip, so
the win here is *bandwidth* (half the weight bytes moved), not MACs. The
decompression is gather-free (VPU compare+madd, ~keep/G of the matmul's
FLOPs). See DESIGN.md §2 for the mapping table.

Tiling (defaults, f32 worst case):
    x tile      (bm=128, bk=256)           128 KB
    values/sel  (bkk=128, bn=128) int8+u8   32 KB
    dense w     (bk=256, bn=128)           128 KB
    one-hot tmp (16 groups, 16, 8, 128)      1 MB transient
    out         (bm=128, bn=128)            64 KB
  comfortably inside the ~16 MB VMEM of a v5e core; MXU dims all 128-mult.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import decompress_tile


def _kernel(
    x_ref,  # (bm, bk) float
    v_ref,  # (bkk, bn) int8/float
    s_ref,  # (bkk, bn) uint8
    scale_ref,  # (1, bn) f32
    o_ref,  # (bm, bn) f32
    *,
    group_size: int,
    keep: int,
    nk: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = decompress_tile(v_ref[...], s_ref[...], group_size, keep)  # (bk, bn)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _scale():
        o_ref[...] *= scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "group_size", "keep", "block_m", "block_n", "block_groups",
        "interpret",
    ),
)
def nm_spmm_2d(
    x: jax.Array,  # (M, K) — K a multiple of group_size, groups-padded
    values: jax.Array,  # (Kk, N) int8 or float
    select: jax.Array,  # (Kk, N) uint8
    scale: jax.Array,  # (1, N) f32 (pass ones for unquantized)
    *,
    group_size: int,
    keep: int,
    block_m: int = 128,
    block_n: int = 128,
    block_groups: int = 16,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    kk, n = values.shape
    assert k % group_size == 0 and kk == (k // group_size) * keep, (
        f"K={k} / Kk={kk} inconsistent with {keep}:{group_size} sparsity"
    )
    bm = min(block_m, m)
    bn = min(block_n, n)
    gpb = min(block_groups, k // group_size)
    bk = gpb * group_size
    bkk = gpb * keep
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(
            _kernel, group_size=group_size, keep=keep, nk=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk_: (i, kk_)),
            pl.BlockSpec((bkk, bn), lambda i, j, kk_: (kk_, j)),
            pl.BlockSpec((bkk, bn), lambda i, j, kk_: (kk_, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk_: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, values, select, scale)
