"""quant_matmul — packed sub-byte dequant matmul (the production LM path).

Same storage format as `bitserial` (packed two's-complement planes from
`quant.pack_planes`) but a single MXU pass per K-tile: unpack -> sign-extend
-> one matmul. This is what the serving engine uses for weight-quantized
projections: HBM moves bits/8 bytes per weight (the memory-roofline win on
decode shapes), the MXU runs one dense pass.

`bitserial_matmul` (plane-per-pass) and this kernel are numerically
identical; tests assert both against the same oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import unpack_tile


def _kernel(x_ref, p_ref, scale_ref, o_ref, *, bits: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = unpack_tile(p_ref[...], bits).astype(jnp.float32)  # (bk, bn)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _scale():
        o_ref[...] *= scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_m", "block_n", "block_k", "interpret"),
)
def quant_matmul_2d(
    x: jax.Array,  # (M, K)
    packed: jax.Array,  # (K * bits / 8, N) uint8
    scale: jax.Array,  # (1, N) f32
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    kp, n = packed.shape
    vpb = 8 // bits
    assert kp * vpb == k, f"packed rows {kp} x {vpb} != K={k}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    assert bk % vpb == 0 and k % bk == 0, (bk, vpb, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // vpb, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scale)
