"""Shared helpers for the Pallas TPU kernels.

Everything here runs *inside* kernel bodies (on VMEM-resident tiles) or is
shape plumbing for the ops wrappers. Block shapes default to MXU-aligned
(128 multiples); the working set per grid cell is kept well under the
~16 MB/core VMEM budget of TPU v5e (see each kernel's header math).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad `axis` of `x` up to the next multiple of `multiple`."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def unpack_tile(packed: jax.Array, bits: int) -> jax.Array:
    """VMEM unpack: uint8 (Kp, N) tile -> signed int32 (Kp*vpb, N) tile.

    Mirrors `core.quant.unpack_planes` but with static shapes only (no
    slicing to a dynamic K — the wrapper pre-pads K to tile multiples).
    1-bit planes decode {0,1} -> {-1,+1}.
    """
    vpb = 8 // bits
    mask = (1 << bits) - 1
    kp, n = packed.shape
    shifts = (jnp.arange(vpb, dtype=jnp.uint32) * bits).reshape(1, vpb, 1)
    u = (packed.astype(jnp.uint32)[:, None, :] >> shifts) & mask
    u = u.reshape(kp * vpb, n).astype(jnp.int32)
    if bits == 1:
        return jnp.where(u > 0, 1, -1)
    sign_bit = 1 << (bits - 1)
    return jnp.where(u >= sign_bit, u - (1 << bits), u)


def decompress_tile(
    values: jax.Array, select: jax.Array, group_size: int, keep: int
) -> jax.Array:
    """VMEM decompress: (Kk, N) values+select -> dense (Kk//keep*G, N).

    Gather-free (TPU VPU-friendly): a one-hot compare against an in-group
    iota scatters each compressed row into its dense slot. Cost is
    keep * dense_K * N compares — ~keep/G of the matmul's MACs, i.e. noise
    next to the MXU work it unlocks.
    """
    kk, n = values.shape
    groups = kk // keep
    vals = values.reshape(groups, keep, n).astype(jnp.float32)
    sel = select.reshape(groups, keep, n).astype(jnp.int32)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, group_size, 1, 1), 1)
    onehot = (sel[:, None, :, :] == slot).astype(jnp.float32)
    dense = jnp.sum(onehot * vals[:, None, :, :], axis=2)  # (groups, G, N)
    return dense.reshape(groups * group_size, n)


def flatten_batch(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """(..., K) -> ((M, K), leading_shape) for 2-D kernel entry."""
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    return x.reshape(m, x.shape[-1]), lead
