"""sparse_conv1d — one fused VA-net layer (im2col + SPE matmul) in Pallas.

The chip streams the ifmap through the shared SPad and never materializes
im2col patches in memory; this kernel does the same on TPU: the input tile
lives once in VMEM, windows are cut *inside* the kernel (static strided
slices), and the compressed weights are decompressed in VMEM and fed to
the MXU. HBM traffic: the raw signal + the compressed weight stream only.

Shapes are the VA detector's (T<=512, C<=96, N<=96), so a whole (1, T, C)
row plus all weights fit in VMEM trivially; the grid walks
(batch, T_out tiles, N tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import decompress_tile


def _kernel(
    x_ref,  # (1, T_pad, C) float — full padded row in VMEM
    v_ref,  # (Kk, bn)
    s_ref,  # (Kk, bn)
    scale_ref,  # (1, bn)
    o_ref,  # (1, bt, bn) f32
    *,
    ksize: int,
    stride: int,
    group_size: int,
    keep: int,
    block_t: int,
    k_dense: int,
):
    bt = block_t
    t0 = pl.program_id(1) * bt * stride  # input start of this output tile
    span = (bt - 1) * stride + ksize
    win = x_ref[0, pl.ds(t0, span), :].astype(jnp.float32)  # (span, C)
    # im2col inside VMEM: row-order (tap, channel) == compiler's flatten.
    cols = [
        win[i : i + (bt - 1) * stride + 1 : stride, :] for i in range(ksize)
    ]
    patches = jnp.concatenate(cols, axis=-1)  # (bt, ksize*C)
    if patches.shape[-1] < k_dense:  # group padding (zeros, like the chip)
        patches = jnp.pad(
            patches, ((0, 0), (0, k_dense - patches.shape[-1]))
        )
    w = decompress_tile(v_ref[...], s_ref[...], group_size, keep)
    y = jnp.dot(patches, w, preferred_element_type=jnp.float32)
    o_ref[0, :, :] = y * scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "ksize", "stride", "group_size", "keep", "block_t", "block_n",
        "interpret",
    ),
)
def sparse_conv1d_call(
    x: jax.Array,  # (B, T, C) — unpadded signal
    values: jax.Array,  # (Kk, N)
    select: jax.Array,  # (Kk, N) uint8
    scale: jax.Array,  # (1, N)
    *,
    ksize: int,
    stride: int,
    group_size: int,
    keep: int,
    block_t: int = 64,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, t, c = x.shape
    kk, n = values.shape
    k_dense = (kk // keep) * group_size
    assert k_dense >= ksize * c, (k_dense, ksize, c)
    t_out = (t - 1) // stride + 1
    # SAME padding (XLA convention), applied host-side once.
    pad_total = max((t_out - 1) * stride + ksize - t, 0)
    pad_l = pad_total // 2
    bt = min(block_t, t_out)
    nt = pl.cdiv(t_out, bt)
    # pad T so every tile's input span is in-bounds
    span_end = (nt * bt - 1) * stride + ksize
    xp = jnp.pad(x, ((0, 0), (pad_l, max(span_end - t - pad_l, 0)), (0, 0)))
    bn = min(block_n, n)
    grid = (b, nt, pl.cdiv(n, bn))
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            ksize=ksize,
            stride=stride,
            group_size=group_size,
            keep=keep,
            block_t=bt,
            k_dense=k_dense,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, xp.shape[1], c), lambda bi, ti, ni: (bi, 0, 0)),
            pl.BlockSpec((kk, bn), lambda bi, ti, ni: (0, ni)),
            pl.BlockSpec((kk, bn), lambda bi, ti, ni: (0, ni)),
            pl.BlockSpec((1, bn), lambda bi, ti, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((1, bt, bn), lambda bi, ti, ni: (bi, ti, ni)),
        out_shape=jax.ShapeDtypeStruct((b, nt * bt, n), jnp.float32),
        interpret=interpret,
    )(xp, values, select, scale)
    return out[:, :t_out, :]
