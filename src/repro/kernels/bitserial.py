"""bitserial_matmul — the CMUL as a Pallas TPU kernel.

The chip's configurable multiplier splits each B-bit weight into 1-bit
segments, multiplies each against the selected activation, and
shift-accumulates. The TPU-native twin: per VMEM tile, unpack the packed
two's-complement planes and run one MXU matmul per plane:

    y = sum_b s_b 2^b (x @ W_b),   s_b = -1 for the sign plane else +1

Numerically identical to dequant-then-matmul (asserted in tests). HBM
traffic is the packed size (bits/8 bytes per weight) — this is how sub-byte
(4/2/1-bit) layers pay for only what they store, without native int4
dtypes. For 8-bit layers prefer `quant_matmul` (1 MXU pass, same bytes);
the plane loop is the *faithful* CMUL arithmetic and the sub-byte path.

Tiling (defaults): x (128, 256) f32 + packed (256*bits/8, 128) u8 +
out (128, 128) f32 + per-plane {0,1} tile (256, 128) f32 — ≪ VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, p_ref, scale_ref, o_ref, *, bits: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    packed = p_ref[...]  # (bk/vpb, bn) uint8
    vpb = 8 // bits
    kp, bn = packed.shape
    mask = (1 << bits) - 1
    shifts = (jnp.arange(vpb, dtype=jnp.uint32) * bits).reshape(1, vpb, 1)
    u = (packed.astype(jnp.uint32)[:, None, :] >> shifts) & mask
    u = u.reshape(kp * vpb, bn)  # unsigned two's-complement words (bk, bn)

    if bits == 1:
        # plane in {0,1} encodes {-1,+1}: w = 2p - 1
        p = u.astype(jnp.float32)
        acc = 2.0 * jnp.dot(x, p, preferred_element_type=jnp.float32)
        acc -= jnp.sum(x, axis=-1, keepdims=True)
    else:
        acc = jnp.zeros_like(o_ref)
        for b in range(bits):  # static: one MXU pass per plane
            plane = ((u >> b) & 1).astype(jnp.float32)
            coeff = -(2.0 ** (bits - 1)) if b == bits - 1 else 2.0**b
            acc += coeff * jnp.dot(
                x, plane, preferred_element_type=jnp.float32
            )
    o_ref[...] += acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _scale():
        o_ref[...] *= scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_m", "block_n", "block_k", "interpret"),
)
def bitserial_matmul_2d(
    x: jax.Array,  # (M, K)
    packed: jax.Array,  # (K * bits / 8, N) uint8 — `quant.pack_planes`
    scale: jax.Array,  # (1, N) f32
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    kp, n = packed.shape
    vpb = 8 // bits
    assert kp * vpb == k, f"packed rows {kp} x {vpb} != K={k}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    assert bk % vpb == 0 and k % bk == 0, (bk, vpb, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // vpb, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scale)
