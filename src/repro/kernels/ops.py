"""Public wrappers for the Pallas kernels.

Handle batch-dim flattening, tile padding, scale defaulting, and backend
dispatch: on CPU (this container) kernels run in interpret mode — the
kernel *body* executes in Python for correctness validation; on TPU the
same code lowers to Mosaic. `interpret=None` means auto.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import bitserial as _bitserial
from repro.kernels import nm_spmm as _nm_spmm
from repro.kernels import quant_matmul as _quant_matmul
from repro.kernels import sparse_conv1d as _sparse_conv1d
from repro.kernels._common import flatten_batch, pad_to


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _ones_scale(n: int) -> jax.Array:
    return jnp.ones((1, n), jnp.float32)


def nm_spmm(
    x: jax.Array,
    values: jax.Array,
    select: jax.Array,
    scale: Optional[jax.Array] = None,
    *,
    group_size: int,
    keep: int,
    block_m: int = 128,
    block_n: int = 128,
    block_groups: int = 16,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Balanced select-index sparse matmul (..., K) x (Kk, N) -> (..., N).

    K (dense contraction of `x`) must equal (Kk // keep) * group_size —
    i.e. `x` is already group-padded, as `core.compiler` guarantees.
    """
    kk, n = values.shape
    x2, lead = flatten_batch(x)
    m, k = x2.shape
    assert kk % keep == 0 and k == (kk // keep) * group_size, (k, kk)
    sc = scale if scale is not None else _ones_scale(n)
    sc = sc.reshape(1, n).astype(jnp.float32)
    # pad M and N to tile multiples; K is tiled in whole groups already.
    bm = min(block_m, max(8, m))
    bn = min(block_n, n)
    xp = pad_to(x2, 0, bm)
    vp = pad_to(values, 1, bn)
    sp = pad_to(select, 1, bn)
    scp = pad_to(sc, 1, bn)
    gpb = block_groups
    while (k // group_size) % gpb:
        gpb //= 2
    y = _nm_spmm.nm_spmm_2d(
        xp, vp, sp, scp,
        group_size=group_size, keep=keep,
        block_m=bm, block_n=bn, block_groups=gpb,
        interpret=_auto_interpret(interpret),
    )[:m, :n]
    return y.reshape(*lead, n)


def bitserial_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: Optional[jax.Array] = None,
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """CMUL bit-plane matmul (..., K) x packed(K*bits/8, N) -> (..., N)."""
    vpb = 8 // bits
    kp, n = packed.shape
    k = kp * vpb
    x2, lead = flatten_batch(x)
    m, kx = x2.shape
    assert kx == k, (kx, k)
    sc = (scale if scale is not None else _ones_scale(n)).reshape(1, n)
    bm = min(block_m, max(8, m))
    bn = min(block_n, n)
    bk = min(block_k, k)
    while k % bk or bk % vpb:
        bk //= 2
    xp = pad_to(x2, 0, bm)
    pp = pad_to(packed, 1, bn)
    scp = pad_to(sc.astype(jnp.float32), 1, bn)
    y = _bitserial.bitserial_matmul_2d(
        xp, pp, scp, bits=bits,
        block_m=bm, block_n=bn, block_k=bk,
        interpret=_auto_interpret(interpret),
    )[:m, :n]
    return y.reshape(*lead, n)


def quant_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: Optional[jax.Array] = None,
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Packed dequant matmul (single MXU pass) — serving path."""
    vpb = 8 // bits
    kp, n = packed.shape
    k = kp * vpb
    x2, lead = flatten_batch(x)
    m, kx = x2.shape
    assert kx == k, (kx, k)
    sc = (scale if scale is not None else _ones_scale(n)).reshape(1, n)
    bm = min(block_m, max(8, m))
    bn = min(block_n, n)
    bk = min(block_k, k)
    while k % bk or bk % vpb:
        bk //= 2
    xp = pad_to(x2, 0, bm)
    pp = pad_to(packed, 1, bn)
    scp = pad_to(sc.astype(jnp.float32), 1, bn)
    y = _quant_matmul.quant_matmul_2d(
        xp, pp, scp, bits=bits,
        block_m=bm, block_n=bn, block_k=bk,
        interpret=_auto_interpret(interpret),
    )[:m, :n]
    return y.reshape(*lead, n)


def sparse_conv1d(
    x: jax.Array,
    values: jax.Array,
    select: jax.Array,
    scale: Optional[jax.Array] = None,
    *,
    ksize: int,
    stride: int = 1,
    group_size: int,
    keep: int,
    block_t: int = 64,
    block_n: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused sparse-quantized 1-D conv (B, T, C) -> (B, T_out, N)."""
    kk, n = values.shape
    sc = (scale if scale is not None else _ones_scale(n)).reshape(1, n)
    bn = min(block_n, n)
    vp = pad_to(values, 1, bn)
    sp = pad_to(select, 1, bn)
    scp = pad_to(sc.astype(jnp.float32), 1, bn)
    y = _sparse_conv1d.sparse_conv1d_call(
        x, vp, sp, scp,
        ksize=ksize, stride=stride, group_size=group_size, keep=keep,
        block_t=block_t, block_n=bn,
        interpret=_auto_interpret(interpret),
    )
    return y[..., :n]
