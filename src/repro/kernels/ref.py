"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition* of its kernel: small, obviously
correct, and memory-naive. Kernel tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle; the oracles themselves are validated
against the ``core.quant`` / ``core.sparsity`` math in the core tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core import sparsity as S


# ---------------------------------------------------------------------------
# nm_spmm — balanced select-index sparse matmul (the SPE)
# ---------------------------------------------------------------------------


def nm_spmm_ref(
    x: jax.Array,
    values: jax.Array,
    select: jax.Array,
    scale: jax.Array | None,
    *,
    group_size: int,
    keep: int,
) -> jax.Array:
    """y[..., n] = sum_r values[r, n] * x[..., (r//keep)*G + select[r, n]].

    ``values`` may be int8 (quantized, with per-channel ``scale``) or float
    (``scale=None``). Output is f32.
    """
    cfg = S.SparsityConfig(group_size, keep)
    y = S.sparse_matmul_ref(
        x.astype(jnp.float32), values.astype(jnp.float32), select, cfg
    )
    if scale is not None:
        y = y * scale.reshape((1,) * (y.ndim - 1) + (-1,))
    return y


# ---------------------------------------------------------------------------
# bitserial_matmul — CMUL bit-plane matmul over packed planes
# ---------------------------------------------------------------------------


def bitserial_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k: int,
) -> jax.Array:
    """y = x @ dequantize(unpack(packed)) — defined via the exact bit-serial
    shift-accumulate (`quant.bitserial_matmul_exact`), i.e. the CMUL's own
    arithmetic. Output f32."""
    q = Q.unpack_planes(packed, bits, k)
    y = Q.bitserial_matmul_exact(x.astype(jnp.float32), q, bits)
    return y * scale.reshape((1,) * (y.ndim - 1) + (-1,))


# ---------------------------------------------------------------------------
# quant_matmul — packed int8/int4/int2/int1 dense dequant matmul (LM path)
# ---------------------------------------------------------------------------


def quant_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k: int,
) -> jax.Array:
    """y = x @ (unpack(packed) * scale). Output f32."""
    q = Q.unpack_planes(packed, bits, k).astype(jnp.float32)
    y = x.astype(jnp.float32) @ q
    return y * scale.reshape((1,) * (y.ndim - 1) + (-1,))


# ---------------------------------------------------------------------------
# sparse_conv1d — fused im2col + SPE matmul (one VA-net layer)
# ---------------------------------------------------------------------------


def sparse_conv1d_ref(
    x: jax.Array,
    values: jax.Array,
    select: jax.Array,
    scale: jax.Array | None,
    *,
    ksize: int,
    stride: int,
    group_size: int,
    keep: int,
) -> jax.Array:
    """(B, T, C) -> (B, T_out, N) sparse-quantized conv, SAME padding.

    The contraction dim is the flattened (ksize * C) window, zero-padded to
    a whole number of sparsity groups — exactly what `core.compiler` emits.
    """
    b, t, c = x.shape
    t_out = (t - 1) // stride + 1
    pad_total = max((t_out - 1) * stride + ksize - t, 0)
    pad_l = pad_total // 2
    xp = jnp.pad(x, ((0, 0), (pad_l, pad_total - pad_l), (0, 0)))
    starts = jnp.arange(t_out) * stride
    patches = jax.vmap(
        lambda s: jax.lax.dynamic_slice_in_dim(xp, s, ksize, axis=1),
        out_axes=1,
    )(starts).reshape(b, t_out, ksize * c)
    k_dense = (values.shape[0] // keep) * group_size
    if patches.shape[-1] < k_dense:
        patches = jnp.pad(
            patches, ((0, 0), (0, 0), (0, k_dense - patches.shape[-1]))
        )
    return nm_spmm_ref(
        patches, values, select, scale, group_size=group_size, keep=keep
    )
