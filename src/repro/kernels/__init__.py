"""Pallas TPU kernels for the paper's compute hot-spots.

- nm_spmm        : the SPE — balanced select-index sparse matmul
- bitserial      : the CMUL — bit-plane (8/4/2/1-bit) matmul
- quant_matmul   : packed dequant matmul (production sub-byte path)
- sparse_conv1d  : fused im2col + SPE matmul (one VA-net layer)

`ops` holds the public wrappers (batch handling, padding, interpret
dispatch); `ref` the pure-jnp oracles every kernel is tested against.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
