"""Int8 gradient compression with error feedback for cross-pod DCN.

The paper beats the memory wall with compressed weights/activations;
the same move applied to the slowest wire in a multi-pod job — the
cross-pod data-center network — is int8 gradient all-reduce:

  * `quantize_leaf` — per-leaf symmetric int8 (scale = amax/127), so
    the one-shot error is bounded by scale/2;
  * `compress_residual` — error feedback: quantize (grad + carried
    residual), carry the new residual. Telescoping makes the scheme
    lossless over time: sum(dequantized sent) + residual == sum(grads),
    which is why compressed SGD converges unbiased;
  * `compressed_psum_mean` — the shard_map collective: each device
    all-gathers int8 values + f32 scalar scales and dequantize-averages
    locally.

Two schemes, selectable via `trainer.make_dp_step_compressed(...,
scheme=...)`:

  * `compressed_psum_mean` ("gather") — every device all-gathers the
    full int8 leaf from every peer and dequantize-averages locally.
    One error buffer per leaf.
  * `two_stage_psum_mean` ("two_stage") — quantized reduce-scatter +
    all-gather: stage 1 all-to-alls int8 chunks so device d owns shard
    d of the dequantized mean; stage 2 re-quantizes the owned shard
    and all-gathers it back. Error feedback at BOTH quantization
    points (err1 full-leaf, err2 shard-sized), each stage telescoping,
    so the composition is lossless over time like the one-stage scheme.

Wire accounting, honestly: a ring all-reduce of f32 costs each device
~2·(n-1)/n·4·|leaf| bytes of egress. The gather scheme costs
(n-1)·|leaf| int8 — a (8/n)x reduction over f32: a genuine 4x at the
production 2-pod mesh (`launch/mesh.py`), break-even at n=8, a LOSS
beyond. The two-stage scheme costs ~2·(n-1)/n·|leaf| int8
(all-to-all + all-gather of 1/n-sized shards) — ~4x below the f32
ring at ANY pod count. Scheme crossover guidance: n < 8 pods -> use
"gather" (fewer collectives, one quantization error instead of two);
n >= 8 -> use "two_stage" (the gather scheme's egress win has decayed
to <= 1x while two-stage holds ~4x). `benchmarks/dist_compression.py`
sweeps scheme x pod count and reports both the HLO-accounted
collective bytes and this modeled per-device egress.

Non-finite gradients (loss-spike inf/NaN) are zeroed before
quantization so they can neither corrupt the wire values nor lodge in
the persistent error buffer — a poisoned residual would otherwise
re-enter every later step. `uncompressed_psum_mean` applies the same
finite-guard by default so `compress=False` is a fair ablation
baseline (same failure semantics, only the wire format differs);
pass `finite_guard=False` for raw IEEE propagation.

In-pod axes keep XLA's native bf16/f32 collectives (ICI is not the
bottleneck); only the `pod` axis routes through here — see
`trainer.make_dp_step_compressed`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-leaf int8: returns (q int8, scale f32 scalar) with
    |dequantize(q, scale) - g| <= scale / 2 elementwise."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(
    g: jax.Array, err: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback step: quantize (g + err), return (q, scale,
    new_err) where new_err is the quantization residual to carry into
    the next step. Telescoping identity: across steps,
    sum(dequantize(q_t, s_t)) + err_T == sum(g_t).

    Non-finite entries of (g + err) are zeroed first so one bad step
    cannot poison the carried residual forever."""
    t = g.astype(jnp.float32) + err
    t = jnp.where(jnp.isfinite(t), t, 0.0)
    q, scale = quantize_leaf(t)
    new_err = t - dequantize_leaf(q, scale)
    return q, scale, new_err


# ---------------------------------------------------------------------------
# shard_map collectives
# ---------------------------------------------------------------------------


def _tree_zip_map(fn, a: Any, b: Any) -> tuple[Any, Any]:
    """Map fn(leaf_a, leaf_b) -> (x, y) over two trees, unzipping the
    results into two trees of the same structure."""
    flat_a, treedef = jax.tree_util.tree_flatten(a)
    flat_b = treedef.flatten_up_to(b)
    xs, ys = [], []
    for la, lb in zip(flat_a, flat_b):
        x, y = fn(la, lb)
        xs.append(x)
        ys.append(y)
    return (
        jax.tree_util.tree_unflatten(treedef, xs),
        jax.tree_util.tree_unflatten(treedef, ys),
    )


def compressed_psum_mean(
    grads: Any, err: Any, axis: str
) -> tuple[Any, Any]:
    """Mean of `grads` over mesh axis `axis` via int8+error-feedback
    compression. Call inside shard_map. Returns (mean_grads, new_err);
    per device, mean + mean-of-residuals telescopes to the true mean.

    Per-device egress per leaf: (n-1) * (|leaf| int8 + 4B scale) via
    ring all-gather, vs ~2*(n-1)/n * 4*|leaf| for an f32 ring
    all-reduce — see the module docstring for where each wins.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        q, scale, new_e = compress_residual(g, e)
        qs = jax.lax.all_gather(q, axis)  # (n, ...) int8
        ss = jax.lax.all_gather(scale, axis)  # (n,) f32
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim)
        return jnp.sum(deq, axis=0) / n, new_e

    return _tree_zip_map(one, grads, err)


def two_stage_psum_mean(
    grads: Any, err1: Any, err2: Any, axis: str
) -> tuple[Any, Any, Any]:
    """Mean of `grads` over mesh axis `axis` via quantized
    reduce-scatter + all-gather with two-stage error feedback. Call
    inside shard_map. Returns (mean_grads, new_err1, new_err2).

    Stage 1 (reduce-scatter): quantize (g + err1) per leaf, split the
    int8 codes into n chunks and all-to-all them, so device d receives
    chunk d from every peer; dequantize with the all-gathered per-peer
    scales and average -> device d owns shard d of the mean. err1 is
    the full-leaf quantization residual.

    Stage 2 (all-gather): quantize (owned shard + err2), all-gather the
    int8 shards + scales, dequantize into the full mean. err2 is the
    shard-sized residual: leaf shape (ceil(|leaf|/n),), carried
    per-device.

    Both stages telescope, so over steps (zero-initialized buffers):

        sum_t(returned mean) + pmean(err1_T, axis) + assembled(err2_T)
            == sum_t(true f32 mean)

    where assembled(err2) concatenates the per-device shards in axis
    order (exactly how checkpointing lays them out under a leading
    pod-axis spec — see `trainer.init_dp_err`).

    Per-device egress per leaf: ~2*(n-1)/n * |leaf| int8 + 8*(n-1)
    scale bytes — the same ~4x under the f32 ring at any n, unlike the
    gather scheme's (8/n)x (module docstring).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e1, e2):
        t = g.astype(jnp.float32) + e1
        t = jnp.where(jnp.isfinite(t), t, 0.0)
        size = t.size
        shard = -(-size // n)  # ceil: per-device shard length
        flat = jnp.pad(t.reshape(-1), (0, shard * n - size))
        q1, s1 = quantize_leaf(flat)
        new_e1 = (flat - dequantize_leaf(q1, s1))[:size].reshape(t.shape)
        # stage-1 exchange: row j of `chunks` is this device's shard-j
        # chunk; after the tiled all_to_all, row j holds peer j's chunk
        # for the shard THIS device owns.
        chunks = q1.reshape(n, shard)
        recv = jax.lax.all_to_all(
            chunks, axis, split_axis=0, concat_axis=0, tiled=True
        )
        s1_all = jax.lax.all_gather(s1, axis)  # (n,) f32
        own = jnp.sum(recv.astype(jnp.float32) * s1_all[:, None], 0) / n
        # stage-2: re-quantize the owned shard, gather all shards back
        u = own + e2
        u = jnp.where(jnp.isfinite(u), u, 0.0)
        q2, s2 = quantize_leaf(u)
        new_e2 = u - dequantize_leaf(q2, s2)
        q2_all = jax.lax.all_gather(q2, axis)  # (n, shard) int8
        s2_all = jax.lax.all_gather(s2, axis)  # (n,) f32
        mean = (q2_all.astype(jnp.float32) * s2_all[:, None]).reshape(-1)
        return mean[:size].reshape(t.shape), new_e1, new_e2

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e1 = treedef.flatten_up_to(err1)
    flat_e2 = treedef.flatten_up_to(err2)
    ms, n1s, n2s = [], [], []
    for g, e1, e2 in zip(flat_g, flat_e1, flat_e2):
        m, a, b = one(g, e1, e2)
        ms.append(m)
        n1s.append(a)
        n2s.append(b)
    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, ms), unflat(treedef, n1s), unflat(treedef, n2s)


def two_stage_shard_len(size: int, n: int) -> int:
    """Length of the per-device stage-2 shard (and err2 buffer) for a
    leaf of `size` elements reduced over `n` devices: ceil(size/n)."""
    return -(-size // n)


def uncompressed_psum_mean(
    grads: Any, axis: str, *, finite_guard: bool = True
) -> Any:
    """Baseline: plain f32 pmean over `axis` (inside shard_map).

    By default non-finite entries are zeroed before the reduction —
    the same guard the compressed paths apply pre-quantization — so
    `compress=False` ablations share failure semantics with the
    compressed run instead of broadcasting one pod's inf/NaN to every
    replica. `finite_guard=False` opts out (raw IEEE propagation, the
    pre-guard behavior)."""
    def one(g):
        if finite_guard:
            g = jnp.where(jnp.isfinite(g), g, jnp.zeros((), g.dtype))
        return jax.lax.pmean(g, axis)

    return jax.tree.map(one, grads)
