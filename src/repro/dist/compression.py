"""Int8 gradient compression with error feedback for cross-pod DCN.

The paper beats the memory wall with compressed weights/activations;
the same move applied to the slowest wire in a multi-pod job — the
cross-pod data-center network — is int8 gradient all-reduce:

  * `quantize_leaf` — per-leaf symmetric int8 (scale = amax/127), so
    the one-shot error is bounded by scale/2;
  * `compress_residual` — error feedback: quantize (grad + carried
    residual), carry the new residual. Telescoping makes the scheme
    lossless over time: sum(dequantized sent) + residual == sum(grads),
    which is why compressed SGD converges unbiased;
  * `compressed_psum_mean` — the shard_map collective: each device
    all-gathers int8 values + f32 scalar scales and dequantize-averages
    locally.

Wire accounting, honestly: a ring all-reduce of f32 costs each device
~2·(n-1)/n·4·|leaf| bytes of egress; all-gathering a full int8 leaf
per device costs (n-1)·|leaf| — a (8/n)x reduction. The production
mesh (`launch/mesh.py`) has n=2 pods, where that is a genuine 4x;
beyond n=8 the gather scheme loses and the right move is a quantized
all-to-all reduce-scatter + all-gather (n-independent ~4x; ROADMAP
open item). `benchmarks/dist_compression.py` reports both the
HLO-accounted collective bytes and this modeled per-device egress.

Non-finite gradients (loss-spike inf/NaN) are zeroed before
quantization so they can neither corrupt the wire values nor lodge in
the persistent error buffer — a poisoned residual would otherwise
re-enter every later step, unlike the stateless uncompressed path.

In-pod axes keep XLA's native bf16/f32 collectives (ICI is not the
bottleneck); only the `pod` axis routes through here — see
`trainer.make_dp_step_compressed`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-leaf int8: returns (q int8, scale f32 scalar) with
    |dequantize(q, scale) - g| <= scale / 2 elementwise."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(
    g: jax.Array, err: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback step: quantize (g + err), return (q, scale,
    new_err) where new_err is the quantization residual to carry into
    the next step. Telescoping identity: across steps,
    sum(dequantize(q_t, s_t)) + err_T == sum(g_t).

    Non-finite entries of (g + err) are zeroed first so one bad step
    cannot poison the carried residual forever."""
    t = g.astype(jnp.float32) + err
    t = jnp.where(jnp.isfinite(t), t, 0.0)
    q, scale = quantize_leaf(t)
    new_err = t - dequantize_leaf(q, scale)
    return q, scale, new_err


# ---------------------------------------------------------------------------
# shard_map collectives
# ---------------------------------------------------------------------------


def _tree_zip_map(fn, a: Any, b: Any) -> tuple[Any, Any]:
    """Map fn(leaf_a, leaf_b) -> (x, y) over two trees, unzipping the
    results into two trees of the same structure."""
    flat_a, treedef = jax.tree_util.tree_flatten(a)
    flat_b = treedef.flatten_up_to(b)
    xs, ys = [], []
    for la, lb in zip(flat_a, flat_b):
        x, y = fn(la, lb)
        xs.append(x)
        ys.append(y)
    return (
        jax.tree_util.tree_unflatten(treedef, xs),
        jax.tree_util.tree_unflatten(treedef, ys),
    )


def compressed_psum_mean(
    grads: Any, err: Any, axis: str
) -> tuple[Any, Any]:
    """Mean of `grads` over mesh axis `axis` via int8+error-feedback
    compression. Call inside shard_map. Returns (mean_grads, new_err);
    per device, mean + mean-of-residuals telescopes to the true mean.

    Per-device egress per leaf: (n-1) * (|leaf| int8 + 4B scale) via
    ring all-gather, vs ~2*(n-1)/n * 4*|leaf| for an f32 ring
    all-reduce — see the module docstring for where each wins.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        q, scale, new_e = compress_residual(g, e)
        qs = jax.lax.all_gather(q, axis)  # (n, ...) int8
        ss = jax.lax.all_gather(scale, axis)  # (n,) f32
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim)
        return jnp.sum(deq, axis=0) / n, new_e

    return _tree_zip_map(one, grads, err)


def uncompressed_psum_mean(grads: Any, axis: str) -> Any:
    """Baseline: plain f32 pmean over `axis` (inside shard_map)."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
