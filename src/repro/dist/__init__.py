"""Distributed execution: sharding rules, gradient accumulation,
compressed cross-pod collectives.

  sharding    — mesh-aware PartitionSpec rules for params / batches /
                caches, plus the logical activation-constraint system
                (`activation_context` / `constrain`);
  accumulate  — micro-batch gradient accumulation (scan);
  compression — int8 + error-feedback gradient reduction for the
                DCN-bound `pod` axis.
"""

from repro.dist import accumulate, compression, sharding
from repro.dist.accumulate import accumulate_grads
from repro.dist.sharding import (
    activation_context,
    batch_specs,
    cache_specs,
    constrain,
    data_axes,
    named,
    param_specs,
    spec_for_path,
)

__all__ = [
    "accumulate",
    "accumulate_grads",
    "activation_context",
    "batch_specs",
    "cache_specs",
    "compression",
    "constrain",
    "data_axes",
    "named",
    "param_specs",
    "sharding",
    "spec_for_path",
]
