"""Mesh-aware partition rules: params, batches, caches, activations.

One rule table serves every architecture because the param trees follow
two conventions (see `models/layers.py`): 2-D weights are
(in_features, out_features), and path names identify the role of each
linear. The physical axes come from `launch/mesh.py`:

  data  — DP + FSDP (ZeRO-style parameter/optimizer sharding);
  model — TP (attention heads / ffn columns / vocab / MoE hidden);
  pod   — pure DP across pods (params replicated; `dist.compression`
          owns the cross-pod gradient traffic).

The config's parallelism profile gates the rules: `use_tp=False` folds
the model axis into data parallelism (params fully replicated,
`data_axes` returns every mesh axis); `fsdp=False` drops the data-axis
entries. Every axis assignment passes the `_dim_ok` divisibility guard —
a dimension the axis does not divide is left unsharded rather than
padded here (padding is the model's job, see `transformer.Dims`).

Activation shardings use a *logical* vocabulary ("dp", "tp", None) via
`constrain(...)`, resolved against the (cfg, mesh) pushed by
`activation_context`. Outside a context `constrain` is an identity, so
model code is mesh-free by default and tests run unsharded.
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardingGuardError(ValueError):
    """A leaf that must be sharded (strict mode) failed the divisibility
    guard and would have been silently replicated on every device."""


# ---------------------------------------------------------------------------
# Axis helpers
# ---------------------------------------------------------------------------


def _axis_size(axis, mesh: Mesh) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_axis_size(a, mesh) for a in axis)
    return mesh.shape[axis]


def _dim_ok(dim: int, axis, mesh: Mesh) -> bool:
    """Can `dim` be sharded over `axis` (a name, tuple of names, or
    None) without padding?"""
    size = _axis_size(axis, mesh)
    return size <= 1 or dim % size == 0


def data_axes(cfg, mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch dimension. TP profiles reserve the
    'model' axis; pure-DP profiles (use_tp=False) fold it into DP."""
    if cfg.use_tp:
        return tuple(n for n in mesh.axis_names if n != "model")
    return tuple(mesh.axis_names)


def _fsdp_axis(cfg, mesh: Mesh) -> Optional[str]:
    if cfg.fsdp and "data" in mesh.axis_names:
        return "data"
    return None


def _tp_axis(cfg, mesh: Mesh) -> Optional[str]:
    if cfg.use_tp and "model" in mesh.axis_names:
        return "model"
    return None


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# in_features -> fsdp, out_features -> tp (column-parallel). The
# recurrent-family projections (rg-lru w_x/w_i/w_a, rwkv6
# w_r/w_k/w_v/w_g/cm_k/cm_r) are column-parallel too: the rg-lru
# recurrence is elementwise in the hidden dim and rwkv mixes per-head,
# so splitting their output columns over tp is exact — without these
# entries the whole recurrent stack replicates and sharded decode's
# per-device param bytes stop shrinking with the mesh.
_COL_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up",
                 "w_x", "w_i", "w_a", "w_r", "w_k", "w_v", "w_g",
                 "cm_k", "cm_r")
# in_features -> tp, out_features -> fsdp (row-parallel)
_ROW_PARALLEL = ("wo", "w_down", "w_out", "w_o", "cm_v")
# always replicated (norm scales/biases, linear biases, quant scales)
_REPLICATED_LEAVES = ("scale", "bias", "b", "meta")

_MOE_WEIGHTS = ("w_gate", "w_up", "w_down")


def _guarded(shape: Sequence[int], last_two: tuple, mesh: Mesh) -> P:
    """Spec for `shape`: `last_two` axes on the trailing two dims (guard
    applied per-dim), None on every leading (stack) dim."""
    nd = len(shape)
    entries: list[Any] = [None] * nd
    for off, axis in enumerate(last_two):
        i = nd - 2 + off
        if i < 0:
            continue
        if axis is not None and _dim_ok(shape[i], axis, mesh):
            entries[i] = axis
    return P(*entries)


def spec_for_path(path: str, shape: Sequence[int], cfg, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its
    '/'-joined tree path (e.g. "blocks/pos0/mix/wq/w")."""
    parts = [p for p in path.split("/") if p]
    leaf = parts[-1]
    fsdp = _fsdp_axis(cfg, mesh)
    tp = _tp_axis(cfg, mesh)

    if leaf in _REPLICATED_LEAVES or (parts and parts[-2:-1] == ["meta"]):
        return P()

    # raw-array leaves (MoE expert stacks) are named directly; linear
    # leaves are {"w"} dicts named by their parent module
    name = parts[-2] if leaf in ("w", "packed", "values_q", "select") \
        else leaf
    in_moe = "moe" in parts

    if in_moe and name in _MOE_WEIGHTS:
        if getattr(cfg, "moe_shard", "tp_fsdp") == "tp_only":
            fsdp = None  # experts replicated over data: no D-contraction
            #              all-reduce for small-expert models
        if name == "w_down":
            return _guarded(shape, (tp, fsdp), mesh)
        return _guarded(shape, (fsdp, tp), mesh)

    if name == "embed":
        # vocab rows on tp (embedding gather all-reduces over model),
        # d_model on fsdp
        return _guarded(shape, (tp, fsdp), mesh)
    if name == "lm_head":
        return _guarded(shape, (fsdp, tp), mesh)
    if name == "router":
        return _guarded(shape, (fsdp, None), mesh)
    if name in _COL_PARALLEL:
        return _guarded(shape, (fsdp, tp), mesh)
    if name in _ROW_PARALLEL:
        return _guarded(shape, (tp, fsdp), mesh)
    # unknown leaves (recurrent-block internals, pos_emb, compiled
    # serving formats): replicate, dim-for-dim
    return P(*([None] * len(shape)))


def _path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _tree_paths(tree: Any):
    """(path_str, leaf) pairs in tree order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(kp), leaf) for kp, leaf in flat]


def param_specs(shapes: Any, cfg, mesh: Mesh) -> Any:
    """PartitionSpec pytree mirroring a parameter (shape) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [
        spec_for_path(_path_str(kp), getattr(leaf, "shape", ()), cfg, mesh)
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_specs(tree: Any, cfg, mesh: Mesh, *, strict: bool = False) -> Any:
    """Shard the leading (batch) dim of every leaf over the data axes;
    everything else replicated. Leaves whose batch dim the combined
    data-axis size does not divide stay unsharded — or, under
    `strict=True`, raise `ShardingGuardError` instead of silently
    replicating (serving paths that size per-device memory from the
    sharded avals must never fall back to replication)."""
    axes = data_axes(cfg, mesh)
    n_data = _axis_size(axes, mesh)

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if not shape:
            if strict and n_data > 1:
                raise ShardingGuardError(
                    f"batch_specs(strict): scalar leaf has no batch dim "
                    f"to shard over data axes {axes} (size {n_data})"
                )
            return P()
        first = axes if _dim_ok(shape[0], axes, mesh) else None
        if strict and n_data > 1 and first is None:
            raise ShardingGuardError(
                f"batch_specs(strict): batch dim {shape[0]} of leaf "
                f"shape {tuple(shape)} not divisible by data axes "
                f"{axes} (size {n_data})"
            )
        return P(first, *([None] * (len(shape) - 1)))

    return jax.tree.map(one, tree)


_KV_LEAVES = ("k", "v", "k_scale", "v_scale", "cross_k", "cross_v")


def cache_batch_axis(path_parts: Sequence[str]) -> int:
    """Index of the pool-slot (batch) axis for one decode-cache leaf,
    identified by its tree path: stacked subtrees ("blocks", "dec")
    carry a leading layer-group axis before the batch axis. Shared by
    `cache_specs` (which shards that axis over data) and
    `serve.seating` (which scatters/gathers per-slot rows along it) so
    the two can never disagree about where a slot lives."""
    return 1 if path_parts and path_parts[0] in ("blocks", "dec") else 0


def cache_specs(cache: Any, cfg, mesh: Mesh, *, strict: bool = False) -> Any:
    """Decode-cache rules: batch dim over the data axes; KV-head dim of
    attention buffers over the model axis. Stacked subtrees ("blocks",
    "dec") carry a leading layer-group dim before the batch dim.

    `strict=True` raises `ShardingGuardError` for any leaf whose batch
    dim the combined data-axis size does not divide (instead of leaving
    it silently replicated) — the sharded decode path sizes per-device
    cache memory from these specs, and a replicated KV buffer would
    quietly multiply it by the device count. The KV-head/model-axis rule
    stays best-effort even under strict: a head count the model axis
    does not divide falls back to batch-only sharding, which is valid
    (just less memory-efficient along model)."""
    axes = data_axes(cfg, mesh)
    n_data = _axis_size(axes, mesh)
    tp = _tp_axis(cfg, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for kp, leaf in flat:
        parts = _path_str(kp).split("/")
        shape = getattr(leaf, "shape", ())
        b_idx = cache_batch_axis(parts)
        entries: list[Any] = [None] * len(shape)
        if len(shape) > b_idx:
            if _dim_ok(shape[b_idx], axes, mesh):
                entries[b_idx] = axes
            elif strict and n_data > 1:
                raise ShardingGuardError(
                    f"cache_specs(strict): leaf {'/'.join(parts)} shape "
                    f"{tuple(shape)} batch dim {shape[b_idx]} (index "
                    f"{b_idx}) not divisible by data axes {axes} "
                    f"(size {n_data})"
                )
        # leaves with no batch dim (rank <= b_idx, e.g. unbatched step
        # counters) replicate even under strict: they don't scale with
        # the pool, so replication is correct and accounting-honest
        h_idx = b_idx + 2  # (B, slots, heads, ...) layout
        if (
            parts[-1] in _KV_LEAVES
            and tp is not None
            and len(shape) > h_idx
            and _dim_ok(shape[h_idx], tp, mesh)
        ):
            entries[h_idx] = tp
        specs.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, specs)


def spec_shard_factor(spec: P, mesh: Mesh) -> int:
    """How many ways `spec` splits one array over `mesh` (product of the
    mesh-axis sizes it names); per-device bytes = nbytes / factor."""
    return math.prod(_axis_size(entry, mesh) for entry in spec)


def bytes_per_device(tree: Any, specs: Any, mesh: Mesh) -> int:
    """Per-device bytes of `tree` placed with `specs`, accounted from
    the sharded avals (no allocation): each leaf contributes
    nbytes / spec_shard_factor. `tree` may hold arrays or
    ShapeDtypeStructs; `specs` must mirror it leaf-for-leaf (the pytrees
    `param_specs`/`cache_specs`/`batch_specs` return)."""
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, P)
    )
    if len(leaves) != len(spec_leaves):
        raise ValueError(
            f"tree has {len(leaves)} leaves but specs {len(spec_leaves)}"
        )
    total = 0
    for leaf, spec in zip(leaves, spec_leaves):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None or dtype is None:
            continue
        nbytes = math.prod(shape) * jax.numpy.dtype(dtype).itemsize
        total += nbytes // spec_shard_factor(spec, mesh)
    return total


def named(specs: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree (None passes
    through, for jit in_shardings slots left to the compiler)."""
    def one(s):
        if s is None:
            return None
        return NamedSharding(mesh, s)

    return jax.tree.map(
        one, specs, is_leaf=lambda x: x is None or isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Logical activation constraints
# ---------------------------------------------------------------------------

_ACTIVATION_CTX: list[tuple[Any, Mesh]] = []


@contextlib.contextmanager
def activation_context(cfg, mesh: Mesh):
    """Makes `constrain` resolve logical axes against (cfg, mesh).
    Nestable; the innermost context wins."""
    _ACTIVATION_CTX.append((cfg, mesh))
    try:
        yield
    finally:
        _ACTIVATION_CTX.pop()


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint over logical axes ("dp", "tp", None),
    one per dim of x. A no-op outside `activation_context`, and any
    logical axis whose physical size does not divide the dim is
    dropped — model code never has to know the mesh."""
    if not _ACTIVATION_CTX:
        return x
    cfg, mesh = _ACTIVATION_CTX[-1]
    if len(logical) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical)} axes for rank-{x.ndim} array"
        )
    dp = data_axes(cfg, mesh)
    tp = _tp_axis(cfg, mesh)
    entries: list[Any] = []
    for dim, ax in zip(x.shape, logical):
        if ax == "dp":
            entries.append(dp if _dim_ok(dim, dp, mesh) else None)
        elif ax == "tp":
            entries.append(
                tp if tp is not None and _dim_ok(dim, tp, mesh) else None
            )
        elif ax is None:
            entries.append(None)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries))
    )
