"""Micro-batch gradient accumulation.

`accumulate_grads` splits the global batch into `n_steps` leading-dim
chunks and scans `grad_fn` over them, summing gradients in the params'
dtype (f32 masters) and averaging at the end. Because every loss in the
repo is a mean over batch elements, the mean of the micro-batch
gradients equals the full-batch gradient exactly (up to reduction-order
noise) — the invariant `tests/test_train.py` pins.

The scan keeps HLO size O(1) in `n_steps`, and under jit the per-chunk
activations are freed between iterations — peak activation memory drops
by ~n_steps while the wall-clock FLOPs stay identical. This is the
standard lever for fitting the train_4k cell on small meshes.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def accumulate_grads(
    grad_fn: Callable[[Any, Any], tuple[Any, dict]],
    params: Any,
    batch: Any,
    n_steps: int,
) -> tuple[Any, dict]:
    """Run `grad_fn(params, micro_batch) -> (grads, metrics)` over
    `n_steps` leading-dim chunks of `batch`; returns the mean gradients
    and the mean of each metric."""
    if n_steps is None or n_steps <= 1:
        return grad_fn(params, batch)

    def split(x):
        b = x.shape[0]
        if b % n_steps:
            raise ValueError(
                f"batch dim {b} not divisible by n_steps={n_steps}"
            )
        return x.reshape(n_steps, b // n_steps, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(g_acc, mb):
        g, metrics = grad_fn(params, mb)
        return jax.tree.map(jnp.add, g_acc, g), metrics

    g0 = jax.tree.map(jnp.zeros_like, params)
    g_sum, stacked = jax.lax.scan(body, g0, micro)
    grads = jax.tree.map(lambda g: g / n_steps, g_sum)
    metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), stacked)
    return grads, metrics
