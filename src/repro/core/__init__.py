"""Core: the paper's contribution as composable JAX modules.

- quant      : mixed-bit-width (8/4/2/1) quantization + bit-plane CMUL math
- sparsity   : co-design balanced pruning (select-index compressed format)
- spe        : sparse-quantized linear/conv operators (3 compute paths)
- vadetect   : the 8-layer 1-D FCN VA detector + 6-segment voting
- compiler   : trained model -> AcceleratorProgram (chip format + schedule)
- perf_model : analytic cycle/energy/power model of the 2x4x4x16 chip
"""

from repro.core import compiler, perf_model, quant, sparsity, spe, vadetect

__all__ = [
    "compiler",
    "perf_model",
    "quant",
    "sparsity",
    "spe",
    "vadetect",
]
