"""The paper's workload: an 8-layer 1-D fully-convolutional VA detector.

Input: one IEGM recording — 512 samples @ 250 Hz, band-pass filtered
15–55 Hz (`data/iegm.py`), single lead (RVA-Bi). Output: VA (VT/VF) vs
non-VA. A diagnosis aggregates 6 recordings by majority vote.

The paper specifies "an 8-layer, one-dimensional, fully convolutional
network … 50 % sparsity … 8-bit quantization" but not the per-layer dims;
we use a standard small FCN (≈31k params) consistent with the chip's
2×4×4×16 PE array (channel counts multiples of 16 where possible, first
input channel padded to N=4 exactly as the paper does for the 1-D demo).

Every conv layer is an SPE operator: balanced 16:8 pruning + 8-bit
quantization are applied *during training* (co-design QAT) via
`spe_train_weight`, and `core/compiler.py` freezes the result into the
chip's compressed format.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.spe import (
    SPEConfig,
    conv1d_apply,
    conv1d_init,
)

# (c_out, ksize, stride) for the 8 conv layers. The paper gives layer count
# (8), input length (512) and the compression recipe but not per-layer dims;
# this stack is sized so the chip model lands on the paper's operating point
# (~2.6 M dense MACs -> 150 GOPS effective at 35 us; see perf_model).
# First layer consumes the N=4-padded input channel (paper: "N is padded
# to 4"), last layer is the 1x1 classifier head (fully convolutional).
VA_LAYERS: tuple[tuple[int, int, int], ...] = (
    (16, 7, 2),  # 512 -> 256
    (24, 5, 2),  # 256 -> 128
    (32, 5, 1),  # 128 -> 128
    (48, 3, 2),  # 128 -> 64
    (64, 3, 1),  # 64  -> 64
    (64, 3, 2),  # 64  -> 32
    (96, 3, 2),  # 32  -> 16
    (2, 1, 1),   # 1x1 head -> logits per position
)

N_INPUT_PAD = 4  # paper: input channel count padded to N=4
RECORD_LEN = 512
VOTE_SEGMENTS = 6


@dataclasses.dataclass(frozen=True)
class VAConfig:
    layers: tuple[tuple[int, int, int], ...] = VA_LAYERS
    spe: Optional[SPEConfig] = SPEConfig(
        bits=8, group_size=16, keep=8, sparse=True, quantized=True
    )
    # Mixed-precision demo point: per-layer bit widths (None -> cfg.bits).
    layer_bits: Optional[tuple[int, ...]] = None

    def layer_spe(self, i: int) -> Optional[SPEConfig]:
        if self.spe is None:
            return None
        bits = self.spe.bits
        if self.layer_bits is not None:
            bits = self.layer_bits[i]
        # The 1x1 head contracts few channels; keep it dense 8-bit so
        # the classifier capacity is preserved (the chip runs it on MPEs).
        if i == len(self.layers) - 1:
            return SPEConfig(bits=8, sparse=False, quantized=True)
        return SPEConfig(
            bits=bits,
            group_size=self.spe.group_size,
            keep=self.spe.keep,
            sparse=self.spe.sparse,
            quantized=self.spe.quantized,
        )


def init(key: jax.Array, cfg: VAConfig = VAConfig()) -> dict:
    params = {}
    c_in = N_INPUT_PAD
    keys = jax.random.split(key, len(cfg.layers))
    for i, (c_out, ks, _) in enumerate(cfg.layers):
        params[f"conv{i}"] = conv1d_init(keys[i], c_in, c_out, ks)
        c_in = c_out
    return params


def apply(
    params: dict,
    x: jax.Array,
    cfg: VAConfig = VAConfig(),
    *,
    train: bool = True,
) -> jax.Array:
    """(B, 512) or (B, 512, 1) IEGM -> (B, 2) logits."""
    if x.ndim == 2:
        x = x[..., None]
    b, t, c = x.shape
    if c < N_INPUT_PAD:  # paper: zero-pad input channels to N=4
        x = jnp.pad(x, ((0, 0), (0, 0), (0, N_INPUT_PAD - c)))
    h = x
    n_layers = len(cfg.layers)
    for i, (c_out, ks, stride) in enumerate(cfg.layers):
        # SPE constraints apply in training (QAT/co-design) *and* eval, so
        # eval numerics match the compiled chip program exactly.
        h = conv1d_apply(params[f"conv{i}"], h, cfg.layer_spe(i), stride=stride)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    # fully-convolutional head: average logits over remaining positions
    return jnp.mean(h, axis=1)


def loss_fn(
    params: dict, batch: dict, cfg: VAConfig = VAConfig()
) -> tuple[jax.Array, dict]:
    logits = apply(params, batch["signal"], cfg)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return nll, {"loss": nll, "accuracy": acc}


def predict(params: dict, x: jax.Array, cfg: VAConfig = VAConfig()) -> jax.Array:
    """Per-segment class predictions (B,)."""
    return jnp.argmax(apply(params, x, cfg, train=False), axis=-1)


def vote(segment_preds: jax.Array) -> jax.Array:
    """Majority vote over VOTE_SEGMENTS segment predictions.

    segment_preds: (..., VOTE_SEGMENTS) int predictions (0=non-VA, 1=VA).
    Returns (...,) diagnosis. Ties break toward VA (clinically conservative:
    a missed VA is fatal; a false positive is a recoverable shock).
    """
    votes = jnp.sum(segment_preds, axis=-1)
    return (votes * 2 >= segment_preds.shape[-1]).astype(jnp.int32)


def diagnose(
    params: dict, recordings: jax.Array, cfg: VAConfig = VAConfig()
) -> jax.Array:
    """(B, VOTE_SEGMENTS, 512) -> (B,) diagnosis via 6-segment voting."""
    b, s, t = recordings.shape
    preds = predict(params, recordings.reshape(b * s, t), cfg)
    return vote(preds.reshape(b, s))


def param_count(params: dict) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def layer_shapes(cfg: VAConfig = VAConfig()) -> list[dict]:
    """Static per-layer workload description (for the compiler/perf model)."""
    out = []
    t = RECORD_LEN
    c_in = N_INPUT_PAD
    for i, (c_out, ks, stride) in enumerate(cfg.layers):
        t_out = (t - 1) // stride + 1
        spe = cfg.layer_spe(i)
        out.append(
            dict(
                name=f"conv{i}",
                c_in=c_in,
                c_out=c_out,
                ksize=ks,
                stride=stride,
                t_in=t,
                t_out=t_out,
                macs=t_out * c_out * ks * c_in,
                bits=spe.bits if spe else 32,
                sparse=bool(spe and spe.sparse),
                keep_frac=(spe.keep / spe.group_size)
                if (spe and spe.sparse)
                else 1.0,
            )
        )
        t, c_in = t_out, c_out
    return out
