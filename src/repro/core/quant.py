"""Mixed-bit-width quantization — the CMUL arithmetic, as math.

The paper's CMUL (configurable multiplier) supports 8/4/2/1-bit signed
multiplication by splitting the weight into 1-bit segments, multiplying each
segment against the (MUXed) input activation, and shift-accumulating the
partial products:

    w = -w_{B-1} 2^{B-1} + sum_{b<B-1} w_b 2^b          (two's complement)
    x*w = sum_b (+/- 2^b) * (x * w_b)

On TPU we adapt this as *bit-plane matmul*: each 1-bit weight plane W_b is a
{0,1} matrix, so

    X @ W = sum_b s_b 2^b (X @ W_b),   s_b = -1 for the sign plane else +1

and every plane product runs on the MXU at full systolic throughput. This
module provides:

  * symmetric per-channel quantization (quantize / dequantize),
  * straight-through-estimator fake-quant for QAT,
  * two's-complement bit-plane decomposition + packed uint8 storage
    (the storage format the Pallas kernels unpack in VMEM).

All functions are jit-safe and differentiable where meaningful.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

SUPPORTED_BITS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Per-tensor quantization configuration.

    Attributes:
      bits: bit width of the stored weights (1, 2, 4 or 8).
      per_channel: quantize with one scale per output channel (last dim)
        instead of one scale per tensor.
      narrow_range: clamp to [-(2^{b-1}-1), 2^{b-1}-1] (symmetric around 0)
        instead of the full two's-complement range. The chip uses symmetric
        signed arithmetic, so this defaults to True.
    """

    bits: int = 8
    per_channel: bool = True
    narrow_range: bool = True

    def __post_init__(self):
        if self.bits not in SUPPORTED_BITS:
            raise ValueError(
                f"bits must be one of {SUPPORTED_BITS}, got {self.bits}"
            )

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.bits > 1 else 1

    @property
    def qmin(self) -> int:
        if self.bits == 1:
            return -1
        if self.narrow_range:
            return -self.qmax
        return -(1 << (self.bits - 1))


def _scale_for(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Symmetric scale: max|w| maps to qmax. Shape () or (1,...,C)."""
    if cfg.per_channel and w.ndim >= 2:
        reduce_axes = tuple(range(w.ndim - 1))
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    # Guard fully-zero channels.
    amax = jnp.maximum(amax, jnp.finfo(w.dtype).tiny)
    return amax / cfg.qmax


def quantize(w: jax.Array, cfg: QuantConfig) -> tuple[jax.Array, jax.Array]:
    """Quantize to signed integers; returns (q int8, scale float32).

    1-bit is binary-connect style: sign(w) in {-1, +1} with scale mean|w|.
    """
    w = w.astype(jnp.float32)
    if cfg.bits == 1:
        if cfg.per_channel and w.ndim >= 2:
            reduce_axes = tuple(range(w.ndim - 1))
            scale = jnp.mean(jnp.abs(w), axis=reduce_axes, keepdims=True)
        else:
            scale = jnp.mean(jnp.abs(w))
        scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.where(w >= 0, 1, -1).astype(jnp.int8)
        return q, scale
    scale = _scale_for(w, cfg)
    q = jnp.clip(jnp.round(w / scale), cfg.qmin, cfg.qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(w: jax.Array, bits: int, per_channel: bool) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator (for QAT).

    bits/per_channel are static (nondiff_argnums) — jittable inside
    train steps.
    """
    cfg = QuantConfig(bits=bits, per_channel=per_channel)
    q, scale = quantize(w, cfg)
    return dequantize(q, scale).astype(w.dtype)


def _fake_quant_fwd(w, bits, per_channel):
    return fake_quant(w, bits, per_channel), None


def _fake_quant_bwd(bits, per_channel, _, g):
    # STE: identity gradient w.r.t. w.
    return (g,)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


# ---------------------------------------------------------------------------
# Bit-plane (CMUL) decomposition
# ---------------------------------------------------------------------------


def to_bitplanes(q: jax.Array, bits: int) -> jax.Array:
    """Two's-complement bit planes of a signed integer tensor.

    Returns uint8 array of shape (bits, *q.shape) with values in {0,1}.
    Plane b is the 2^b coefficient; the top plane is the sign plane and
    carries weight -2^{bits-1} when recomposing.
    """
    if bits == 1:
        # {-1,+1} stored as a single plane: 1 -> +1, 0 -> -1.
        return (q > 0).astype(jnp.uint8)[None]
    u = q.astype(jnp.int32) & ((1 << bits) - 1)  # two's complement bits
    shifts = jnp.arange(bits, dtype=jnp.int32)
    planes = (u[None] >> shifts.reshape((bits,) + (1,) * q.ndim)) & 1
    return planes.astype(jnp.uint8)


def from_bitplanes(planes: jax.Array, bits: int) -> jax.Array:
    """Inverse of `to_bitplanes` — recompose signed integers (int32)."""
    if bits == 1:
        return jnp.where(planes[0] > 0, 1, -1).astype(jnp.int32)
    weights = (2 ** jnp.arange(bits, dtype=jnp.int32)).at[bits - 1].multiply(-1)
    weights = weights.reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def bitserial_matmul_exact(
    x: jax.Array, q: jax.Array, bits: int
) -> jax.Array:
    """CMUL semantics as bit-plane matmuls: x @ q == sum_b s_b 2^b (x @ W_b).

    This is the mathematically-exact reference of the shift-accumulate the
    chip performs, expressed so every partial product is a dense (MXU-
    friendly) matmul. `x` float, `q` signed int (from `quantize`).
    """
    planes = to_bitplanes(q, bits)  # (bits, K, N)
    if bits == 1:
        # plane in {0,1} encodes {-1,+1}: w = 2*p - 1
        return 2.0 * (x @ planes[0].astype(x.dtype)) - jnp.sum(
            x, axis=-1, keepdims=True
        )
    acc = None
    for b in range(bits):
        coeff = -(2.0 ** (bits - 1)) if b == bits - 1 else 2.0**b
        partial = x @ planes[b].astype(x.dtype)
        acc = partial * coeff if acc is None else acc + partial * coeff
    return acc


# ---------------------------------------------------------------------------
# Packed storage (what lives in HBM; kernels unpack in VMEM)
# ---------------------------------------------------------------------------


def pack_planes(q: jax.Array, bits: int) -> jax.Array:
    """Pack a signed int8 weight tensor into uint8 words of bit-planes.

    Output shape: (ceil(bits*K/8), N) for 2-D input (K, N) — i.e. the packed
    rows hold the two's-complement planes of `bits` consecutive… — concretely
    we pack along K: each uint8 holds 8/bits consecutive K entries' values.
    """
    if q.ndim != 2:
        raise ValueError("pack_planes expects a 2-D (K, N) weight")
    k, n = q.shape
    vals_per_byte = 8 // bits
    pad = (-k) % vals_per_byte
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
    if bits == 1:
        # {-1,+1} -> {0,1} (matches to_bitplanes' 1-bit convention)
        u = (q > 0).astype(jnp.uint8)
    else:
        mask = (1 << bits) - 1
        u = (q.astype(jnp.int32) & mask).astype(jnp.uint8)
    u = u.reshape(-1, vals_per_byte, n)
    shifts = (jnp.arange(vals_per_byte, dtype=jnp.uint8) * bits).reshape(
        1, -1, 1
    )
    packed = jnp.sum(
        (u.astype(jnp.uint32) << shifts.astype(jnp.uint32)), axis=1
    ).astype(jnp.uint8)
    return packed


def unpack_planes(packed: jax.Array, bits: int, k: int) -> jax.Array:
    """Inverse of `pack_planes`: uint8 (K/vpb, N) -> signed int8 (K, N)."""
    vals_per_byte = 8 // bits
    mask = (1 << bits) - 1
    n = packed.shape[-1]
    shifts = (jnp.arange(vals_per_byte, dtype=jnp.uint32) * bits).reshape(
        1, -1, 1
    )
    u = (packed.astype(jnp.uint32)[:, None, :] >> shifts) & mask
    u = u.reshape(-1, n)[:k].astype(jnp.int32)
    if bits == 1:
        return jnp.where(u > 0, 1, -1).astype(jnp.int8)
    # sign-extend two's complement
    sign_bit = 1 << (bits - 1)
    return jnp.where(u >= sign_bit, u - (1 << bits), u).astype(jnp.int8)


def quantized_matmul(
    x: jax.Array,
    q: jax.Array,
    scale: jax.Array,
    bits: int,
    *,
    exact_bitserial: bool = False,
) -> jax.Array:
    """x @ dequantize(q): the inference matmul of a CMUL layer.

    With exact_bitserial=True, computes via the bit-plane decomposition
    (provably identical result; used to cross-check the kernel path).
    """
    if exact_bitserial:
        y = bitserial_matmul_exact(x.astype(jnp.float32), q, bits)
    else:
        y = x.astype(jnp.float32) @ q.astype(jnp.float32)
    scale2d = scale.reshape((1,) * (y.ndim - 1) + (-1,)) if scale.ndim else scale
    return (y * scale2d).astype(x.dtype)


def storage_bits(shape: tuple[int, ...], bits: int) -> int:
    """Number of bits needed to store a weight tensor at this precision."""
    n = 1
    for s in shape:
        n *= s
    return n * bits
