"""Co-design balanced pruning — the SPE's sparse weight format.

The chip's SPE reads *compressed* weights plus per-weight "select signals":
each PE multiplies a non-zero weight against the activation it selects from a
16-register window. For that to run with simple synchronous control (single
shared SPad, no FIFOs), the compiler must prune so every window holds exactly
the same number of non-zeros — *balanced* sparsity across and within PEs.

We reproduce that as G:2G balanced group pruning along the contraction (K)
dimension: within every group of `group_size` consecutive K entries of each
output channel, exactly `keep` survive. At the paper's operating point
group_size=16, keep=8 (50 % sparsity, 4-bit select signals).

Compressed format (what the Pallas kernel consumes):
  values : (K_kept, N) float or int8 — surviving weights, group-major order
  select : (K_kept, N) uint8          — position of each value inside its
                                        group (0..group_size-1)

Dense K index of compressed row r, channel n:
  k = (r // keep) * group_size + select[r, n]
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Balanced-group sparsity configuration (the paper: 16/8)."""

    group_size: int = 16
    keep: int = 8

    def __post_init__(self):
        if not 0 < self.keep <= self.group_size:
            raise ValueError(f"invalid keep={self.keep}/{self.group_size}")

    @property
    def sparsity(self) -> float:
        return 1.0 - self.keep / self.group_size

    @property
    def select_bits(self) -> int:
        return max(1, (self.group_size - 1).bit_length())


def _grouped(w: jax.Array, group_size: int) -> jax.Array:
    """(K, N) -> (K//G, G, N). K must divide; callers pad first."""
    k, n = w.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    return w.reshape(k // group_size, group_size, n)


def balanced_prune_mask(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Boolean keep-mask with exactly `keep` True per (group, channel).

    Keeps the top-|w| entries per group — the compiler's workload-balancing
    constraint: every PE window has identical non-zero count. A trailing
    partial group is zero-padded for ranking (the chip pads redundant units
    with zeros), then the mask is sliced back to K.
    """
    k = w.shape[0]
    pad = (-k) % cfg.group_size
    if pad:
        wp = jnp.pad(w, ((0, pad), (0, 0)))
        return balanced_prune_mask(wp, cfg)[:k]
    g = _grouped(jnp.abs(w), cfg.group_size)  # (Kg, G, N)
    # top-keep along the G axis
    order = jnp.argsort(-g, axis=1)  # descending |w|
    ranks = jnp.argsort(order, axis=1)  # rank of each position
    mask = ranks < cfg.keep
    return mask.reshape(w.shape)


def apply_prune(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Dense weights with the balanced mask applied (zeros at pruned slots)."""
    return jnp.where(balanced_prune_mask(w, cfg), w, 0).astype(w.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def prune_ste(w: jax.Array, group_size: int, keep: int) -> jax.Array:
    """Masked weights with straight-through gradients (for co-design QAT).

    group_size/keep are static (nondiff_argnums) so the op stays jittable
    inside train steps.
    """
    return apply_prune(w, SparsityConfig(group_size, keep))


def _prune_fwd(w, group_size, keep):
    return prune_ste(w, group_size, keep), None


def _prune_bwd(group_size, keep, _, g):
    return (g,)


prune_ste.defvjp(_prune_fwd, _prune_bwd)


# ---------------------------------------------------------------------------
# Compressed (values + select) format
# ---------------------------------------------------------------------------


def compress(
    w: jax.Array, cfg: SparsityConfig
) -> tuple[jax.Array, jax.Array]:
    """Dense (K, N) -> (values (K_kept, N), select uint8 (K_kept, N)).

    Select indices within each group are emitted in ascending dense order so
    the kernel's gathers are monotone within a window (friendlier to VMEM
    addressing, and matches the chip's register scan order).
    """
    k, n = w.shape
    g = _grouped(w, cfg.group_size)  # (Kg, G, N)
    absg = jnp.abs(g)
    order = jnp.argsort(-absg, axis=1)
    ranks = jnp.argsort(order, axis=1)
    keep_mask = ranks < cfg.keep  # (Kg, G, N)
    # Ascending dense position among kept entries:
    # sort positions by (not kept, position) and take the first `keep`.
    pos = jnp.arange(cfg.group_size)[None, :, None]
    sort_key = jnp.where(keep_mask, pos, cfg.group_size + pos)
    sel = jnp.argsort(sort_key, axis=1)[:, : cfg.keep, :]  # (Kg, keep, N)
    vals = jnp.take_along_axis(g, sel, axis=1)  # (Kg, keep, N)
    values = vals.reshape(-1, n)
    select = sel.reshape(-1, n).astype(jnp.uint8)
    return values, select


def decompress(
    values: jax.Array, select: jax.Array, cfg: SparsityConfig, k: int
) -> jax.Array:
    """(values, select) -> dense (K, N) with zeros at pruned positions."""
    kept, n = values.shape
    kg = k // cfg.group_size
    vals = values.reshape(kg, cfg.keep, n)
    sel = select.astype(jnp.int32).reshape(kg, cfg.keep, n)
    # scatter values into their in-group slots
    out = jnp.zeros((kg, cfg.group_size, n), values.dtype)
    gi = jnp.arange(kg)[:, None, None]
    ni = jnp.arange(n)[None, None, :]
    out = out.at[gi, sel, ni].set(vals)
    return out.reshape(k, n)


def sparse_matmul_ref(
    x: jax.Array,
    values: jax.Array,
    select: jax.Array,
    cfg: SparsityConfig,
) -> jax.Array:
    """Gather-MAC reference of the SPE: y[...,n] = sum_r v[r,n]*x[...,k(r,n)].

    This is the jnp oracle for the Pallas `nm_spmm` kernel. It materializes
    the gathered activations (..., K_kept, N) — fine as an oracle, which is
    exactly why the VMEM-tiled kernel exists for production.
    """
    kept, n = values.shape
    group_of_r = (jnp.arange(kept) // cfg.keep).astype(jnp.int32)
    dense_k = group_of_r[:, None] * cfg.group_size + select.astype(jnp.int32)
    x_g = x[..., dense_k]  # (..., K_kept, N)
    return jnp.sum(x_g * values.astype(x.dtype), axis=-2)


def verify_balance(mask: jax.Array, cfg: SparsityConfig) -> bool:
    """Compiler invariant: every (group, channel) has exactly `keep` nnz."""
    g = _grouped(mask.astype(jnp.int32), cfg.group_size)
    counts = g.sum(axis=1)
    return bool(jnp.all(counts == cfg.keep))


def sparsity_schedule(step: int | jax.Array, *, start: int, end: int,
                      final_keep: int, group_size: int) -> jax.Array:
    """Gradual pruning schedule: keep-count ramps G -> final_keep over
    [start, end) (cubic, à la Zhu & Gupta) so co-design training adapts."""
    t = jnp.clip((step - start) / max(1, end - start), 0.0, 1.0)
    frac = 1.0 - (1.0 - t) ** 3  # 0 -> 1
    keep = group_size - frac * (group_size - final_keep)
    return jnp.ceil(keep).astype(jnp.int32)
