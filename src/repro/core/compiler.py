"""The co-design compiler: trained model -> accelerator program.

The paper's full stack is UI → compiler → chip. The compiler's published
responsibilities:

  1. co-design pruning "to balance workloads and execution times across and
     within PEs"  → `sparsity.balanced_prune_mask` (verified balanced),
  2. mixed-precision quantization of weights/activations → `quant`,
  3. emitting the compressed weight stream + select signals the SPE array
     consumes, plus the static synchronous schedule (no FIFOs — every PE's
     work per cycle is known at compile time).

`compile_model` walks a trained parameter pytree, freezes every SPE layer
into `CompiledLayer` form, checks the balance invariant, and produces an
`AcceleratorProgram` with a static schedule + the perf-model report for the
target chip partition.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model, sparsity, vadetect
from repro.core.spe import CompiledLayer, SPEConfig, compile_layer


@dataclasses.dataclass
class AcceleratorProgram:
    """Everything the chip needs for inference on one network."""

    layers: dict[str, CompiledLayer]
    biases: dict[str, jax.Array]
    layer_meta: list[dict]  # static shapes/strides (the schedule skeleton)
    report: perf_model.ChipReport

    def weight_hbm_bytes(self) -> int:
        return sum(l.hbm_bytes() for l in self.layers.values())

    def dense_fp32_bytes(self) -> int:
        return sum(
            l.k_dense * l.values_q.shape[1] * 4 for l in self.layers.values()
        )

    def compression_ratio(self) -> float:
        return self.dense_fp32_bytes() / max(1, self.weight_hbm_bytes())


def compile_model(
    params: dict, cfg: vadetect.VAConfig = vadetect.VAConfig()
) -> AcceleratorProgram:
    """Freeze a trained VA-detector into the chip's program format."""
    meta = vadetect.layer_shapes(cfg)
    layers: dict[str, CompiledLayer] = {}
    biases: dict[str, jax.Array] = {}
    workloads = []
    for i, m in enumerate(meta):
        name = m["name"]
        spe = cfg.layer_spe(i)
        w = params[name]["w"]
        ks, c_in, c_out = w.shape
        w2 = np.asarray(w).reshape(ks * c_in, c_out)
        k_flat = w2.shape[0]
        lcfg = spe if spe is not None else SPEConfig(sparse=False, quantized=False)
        # pad contraction dim to a whole number of groups (the chip pads
        # redundant units with zeros — same trick)
        if lcfg.sparse:
            pad = (-k_flat) % lcfg.group_size
            if pad:
                w2 = np.pad(w2, ((0, pad), (0, 0)))
        compiled = compile_layer(jnp.asarray(w2), lcfg)
        # verify the compiler invariant that makes synchronous execution work
        if lcfg.sparse:
            mask = sparsity.balanced_prune_mask(
                jnp.asarray(w2), lcfg.sparsity_cfg
            )
            assert sparsity.verify_balance(mask, lcfg.sparsity_cfg), name
        layers[name] = compiled
        biases[name] = params[name]["b"]
        workloads.append(
            perf_model.LayerWorkload(
                name=name,
                c_in=m["c_in"],
                c_out=m["c_out"],
                ksize=m["ksize"],
                t_out=m["t_out"],
                macs=m["macs"],
                bits=m["bits"],
                keep_frac=m["keep_frac"],
                sparse=m["sparse"],
            )
        )
    report = perf_model.chip_report(workloads)
    return AcceleratorProgram(
        layers=layers, biases=biases, layer_meta=meta, report=report
    )


def execute(
    program: AcceleratorProgram,
    x: jax.Array,
    cfg: vadetect.VAConfig = vadetect.VAConfig(),
    *,
    path: str = "reference",
) -> jax.Array:
    """Run the compiled program (software twin of the chip's execution).

    Uses the im2col-as-matmul dataflow the SPE array implements; `path`
    selects reference (gather oracle) or kernel (Pallas) execution for the
    sparse layers. Returns (B, 2) logits.
    """
    from repro.core.spe import spe_matmul

    if x.ndim == 2:
        x = x[..., None]
    b, t, c = x.shape
    if c < vadetect.N_INPUT_PAD:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, vadetect.N_INPUT_PAD - c)))
    h = x
    n_layers = len(cfg.layers)
    for i, m in enumerate(program.layer_meta):
        name = m["name"]
        layer = program.layers[name]
        ks, stride = m["ksize"], m["stride"]
        # im2col patches == the chip's SPad streaming order.
        # XLA SAME semantics: total pad so t_out = ceil(t/stride).
        t_in = h.shape[1]
        t_out = (t_in - 1) // stride + 1
        pad_total = max((t_out - 1) * stride + ks - t_in, 0)
        pad_l = pad_total // 2
        pad_r = pad_total - pad_l
        xp = jnp.pad(h, ((0, 0), (pad_l, pad_r), (0, 0)))
        starts = jnp.arange(t_out) * stride
        patches = jax.vmap(
            lambda s, xp=xp, ks=ks: jax.lax.dynamic_slice_in_dim(
                xp, s, ks, axis=1
            ),
            out_axes=1,
        )(starts)  # (B, T_out, ks, C_in)
        flat = patches.reshape(b, t_out, ks * h.shape[2])
        k_dense = layer.k_dense
        if flat.shape[-1] < k_dense:  # compiler padded K to group multiple
            flat = jnp.pad(
                flat, ((0, 0), (0, 0), (0, k_dense - flat.shape[-1]))
            )
        y = spe_matmul(flat, layer, path=path) + program.biases[name]
        h = jax.nn.relu(y) if i < n_layers - 1 else y
    return jnp.mean(h, axis=1)
