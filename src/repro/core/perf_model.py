"""Analytic cycle/energy/power model of the fabricated chip.

We obviously cannot re-measure the TSMC 40 nm silicon; what we *can* do —
and what this module does — is model the published architecture faithfully
enough that the paper's own measured numbers (150 GOPS, 35 µs/inference,
10.60 µW average power, 0.57 µW/mm²) fall out of the model at the paper's
operating point, and then use the same model to predict the other operating
points the chip supports (4/2/1-bit layers, dense vs sparse) for the
ablation benchmarks.

Architecture constants (all from the paper):
  * 4-D array N×W×H×M = 2×4×4×16 = 512 PEs; 12 PE + 4 MPE per SPE.
  * 1-D demo engages 1 of 4 computing cores with N padded to 4 → 128 PEs.
  * 400 MHz @ 1.14 V, TSMC 40 nm LP; die 18.63 mm².
  * 50 % balanced sparsity → each PE skips zeros → 2× effective MACs.

Calibrated constants (fit so the model reproduces the measured silicon —
documented as calibration, not measurement):
  * E_MAC_8B: energy of one 8-bit sparse MAC incl. local data movement.
  * P_LEAK: leakage + always-on (SPad, control, clock tree).
  * CMUL energy scales ≈ linearly with weight bit width (bit-serial planes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# Chip constants (published)
# ---------------------------------------------------------------------------
FREQ_HZ = 400e6
VOLTAGE = 1.14
DIE_AREA_MM2 = 18.63
ARRAY_N, ARRAY_W, ARRAY_H, ARRAY_M = 2, 4, 4, 16
TOTAL_PES = ARRAY_N * ARRAY_W * ARRAY_H * ARRAY_M  # 512
DEMO_CORES = 1  # of ARRAY_W computing cores engaged in the 1-D demo
DEMO_N_PAD = 4  # input channels padded to 4
DEMO_PES = 128  # paper: "only 128 PEs are engaged"

# ---------------------------------------------------------------------------
# Calibrated constants (fit so the model lands on Table 1's measured row;
# documented as calibration, not measurement)
# ---------------------------------------------------------------------------
# One IEGM recording spans 512 samples @ 250 Hz = 2.048 s; the chip is
# duty-cycled: one 35 us inference per recording window. The paper's
# "10.60 uW average power" is the monitoring average over that window.
RECORD_PERIOD_S = 512 / 250.0
E_MAC_8B_J = 0.2e-12  # J per executed 8-bit MAC incl. SPad movement
P_IDLE_W = 10.48e-6  # retention + always-on front-end + leakage
N_PAR = ARRAY_N  # input channels consumed per cycle per core (N=2)
TILE_OVERHEAD_CYC = 11  # SPad window (re)load + bias + act + writeback


@dataclasses.dataclass(frozen=True)
class LayerWorkload:
    """Static description of one conv/linear layer's work."""

    name: str
    c_in: int
    c_out: int
    ksize: int
    t_out: int
    macs: int  # dense MAC count
    bits: int = 8
    keep_frac: float = 0.5  # kept fraction under balanced pruning
    sparse: bool = True


@dataclasses.dataclass
class LayerReport:
    name: str
    cycles: int
    dense_macs: int
    executed_macs: int
    utilization: float  # executed MACs / (cycles * engaged PEs)


@dataclasses.dataclass
class ChipReport:
    layers: list[LayerReport]
    total_cycles: int
    latency_s: float
    effective_gops: float  # dense-equivalent ops/s (the paper's metric)
    executed_gops: float  # physically-executed ops/s
    energy_j: float
    avg_power_w: float
    power_density_uw_mm2: float
    pe_utilization: float

    def summary(self) -> dict:
        return {
            "latency_us": self.latency_s * 1e6,
            "effective_GOPS": self.effective_gops,
            "executed_GOPS": self.executed_gops,
            "avg_power_uW": self.avg_power_w * 1e6,
            "power_density_uW_mm2": self.power_density_uw_mm2,
            "pe_utilization": self.pe_utilization,
            "total_cycles": self.total_cycles,
        }


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def layer_cycles(
    wl: LayerWorkload, *, engaged_pes: int = DEMO_PES, n_par: int = N_PAR
) -> LayerReport:
    """Blocked-loop cycle model of the SPE array on one layer.

    Dataflow (paper Fig. 1/2): the array computes a W×H×M block of outputs
    in parallel; input channels stream N(=2)-at-a-time through the shared
    SPad; each PE performs one (non-skipped) MAC per cycle; with balanced
    sparsity, pruned weights are skipped for free (that is the point of
    the balanced constraint: all PEs skip in lockstep). Bit width < 8 does
    not change the cycle count on this chip (the CMUL is spatially
    bit-parallel); it changes energy. Each output tile additionally pays
    TILE_OVERHEAD_CYC for the SPad window (re)load, bias, activation and
    writeback — the calibrated constant that lands the model on the
    paper's measured 35 us (see EXPERIMENTS.md §Paper).
    """
    m_tiles = _ceil_div(wl.c_out, ARRAY_M)
    pos_tiles = _ceil_div(wl.t_out, ARRAY_H)
    cin_steps = _ceil_div(wl.c_in, n_par)
    kept = wl.keep_frac if wl.sparse else 1.0
    # kept fraction of the k x c_in contraction survives; balanced pruning
    # guarantees the per-group count is exact, so the cycle count is exact.
    contraction_cycles = max(1, math.ceil(wl.ksize * cin_steps * kept))
    cycles = m_tiles * pos_tiles * (contraction_cycles + TILE_OVERHEAD_CYC)
    executed = int(wl.macs * kept)
    util = executed / max(1, cycles * engaged_pes)
    return LayerReport(
        name=wl.name,
        cycles=int(cycles),
        dense_macs=wl.macs,
        executed_macs=executed,
        utilization=min(1.0, util),
    )


def chip_report(
    layers: Sequence[LayerWorkload],
    *,
    engaged_pes: int = DEMO_PES,
    freq_hz: float = FREQ_HZ,
) -> ChipReport:
    reports = [layer_cycles(wl, engaged_pes=engaged_pes) for wl in layers]
    total_cycles = sum(r.cycles for r in reports)
    latency = total_cycles / freq_hz
    dense_ops = 2 * sum(r.dense_macs for r in reports)  # MAC = 2 ops
    executed_ops = 2 * sum(r.executed_macs for r in reports)
    # energy: per executed MAC, scaled by bit width (bit-serial CMUL
    # planes); the monitoring average duty-cycles one inference per
    # 2.048 s recording window on top of the idle/retention floor.
    energy = 0.0
    for wl, r in zip(layers, reports):
        e_mac = E_MAC_8B_J * (wl.bits / 8.0)
        energy += r.executed_macs * e_mac
    avg_power = P_IDLE_W + energy / RECORD_PERIOD_S
    return ChipReport(
        layers=reports,
        total_cycles=total_cycles,
        latency_s=latency,
        effective_gops=dense_ops / latency / 1e9,
        executed_gops=executed_ops / latency / 1e9,
        energy_j=energy,
        avg_power_w=avg_power,
        power_density_uw_mm2=avg_power * 1e6 / DIE_AREA_MM2,
        pe_utilization=sum(r.executed_macs for r in reports)
        / max(1, total_cycles * engaged_pes),
    )


# Paper Table-1 reference row (measured silicon) for benchmark comparison.
PAPER_MEASURED = {
    "latency_us": 35.0,
    "effective_GOPS": 150.0,
    "avg_power_uW": 10.60,
    "power_density_uW_mm2": 0.57,
    "inference_accuracy": 0.9235,
    "diagnostic_accuracy": 0.9995,
    "precision": 0.9988,
    "recall": 0.9984,
}

PRIOR_WORKS = {
    "TBCAS'19 [4]": {"tech_nm": 180, "power_uW": 13.34, "density": 14.50},
    "ICICM'22 [5]": {"tech_nm": 180, "power_uW": 11.76, "density": 8.11},
    "MWSCAS'22 [3]": {"tech_nm": 40, "power_uW": 5.10, "density": 9.44},
    "ISCAS'24 [2]": {"tech_nm": 40, "power_uW": 12.19, "density": None},
}
