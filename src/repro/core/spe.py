"""SPE — sparse-quantized linear operators, composable in any model here.

These are the software twins of the chip's Sparse Processing Elements: a
linear / 1-D conv operator whose weights are (a) balanced-group pruned
(`core.sparsity`) and (b) mixed-bit-width quantized (`core.quant`), with an
execution path that mirrors the hardware dataflow:

    HBM:  packed bit-planes of compressed weights + 4-bit select signals
    VMEM: one activation K-tile (the shared SPad) + unpacked weight tile
    MXU:  half-size matmul per bit plane, shift-accumulated

Three interchangeable compute paths (all numerically identical):
  * ``dense``     — dequantized dense matmul (XLA; used for dry-run/backprop)
  * ``reference`` — gather + bit-serial jnp (oracle semantics)
  * ``kernel``    — Pallas `nm_spmm` / `bitserial_matmul` (TPU target;
                    interpret-mode on CPU)

Training uses fake-quant + prune-STE on the dense master weights (QAT /
co-design pruning); `core.compiler` freezes a trained layer into the
compressed inference format.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core import sparsity as S

ComputePath = Literal["dense", "reference", "kernel"]


@dataclasses.dataclass(frozen=True)
class SPEConfig:
    """Joint sparsity × quantization operating point of one layer."""

    bits: int = 8
    group_size: int = 16
    keep: int = 8
    sparse: bool = True
    quantized: bool = True
    path: ComputePath = "dense"

    @property
    def sparsity_cfg(self) -> S.SparsityConfig:
        return S.SparsityConfig(self.group_size, self.keep)

    @property
    def quant_cfg(self) -> Q.QuantConfig:
        return Q.QuantConfig(bits=self.bits)


def spe_train_weight(w: jax.Array, cfg: SPEConfig) -> jax.Array:
    """QAT/co-design view of a weight: prune-STE then fake-quant (both
    straight-through). This is what the *training* forward pass uses, so the
    network learns under the exact inference constraints — the paper's
    'co-design pruning' + 'hardware-aware quantization'."""
    if cfg.sparse:
        w = S.prune_ste(w, cfg.group_size, cfg.keep)
    if cfg.quantized:
        w = Q.fake_quant(w, cfg.bits, True)
    return w


@dataclasses.dataclass
class CompiledLayer:
    """Frozen inference-format of one SPE layer (what the chip stores)."""

    values_q: jax.Array  # (K_kept, N) int8 — compressed, quantized
    select: jax.Array  # (K_kept, N) uint8 — in-group select signals
    scale: jax.Array  # (1, N) f32 per-channel scale
    packed_planes: jax.Array  # (K_kept*bits/8, N) uint8 — HBM storage
    bits: int
    group_size: int
    keep: int
    k_dense: int
    sparse: bool = True

    def hbm_bytes(self) -> int:
        sel_bits = max(1, (self.group_size - 1).bit_length())
        return (
            self.packed_planes.size
            + (self.select.size * sel_bits + 7) // 8
            + self.scale.size * 4
        )


def compile_layer(w: jax.Array, cfg: SPEConfig) -> CompiledLayer:
    """Dense trained weight -> compressed/quantized inference format."""
    k, n = w.shape
    scfg = cfg.sparsity_cfg
    if cfg.sparse:
        w = S.apply_prune(w, scfg)
        values, select = S.compress(w, scfg)
    else:
        values, select = w, jnp.zeros((k, n), jnp.uint8)
    q, scale = Q.quantize(values, cfg.quant_cfg)
    packed = Q.pack_planes(q, cfg.bits)
    return CompiledLayer(
        values_q=q,
        select=select,
        scale=scale.reshape(1, -1),
        packed_planes=packed,
        bits=cfg.bits,
        group_size=cfg.group_size,
        keep=cfg.keep,
        k_dense=k,
        sparse=cfg.sparse,
    )


def spe_matmul(
    x: jax.Array, layer: CompiledLayer, *, path: ComputePath = "reference"
) -> jax.Array:
    """y = x @ W_sparse_quant — inference execution of one SPE layer."""
    scfg = S.SparsityConfig(layer.group_size, layer.keep)
    if not layer.sparse:
        # dense (uncompressed) storage: plain dequant matmul on all paths
        y = x.astype(jnp.float32) @ layer.values_q.astype(jnp.float32)
        return (y * layer.scale).astype(x.dtype)
    if path == "dense":
        dense_q = S.decompress(
            layer.values_q.astype(jnp.float32), layer.select, scfg,
            layer.k_dense,
        )
        return (x.astype(jnp.float32) @ dense_q * layer.scale).astype(x.dtype)
    if path == "reference":
        values = layer.values_q.astype(jnp.float32)
        y = S.sparse_matmul_ref(x.astype(jnp.float32), values, layer.select,
                                scfg)
        return (y * layer.scale).astype(x.dtype)
    if path == "kernel":
        from repro.kernels import ops as kops  # lazy: pallas import

        return kops.nm_spmm(
            x, layer.values_q, layer.select, layer.scale,
            group_size=layer.group_size, keep=layer.keep,
        ).astype(x.dtype)
    raise ValueError(f"unknown path {path!r}")


# ---------------------------------------------------------------------------
# Layer modules (init/apply pairs, pure pytrees)
# ---------------------------------------------------------------------------


def linear_init(key: jax.Array, k: int, n: int, dtype=jnp.float32) -> dict:
    scale = (2.0 / (k + n)) ** 0.5
    return {
        "w": jax.random.normal(key, (k, n), dtype) * scale,
        "b": jnp.zeros((n,), dtype),
    }


def linear_apply(params: dict, x: jax.Array, cfg: Optional[SPEConfig]) -> jax.Array:
    w = params["w"]
    if cfg is not None:
        w = spe_train_weight(w, cfg)
    return x @ w + params["b"]


def conv1d_init(
    key: jax.Array, c_in: int, c_out: int, ksize: int, dtype=jnp.float32
) -> dict:
    fan = c_in * ksize
    return {
        "w": jax.random.normal(key, (ksize, c_in, c_out), dtype)
        * (2.0 / fan) ** 0.5,
        "b": jnp.zeros((c_out,), dtype),
    }


def conv1d_apply(
    params: dict,
    x: jax.Array,
    cfg: Optional[SPEConfig],
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """1-D convolution (B, T, C_in) -> (B, T', C_out).

    The SPE treats a KxC_in conv window as a flattened contraction dim, so
    prune/quant apply to the flattened (ksize*c_in, c_out) weight — matching
    how the chip streams ifmap data channel-major through the SPad.
    """
    w, b = params["w"], params["b"]
    ks, c_in, c_out = w.shape
    if cfg is not None:
        w2 = spe_train_weight(w.reshape(ks * c_in, c_out), cfg)
        w = w2.reshape(ks, c_in, c_out)
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride,),
        padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
    )
    return y + b


def conv1d_as_matmul(
    params: dict, x: jax.Array, *, stride: int = 1
) -> jax.Array:
    """im2col view of conv1d — the form the chip (and our kernel) executes.

    SAME padding. Returns identical values to `conv1d_apply` (fp32).
    """
    w, b = params["w"], params["b"]
    ks, c_in, c_out = w.shape
    bsz, t, _ = x.shape
    # XLA SAME semantics: total pad so t_out = ceil(t/stride), left-biased
    t_out = (t - 1) // stride + 1
    pad_total = max((t_out - 1) * stride + ks - t, 0)
    pad_l = pad_total // 2
    pad_r = pad_total - pad_l
    xp = jnp.pad(x, ((0, 0), (pad_l, pad_r), (0, 0)))
    starts = jnp.arange(t_out) * stride
    patches = jax.vmap(
        lambda s: jax.lax.dynamic_slice_in_dim(xp, s, ks, axis=1),
        out_axes=1,
    )(starts)  # (B, T_out, ks, C_in)
    patches = patches.reshape(bsz, t_out, ks * c_in)
    y = patches @ w.reshape(ks * c_in, c_out) + b
    return y
