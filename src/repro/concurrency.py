"""Thread-affinity markers.

`Engine` / `MicroBatchScheduler` are single-threaded by contract:
under the serving frontend, exactly one driver thread may touch them,
and async (event-loop) code must go through the frontend's inbox
instead. That convention was previously enforced only by comment;
`@driver_thread_only` makes it machine-checkable — the
`driver-thread-affinity` rule in `repro.analysis` flags any call to a
marked method from inside an `async def`.

The decorator is a pure marker (returns `fn` unchanged, zero runtime
cost on the hot tick/submit path); the contract is enforced
statically, not dynamically.
"""

from __future__ import annotations


def driver_thread_only(fn):
    """Mark `fn` as callable only from the owning driver thread (or
    whatever single thread owns the object outside a frontend)."""
    fn.__driver_thread_only__ = True
    return fn
