"""Per-slot decode-cache seating: scatter/gather pool rows as pytrees.

Batched prefill admission produces a cache whose batch dimension holds
the *admitted* requests (a handful of rows); the engine's persistent
pool cache holds `batch_size` slots. Seating is the move between the
two: `scatter_slots` writes admitted rows into their destination slots,
`gather_slots` reads slot rows back out (migration, debugging, tests).

Both are pure jittable pytree functions. The slot axis of each leaf is
derived from its tree path via `dist.sharding.cache_batch_axis` — the
same rule `cache_specs` uses to shard that axis over the mesh data
axes — so seating and placement can never disagree about where a slot
lives. Writes go through `jax.lax.dynamic_update_slice` (one update per
seated row, traced start indices): a single compiled cell serves every
(row, slot) assignment of a given shape, XLA updates donated pool
buffers in place, and under jit with explicit in/out shardings
(`ShardedEngine._admission_cell`) the pool never leaves its mesh
placement — seating is O(seated rows), not O(pool).

Engines compile these with `jax.jit(..., donate_argnums=0)`; the module
-level functions stay undonated so tests can reuse their inputs.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd


def _leaf_paths(tree: Any) -> list[tuple[list[str], Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(shd._path_str(kp).split("/"), leaf) for kp, leaf in flat]


def slot_axes(tree: Any) -> list[int]:
    """Slot-axis index for every leaf of a cache pytree, in flatten
    order (parallel to `jax.tree.leaves(tree)`)."""
    return [shd.cache_batch_axis(parts) for parts, _ in _leaf_paths(tree)]


def scatter_slots(
    pool: Any, rows: Any, src: jax.Array, dst: jax.Array
) -> Any:
    """Seat `rows` into `pool`: for every j, slot row `src[j]` of each
    `rows` leaf overwrites slot row `dst[j]` of the matching `pool`
    leaf (along that leaf's slot axis). Every other slot — and every
    non-slot dimension — is untouched, so seating one request can never
    disturb a co-seated tenant.

    `rows` must mirror `pool`'s tree structure with the same per-leaf
    shapes except the slot axis (typically the admitted-batch size);
    `src`/`dst` are (K,) int32 index arrays (K static, values traced).
    Returns the updated pool pytree.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(pool)
    row_leaves = jax.tree.leaves(rows)
    if len(flat) != len(row_leaves):
        raise ValueError(
            f"pool has {len(flat)} leaves but rows {len(row_leaves)} — "
            f"seating needs structurally matching cache pytrees"
        )
    out = []
    for (kp, pl), rl in zip(flat, row_leaves):
        ax = shd.cache_batch_axis(shd._path_str(kp).split("/"))
        for j in range(src.shape[0]):
            sl = jax.lax.dynamic_slice_in_dim(rl, src[j], 1, axis=ax)
            start = [0] * pl.ndim
            start[ax] = dst[j]
            pl = jax.lax.dynamic_update_slice(
                pl, sl.astype(pl.dtype), tuple(start)
            )
        out.append(pl)
    return jax.tree_util.tree_unflatten(treedef, out)


# Leaf names that become (n_pages, page, ...) pools under paged layouts
# (subset of dist.sharding._KV_LEAVES; slot_pos stays per-slot dense).
PAGED_LEAVES = ("k", "v", "k_scale", "v_scale")


def _leaf_layout(parts: list[str], layouts: dict) -> Any:
    """(pages_per_slot, page) for paged pool leaves, else None."""
    if parts[-1] not in PAGED_LEAVES:
        return None
    return layouts.get("/".join(parts[:-1]))


def scatter_pages(
    pool: Any, rows: Any, src: jax.Array, dst: jax.Array,
    phys: jax.Array, *, layouts: dict,
) -> Any:
    """Page-granular seating: the paged twin of `scatter_slots`.

    `rows` is a *dense* admission cache (what batched prefill or the
    chunked-prefill cell produces: slot-axis leaves of capacity `cap`);
    `pool` is the engine's paged pool. Dense leaves (slot_pos, recurrent
    state) seat exactly as `scatter_slots`. Paged K/V leaves are split
    along the capacity axis into `pages_per_slot` logical pages and each
    page is written to its physical page `phys[j, lp]` in the pool
    (`phys` is the (K, span) slot->page indirection rows of the seated
    slots; entries beyond a request's allocated pages point at the
    shard's scratch page, so over-writing them is harmless by
    construction — scratch is never read unmasked).

    `layouts` comes from `model.page_layouts(page)`: attn cache path
    prefix -> (pages_per_slot, page). One compiled cell per admitted
    width, same as dense seating; engines jit with donate_argnums=0.

    Paged leaves move as ONE gather + ONE scatter per leaf (all K*span
    pages at once), not a page-at-a-time update loop: under explicit
    mesh shardings the SPMD partitioner handles a single batched
    scatter well, while O(K*span) chained dynamic updates make compile
    time explode. Entries of `phys` that alias (several slots' unmapped
    tails all point at scratch) scatter in unspecified order — harmless
    by the scratch contract above.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(pool)
    row_leaves = jax.tree.leaves(rows)
    if len(flat) != len(row_leaves):
        raise ValueError(
            f"pool has {len(flat)} leaves but rows {len(row_leaves)} — "
            f"seating needs structurally matching cache pytrees"
        )
    out = []
    for (kp, pl), rl in zip(flat, row_leaves):
        parts = shd._path_str(kp).split("/")
        lay = _leaf_layout(parts, layouts)
        ax = shd.cache_batch_axis(parts)
        if lay is None:
            for j in range(src.shape[0]):
                sl = jax.lax.dynamic_slice_in_dim(rl, src[j], 1, axis=ax)
                start = [0] * pl.ndim
                start[ax] = dst[j]
                pl = jax.lax.dynamic_update_slice(
                    pl, sl.astype(pl.dtype), tuple(start)
                )
        else:
            maxp, page = lay
            # pool leaf: physical-page axis at `ax` (nP); rows leaf:
            # slot axis at `ax`, capacity axis right after it.
            rm = jnp.moveaxis(rl, (ax, ax + 1), (0, 1))  # (slots, cap, ..)
            sel = jnp.take(rm, src, axis=0)  # (K, cap, ..)
            sel = sel.reshape((src.shape[0] * maxp, page) + sel.shape[2:])
            pm = jnp.moveaxis(pl, (ax, ax + 1), (0, 1))  # (nP, page, ..)
            pm = pm.at[phys.reshape(-1)].set(sel.astype(pl.dtype))
            pl = jnp.moveaxis(pm, (0, 1), (ax, ax + 1))
        out.append(pl)
    return jax.tree_util.tree_unflatten(treedef, out)


def gather_pages(
    pool: Any, slots: jax.Array, phys: jax.Array, *, layouts: dict
) -> Any:
    """Inverse of `scatter_pages`: materialize dense cache rows for
    `slots[0..K-1]` from the paged pool — paged K/V leaves gather their
    mapped physical pages back into capacity order, dense leaves gather
    slot rows (exactly `gather_slots`). Used by migration/tests to
    compare a paged slot against its dense twin."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(pool)
    out = []
    for kp, pl in flat:
        parts = shd._path_str(kp).split("/")
        lay = _leaf_layout(parts, layouts)
        ax = shd.cache_batch_axis(parts)
        if lay is None:
            picks = [
                jax.lax.dynamic_slice_in_dim(pl, slots[j], 1, axis=ax)
                for j in range(slots.shape[0])
            ]
            out.append(jnp.concatenate(picks, axis=ax))
        else:
            maxp, page = lay
            pm = jnp.moveaxis(pl, (ax, ax + 1), (0, 1))  # (nP, page, ..)
            sel = jnp.take(pm, phys.reshape(-1), axis=0)  # (K*maxp, page, ..)
            sel = sel.reshape((slots.shape[0], maxp * page) + sel.shape[2:])
            out.append(jnp.moveaxis(sel, (0, 1), (ax, ax + 1)))
    return jax.tree_util.tree_unflatten(treedef, out)


def gather_slots(pool: Any, slots: jax.Array) -> Any:
    """Read slot rows back out: returns a pytree mirroring `pool` whose
    slot axis holds `pool`'s rows `slots[0..K-1]`, in order — the exact
    inverse of `scatter_slots(pool, rows, arange(K), slots)`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(pool)
    out = []
    for kp, pl in flat:
        ax = shd.cache_batch_axis(shd._path_str(kp).split("/"))
        picks = [
            jax.lax.dynamic_slice_in_dim(pl, slots[j], 1, axis=ax)
            for j in range(slots.shape[0])
        ]
        out.append(jnp.concatenate(picks, axis=ax))
    return jax.tree_util.tree_unflatten(treedef, out)
