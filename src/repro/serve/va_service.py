"""The paper's deployment: VA diagnosis service (6-segment voting).

Since the `repro.stream` subsystem landed, this module is the thin
single-patient/small-clinic facade over it: segment classification goes
through `stream.runner.FleetRunner` (the same fixed-shape bucketed
classifier the fleet scheduler feeds), and 6-segment aggregation through
`core.vadetect.vote`. Latency accounting uses the chip perf model, so
the service reports the same numbers the silicon measurement section
does. For many patients with continuous telemetry, use `repro.stream`
directly (`stream.simulate` / `launch/stream.py`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import compiler, vadetect
from repro.core.perf_model import ChipReport
from repro.stream.runner import FleetRunner


@dataclasses.dataclass
class Diagnosis:
    patient: int
    is_va: bool
    segment_preds: list[int]
    chip_latency_us: float


def _bucket_for(n: int) -> int:
    """Smallest power-of-two batch shape >= n: the facade's bucket
    ladder, so repeat calls with the same patient count never retrace."""
    b = 1
    while b < n:
        b *= 2
    return b


class VAService:
    """Batched VA diagnosis over compiled accelerator programs."""

    def __init__(
        self,
        program: compiler.AcceleratorProgram,
        cfg: vadetect.VAConfig = vadetect.VAConfig(),
        *,
        path: str = "reference",
    ):
        self.program = program
        self.cfg = cfg
        self.path = path
        self._runner = FleetRunner(program, cfg, path=path)

    @property
    def report(self) -> ChipReport:
        return self.program.report

    def diagnose_batch(self, recordings: jax.Array) -> list[Diagnosis]:
        """recordings (P, 6, 512) -> one Diagnosis per patient."""
        p, s, t = recordings.shape
        assert s == vadetect.VOTE_SEGMENTS, s
        flat = recordings.reshape(p * s, t)
        bucket = _bucket_for(p * s)
        if bucket > p * s:
            flat = jnp.pad(flat, ((0, bucket - p * s), (0, 0)))
        preds = self._runner.classify(flat)[: p * s].reshape(p, s)
        votes = vadetect.vote(preds)
        lat = self.report.latency_s * 1e6 * s  # 6 inferences per diagnosis
        return [
            Diagnosis(
                patient=i,
                is_va=bool(votes[i]),
                segment_preds=[int(x) for x in preds[i]],
                chip_latency_us=lat,
            )
            for i in range(p)
        ]
