"""The paper's deployment: VA diagnosis service (6-segment voting).

Mirrors the demo pipeline: IEGM recordings stream in, each 512-sample
segment is classified by the compiled accelerator program (software twin
of the chip), and every 6 segments are aggregated by majority vote into a
diagnosis. Latency accounting uses the chip perf model, so the service
reports the same numbers the silicon measurement section does.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compiler, vadetect
from repro.core.perf_model import ChipReport


@dataclasses.dataclass
class Diagnosis:
    patient: int
    is_va: bool
    segment_preds: list[int]
    chip_latency_us: float


class VAService:
    """Batched VA diagnosis over compiled accelerator programs."""

    def __init__(
        self,
        program: compiler.AcceleratorProgram,
        cfg: vadetect.VAConfig = vadetect.VAConfig(),
        *,
        path: str = "reference",
    ):
        self.program = program
        self.cfg = cfg
        self.path = path
        self._infer = jax.jit(
            lambda x: jnp.argmax(
                compiler.execute(program, x, cfg, path=path), axis=-1
            )
        )

    @property
    def report(self) -> ChipReport:
        return self.program.report

    def diagnose_batch(self, recordings: jax.Array) -> list[Diagnosis]:
        """recordings (P, 6, 512) -> one Diagnosis per patient."""
        p, s, t = recordings.shape
        assert s == vadetect.VOTE_SEGMENTS, s
        preds = self._infer(recordings.reshape(p * s, t)).reshape(p, s)
        votes = vadetect.vote(preds)
        lat = self.report.latency_s * 1e6 * s  # 6 inferences per diagnosis
        return [
            Diagnosis(
                patient=i,
                is_va=bool(votes[i]),
                segment_preds=[int(x) for x in preds[i]],
                chip_latency_us=lat,
            )
            for i in range(p)
        ]
