"""Async serving frontend: one transport in front of both engines.

Everything before this module drives requests from inside the process;
this is the boundary where they arrive from outside. One asyncio
frontend feeds

  * LM decode requests into the slot engine (`serve.engine.Engine` /
    `serve.sharded.ShardedEngine`), and
  * patient segment arrivals into the stream fleet's micro-batch
    scheduler (`stream.scheduler.MicroBatchScheduler`),

over two interchangeable transports: length-prefixed JSON frames on a
TCP socket (`SocketClient`), and an in-process client (`InProcClient`)
that enters the exact same message handler — tests and the load lab
drive both paths through one code path and can price the socket hop.

Wire format — every frame is a 4-byte big-endian length followed by a
UTF-8 JSON object:

  client -> server
    {"type": "lm", "uid": int, "prompt": [int...],
     "max_new": int, "eos": int|null}
    {"type": "segment", "patient": int, "seq": int,
     "deadline_rel_s": float, "urgent": bool}
    {"type": "drain"}
  server -> client
    {"type": "lm_result", "uid": int, "status": "completed",
     "tokens": [int...]}
    {"type": "lm_result", "uid": int, "status": "rejected",
     "reason": "admission_rate"|"queue_full"|"invalid"
               |"pages_exhausted",
     "detail": str}
    {"type": "segment_ack", "patient": int, "seq": int,
     "status": "enqueued"|"deferred", "urgent": bool}
    {"type": "drained", "stats": {...}}

Threading: the engines are NOT thread-safe, so the frontend owns the
only thread that touches them — a single driver thread that drains an
ingress inbox, submits/ticks the LM engine, and flushes the stream
scheduler on its size/time triggers. The asyncio event loop owns the
sockets and the admission decision; replies cross back via
`loop.call_soon_threadsafe`. Segment *content* is never shipped: like
`fleet.simulate`, signal content is derived from (patient, seq) by the
deterministic iegm synthesizer, so a segment frame is metadata only.

Backpressure and admission — every ingress decision is explicit, never
a silent drop:

  * LM requests pass a token bucket at `admission_rate_rps` (wire it
    to the load lab's measured saturation knee) with
    `admission_burst` depth, then a bounded pending-set
    (`lm_queue_limit`). Exceeding either sheds the request with a
    typed `rejected` reply (reason `admission_rate` / `queue_full`);
    engine-level validation failures (empty prompt, max_new <= 0,
    duplicate uid) come back as reason `invalid`. Every accepted
    request terminates in exactly one `completed` XOR `rejected`
    reply: submitted == completed + rejected, always.
  * stream ROUTINE segments pass their own bucket
    (`stream_rate_rps`); over-rate routine traffic is *deferred* —
    acked `deferred`, parked in an unbounded deferral queue, and
    released into the scheduler as the bucket refills (or immediately
    at drain). Deferral is a delay, never a drop.
  * stream URGENT segments always pass, at any load: they bypass the
    bucket entirely and additionally mark their patient urgent so the
    scheduler packs them ahead of every routine segment.

Lineage: request ids are minted CLIENT-side (`serve:{uid}` /
`stream:{patient}:{seq}`) and carried across the wire; the frontend
tags `frontend/ingress` and `frontend/reply` instants with them, so
`obs.lineage.assert_joined` spans the transport hop: a served LM
request joins frontend/ingress -> serve/submit -> serve/admit
(prefill/seat) -> serve/decode -> serve/finish -> frontend/reply.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import queue
import struct
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro import obs
from repro.obs.lineage import serve_rid, stream_rid

_HEADER = struct.Struct(">I")

STATUS_COMPLETED = "completed"
STATUS_REJECTED = "rejected"
REASON_ADMISSION = "admission_rate"
REASON_QUEUE_FULL = "queue_full"
REASON_INVALID = "invalid"
REASON_PAGES = "pages_exhausted"


def encode_frame(msg: dict, *, max_frame_bytes: int = 1 << 20) -> bytes:
    """One wire frame: 4-byte big-endian length + UTF-8 JSON body."""
    body = json.dumps(msg, separators=(",", ":")).encode()
    if len(body) > max_frame_bytes:
        raise ValueError(
            f"frame of {len(body)} bytes exceeds the "
            f"{max_frame_bytes}-byte limit"
        )
    return _HEADER.pack(len(body)) + body


async def read_frame(
    reader: asyncio.StreamReader, *, max_frame_bytes: int = 1 << 20
) -> Optional[dict]:
    """Read one frame; None on clean EOF at a frame boundary."""
    try:
        hdr = await reader.readexactly(_HEADER.size)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (length,) = _HEADER.unpack(hdr)
    if length > max_frame_bytes:
        raise ValueError(
            f"incoming frame of {length} bytes exceeds the "
            f"{max_frame_bytes}-byte limit"
        )
    body = await reader.readexactly(length)
    return json.loads(body.decode())


class TokenBucket:
    """Deterministic admission control: `rate` tokens/s refill up to a
    depth of `burst`; each admitted request spends one token. With
    arrivals spaced >= 1/rate apart the bucket never rejects; a burst
    of n back-to-back arrivals admits exactly
    min(n, floor(available)) — a property the shedding tests lean on,
    which is why this is a token bucket and not a noisy sliding-window
    rate estimate."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst < 1:
            raise ValueError(
                f"need rate > 0 and burst >= 1, got {rate}/{burst}"
            )
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t = clock()

    def try_take(self) -> bool:
        now = self._clock()
        self._tokens = min(
            self.burst, self._tokens + (now - self._t) * self.rate
        )
        self._t = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Knobs for one frontend instance. `admission_rate_rps` is the LM
    shed rate — wire it to the load lab's knee (`sweep_serve`'s
    `knee_rate`); None disables shedding. `stream_rate_rps` bounds
    ROUTINE segment admission the same way; URGENT traffic ignores it.
    """

    # LM ingress
    lm_queue_limit: int = 256
    admission_rate_rps: Optional[float] = None
    admission_burst: float = 8.0
    # stream ingress
    stream_rate_rps: Optional[float] = None
    stream_burst: float = 8.0
    stream_buckets: tuple = (4, 8)
    stream_max_wait_s: float = 0.05
    seg_deadline_rel_s: float = 0.5
    # loop cadences
    idle_poll_s: float = 0.001
    deferral_poll_s: float = 0.002
    max_frame_bytes: int = 1 << 20


class Frontend:
    """The transport + admission layer. Construct with an `Engine` (or
    `ShardedEngine`), a stream side (`n_patients` > 0, optionally a
    `FleetRunner` for real classify/vote on flush), or both; then
    `await start()` — with a host, it also listens on a TCP socket.
    The frontend owns the single driver thread that touches the
    engines; never call `engine.tick()` elsewhere while it runs."""

    def __init__(self, *, engine=None, n_patients: int = 0, runner=None,
                 cfg: FrontendConfig = FrontendConfig(),
                 clock: Callable[[], float] = time.monotonic):
        if engine is None and n_patients <= 0:
            raise ValueError("frontend needs an engine, a stream side "
                             "(n_patients > 0), or both")
        self.engine = engine
        self.cfg = cfg
        self._clock = clock
        self._lm_bucket = (
            TokenBucket(cfg.admission_rate_rps, cfg.admission_burst,
                        clock)
            if cfg.admission_rate_rps is not None else None
        )
        self._seg_bucket = (
            TokenBucket(cfg.stream_rate_rps, cfg.stream_burst, clock)
            if cfg.stream_rate_rps is not None else None
        )
        self._sched = None
        self._runner = runner
        self._vstate = None
        self._source = None
        self.n_patients = n_patients
        if n_patients > 0:
            from repro.stream.scheduler import (
                MicroBatchScheduler, SchedulerConfig,
            )

            self._sched = MicroBatchScheduler(
                SchedulerConfig(
                    buckets=tuple(sorted(cfg.stream_buckets)),
                    deadline_s=cfg.seg_deadline_rel_s,
                    max_wait_s=cfg.stream_max_wait_s,
                ),
                n_patients,
            )
            if runner is not None:
                from repro.stream import vote
                from repro.stream.sources import (
                    FleetSource, SourceConfig,
                )

                self._vstate = vote.init(n_patients)
                # content is derived from (patient, seq) — all-normal
                # keeps vote-driven urgency out of the client-marked
                # priority the shedding tests assert on
                self._source = FleetSource(
                    SourceConfig(n_patients=n_patients, seed=0,
                                 va_fraction=0.0)
                )
        # client-marked urgency (sticky per patient); OR-ed with the
        # vote layer's bitmap after every flush
        self._client_urgent = np.zeros(max(n_patients, 1), bool)
        # terminal-reply callbacks for accepted LM requests, keyed by
        # uid — membership doubles as the bounded ingress queue
        self._pending_lm: dict[int, Callable[[dict], None]] = {}
        self._deferred: list[tuple] = []  # parked ROUTINE segments
        self._inbox: queue.SimpleQueue = queue.SimpleQueue()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._driver: Optional[threading.Thread] = None
        self._driver_err: Optional[BaseException] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._stopping = False
        self._epoch = 0.0
        # split counters: `_c_loop` is touched only on the event-loop
        # thread, `_c_drv` only on the driver thread — `stats()` merges
        self._c_loop: dict[str, int] = {}
        self._c_drv: dict[str, int] = {}

    # -- lifecycle ----------------------------------------------------------

    def warm(self, prompt_len: int = 6) -> None:
        """Compile every cell a load point can hit BEFORE the clock
        starts: the engine's admission widths + pool decode
        (`loadlab.warm_engine`) and the stream side's per-bucket
        signal-synth / classify / vote cells. Without this the first
        flush compiles inside the driver thread mid-run, stalling LM
        ticks for seconds and fabricating a latency tail. Call before
        `start()` — it touches the engines from the calling thread."""
        if self._driver is not None:
            raise RuntimeError("warm() must run before start(): the "
                               "driver thread owns the engines once "
                               "it is up")
        if self.engine is not None:
            from repro.obs.loadlab import warm_engine

            warm_engine(self.engine, prompt_len)
        if self._runner is not None:
            import jax.numpy as jnp

            from repro.stream import vote

            for b in sorted(set(self.cfg.stream_buckets)):
                sigs = self._source.signals(
                    np.zeros(b, np.int32), np.zeros(b, np.int32)
                )
                preds = self._runner.classify(sigs["signal"])
                # all-invalid batch: scatters drop, state is unchanged
                _st, _e, _d, urgent = vote.update(
                    self._vstate,
                    jnp.zeros((b,), jnp.int32),
                    preds,
                    jnp.zeros((b,), bool),
                )
                urgent.block_until_ready()

    async def start(self, host: Optional[str] = "127.0.0.1",
                    port: int = 0):
        """Start the driver thread (+ TCP server when `host` is not
        None). Returns the bound (host, port) or None for in-process
        only."""
        self._loop = asyncio.get_running_loop()
        self._epoch = self._clock()
        self._stopping = False
        self._driver = threading.Thread(
            target=self._drive, name="frontend-driver", daemon=True
        )
        self._driver.start()
        self._pump_task = self._loop.create_task(self._deferral_pump())
        if host is None:
            return None
        self._server = await asyncio.start_server(
            self._on_connection, host, port
        )
        return self._server.sockets[0].getsockname()[:2]

    async def stop(self) -> None:
        self._stopping = True
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
            self._pump_task = None
        if self._driver is not None:
            self._inbox.put(("stop",))
            await asyncio.get_running_loop().run_in_executor(
                None, self._driver.join
            )
            self._driver = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if self._driver_err is not None:
            raise RuntimeError(
                "frontend driver thread died"
            ) from self._driver_err

    def stats(self) -> dict:
        out = dict(self._c_loop)
        out.update(self._c_drv)
        if self._sched is not None:
            out["sched_enqueued_total"] = self._sched.enqueued_total
            out["sched_packed_total"] = self._sched.packed_total
            out["sched_ready"] = self._sched.ready()
        out["deferred_pending"] = len(self._deferred)
        out["lm_pending"] = len(self._pending_lm)
        return out

    # -- transport ----------------------------------------------------------

    async def _on_connection(self, reader, writer) -> None:
        def reply(payload: dict, _w=writer) -> None:
            # event-loop thread only; frames are small and the protocol
            # bounds in-flight replies by lm_queue_limit, so buffered
            # writes cannot grow without bound
            try:
                _w.write(encode_frame(
                    payload, max_frame_bytes=self.cfg.max_frame_bytes
                ))
            except (ConnectionResetError, RuntimeError):
                pass  # client went away; terminal accounting stands

        try:
            while True:
                msg = await read_frame(
                    reader, max_frame_bytes=self.cfg.max_frame_bytes
                )
                if msg is None:
                    break
                self.handle_message(msg, reply, transport="socket")
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    # -- message handling (event-loop thread) -------------------------------

    def handle_message(self, msg: dict,
                       reply: Callable[[dict], None],
                       transport: str = "inproc") -> None:
        """Single entry point for both transports."""
        kind = msg.get("type")
        if kind == "lm":
            self._handle_lm(msg, reply, transport)
        elif kind == "segment":
            self._handle_segment(msg, reply, transport)
        elif kind == "drain":
            self._handle_drain(reply)
        else:
            reply({"type": "error",
                   "detail": f"unknown message type {kind!r}"})

    def _bump(self, key: str, n: int = 1) -> None:
        self._c_loop[key] = self._c_loop.get(key, 0) + n

    def _finish_lm(self, uid, rid: str, reply, payload: dict) -> None:
        """The one terminal-reply point for LM requests: every accepted
        or shed request passes through here exactly once."""
        tel = obs.get()
        if tel.enabled:
            tel.tracer.instant(
                "frontend/reply", cat="frontend", request_id=rid,
                status=payload["status"],
                reason=payload.get("reason"),
            )
        if payload["status"] == STATUS_COMPLETED:
            self._bump("lm_completed")
        else:
            self._bump("lm_rejected")
            self._bump(f"lm_rejected_{payload['reason']}")
        if tel.enabled:
            # disabled registry drops counts anyway — guarding skips
            # the f-string format on the disabled path
            tel.registry.counter(
                f"frontend.lm_{payload['status']}_total"
            ).inc()
        reply({"type": "lm_result", "uid": uid, **payload})

    def _handle_lm(self, msg, reply, transport) -> None:
        tel = obs.get()
        self._bump("lm_received")
        uid = msg.get("uid")
        try:
            uid = int(uid)
            prompt = [int(t) for t in msg["prompt"]]
            max_new = int(msg.get("max_new", 16))
            eos = msg.get("eos")
            eos = None if eos is None else int(eos)
        except (KeyError, TypeError, ValueError) as e:
            self._finish_lm(uid, serve_rid(uid), reply, {
                "status": STATUS_REJECTED, "reason": REASON_INVALID,
                "detail": f"malformed lm request: {e}",
            })
            return
        rid = serve_rid(uid)
        if tel.enabled:
            tel.tracer.instant(
                "frontend/ingress", cat="frontend", request_id=rid,
                transport=transport, kind="lm",
                prompt_len=len(prompt),
            )
        if self.engine is None:
            self._finish_lm(uid, rid, reply, {
                "status": STATUS_REJECTED, "reason": REASON_INVALID,
                "detail": "this frontend serves no LM engine",
            })
            return
        if uid in self._pending_lm:
            self._finish_lm(uid, rid, reply, {
                "status": STATUS_REJECTED, "reason": REASON_INVALID,
                "detail": f"uid {uid} already pending on this frontend",
            })
            return
        # active admission control: shed, with an explicit typed
        # rejection, the moment offered load exceeds the configured
        # saturation rate — a shed request costs the engine nothing
        if self._lm_bucket is not None and not self._lm_bucket.try_take():
            self._finish_lm(uid, rid, reply, {
                "status": STATUS_REJECTED, "reason": REASON_ADMISSION,
                "detail": (
                    f"offered load exceeds the admission rate "
                    f"({self.cfg.admission_rate_rps:.3g} req/s, burst "
                    f"{self.cfg.admission_burst:.3g}); retry later"
                ),
            })
            return
        if len(self._pending_lm) >= self.cfg.lm_queue_limit:
            self._finish_lm(uid, rid, reply, {
                "status": STATUS_REJECTED, "reason": REASON_QUEUE_FULL,
                "detail": (
                    f"{self.cfg.lm_queue_limit} requests already "
                    f"pending (bounded ingress queue)"
                ),
            })
            return
        self._pending_lm[uid] = reply
        self._bump("lm_admitted")
        self._inbox.put(("lm", uid, prompt, max_new, eos))

    def _handle_segment(self, msg, reply, transport) -> None:
        tel = obs.get()
        self._bump("seg_received")
        try:
            patient = int(msg["patient"])
            seq = int(msg["seq"])
            deadline_rel = float(
                msg.get("deadline_rel_s", self.cfg.seg_deadline_rel_s)
            )
            urgent = bool(msg.get("urgent", False))
            if self._sched is None:
                raise ValueError("this frontend serves no stream fleet")
            if not 0 <= patient < self.n_patients:
                raise ValueError(
                    f"patient {patient} outside fleet of "
                    f"{self.n_patients}"
                )
        except (KeyError, TypeError, ValueError) as e:
            reply({"type": "segment_ack",
                   "patient": msg.get("patient"),
                   "seq": msg.get("seq"),
                   "status": STATUS_REJECTED,
                   "reason": REASON_INVALID, "detail": str(e)})
            self._bump("seg_rejected_invalid")
            return
        rid = stream_rid(patient, seq)
        if tel.enabled:
            tel.tracer.instant(
                "frontend/ingress", cat="frontend", request_id=rid,
                transport=transport, kind="segment", urgent=urgent,
            )
        ack = {"type": "segment_ack", "patient": patient, "seq": seq,
               "urgent": urgent}
        if urgent:
            # URGENT always passes — no bucket, no deferral — and
            # pins its patient's priority class
            self._bump("seg_urgent")
            self._client_urgent[patient] = True
            self._inbox.put(("segment", patient, seq, deadline_rel,
                             True))
            ack["status"] = "enqueued"
        elif (self._seg_bucket is None
              or self._seg_bucket.try_take()):
            self._inbox.put(("segment", patient, seq, deadline_rel,
                             False))
            self._bump("seg_enqueued")
            ack["status"] = "enqueued"
        else:
            # over-rate ROUTINE traffic is deferred, never dropped:
            # parked here and released as the bucket refills (or
            # immediately at drain)
            self._deferred.append((patient, seq, deadline_rel))
            self._bump("seg_deferred")
            ack["status"] = "deferred"
        if tel.enabled:
            # named ack, not reply: the ack precedes the segment's
            # stream hops in wall time, so it must not look like an
            # exit hop to `lineage.critical_path`
            tel.tracer.instant(
                "frontend/ack", cat="frontend", request_id=rid,
                status=ack["status"],
            )
        if tel.enabled:
            # as above: skip the f-string on the disabled path
            tel.registry.counter(
                f"frontend.seg_{ack['status']}_total"
            ).inc()
        reply(ack)

    def _handle_drain(self, reply) -> None:
        self._release_deferred(force=True)

        def resolve() -> None:
            reply({"type": "drained", "stats": self.stats()})

        self._inbox.put(("drain", resolve))

    def _release_deferred(self, *, force: bool) -> None:
        released = 0
        while self._deferred and (
            force or self._seg_bucket is None
            or self._seg_bucket.try_take()
        ):
            patient, seq, deadline_rel = self._deferred.pop(0)
            self._inbox.put(("segment", patient, seq, deadline_rel,
                             False))
            released += 1
        if released:
            self._bump("seg_deferred_released", released)

    async def _deferral_pump(self) -> None:
        while not self._stopping:
            if self._deferred:
                self._release_deferred(force=False)
            await asyncio.sleep(self.cfg.deferral_poll_s)

    # -- driver thread: the ONLY thread that touches the engines ------------

    def _post(self, cb: Callable, *args) -> None:
        self._loop.call_soon_threadsafe(cb, *args)

    def _resolve_lm(self, uid: int, payload: dict) -> None:
        # event-loop thread (posted from the driver)
        reply = self._pending_lm.pop(uid, None)
        if reply is not None:
            self._finish_lm(uid, serve_rid(uid), reply, payload)

    def _now(self) -> float:
        return self._clock() - self._epoch

    def _drive(self) -> None:
        try:
            self._drive_inner()
        except BaseException as e:  # surfaced by stop()
            self._driver_err = e

    def _drive_inner(self) -> None:
        import jax.numpy as jnp

        from repro.serve.engine import Request
        from repro.serve.paging import PagesExhaustedError

        inflight: dict[int, Any] = {}
        drains: list[Callable] = []
        while True:
            progressed = False
            drained_inbox_dry = True
            while True:
                try:
                    item = self._inbox.get_nowait()
                except queue.Empty:
                    break
                progressed = True
                kind = item[0]
                if kind == "stop":
                    return
                if kind == "lm":
                    _, uid, prompt, max_new, eos = item
                    try:
                        req = Request(
                            uid=uid,
                            prompt=jnp.asarray(prompt, jnp.int32),
                            max_new=max_new, eos=eos,
                        )
                        self.engine.submit(req)
                    except PagesExhaustedError as e:
                        # never satisfiable on this page pool: the
                        # worst-case page need exceeds a whole shard's
                        # usable pages, so queueing could only stall —
                        # typed rejection clients can size down from
                        self._post(self._resolve_lm, uid, {
                            "status": STATUS_REJECTED,
                            "reason": REASON_PAGES,
                            "detail": str(e),
                        })
                    except Exception as e:
                        # engine-boundary validation (empty prompt,
                        # max_new <= 0, duplicate in-flight uid) comes
                        # back as an explicit typed rejection
                        self._post(self._resolve_lm, uid, {
                            "status": STATUS_REJECTED,
                            "reason": REASON_INVALID,
                            "detail": str(e),
                        })
                    else:
                        inflight[uid] = req
                elif kind == "segment":
                    self._enqueue_segment(*item[1:])
                elif kind == "drain":
                    drains.append(item[1])
                    drained_inbox_dry = False
            if self.engine is not None and (
                inflight or self.engine._queue
            ):
                self.engine.tick()
                done = [u for u, r in inflight.items() if r.done]
                for uid in done:
                    req = inflight.pop(uid)
                    self._post(self._resolve_lm, uid, {
                        "status": STATUS_COMPLETED,
                        "tokens": [int(t) for t in req.output],
                    })
                progressed = True
            if self._sched is not None and self._sched.ready():
                if drains or self._sched.should_flush(self._now()):
                    self._flush_stream()
                    progressed = True
            if drains and drained_inbox_dry and not inflight and (
                self.engine is None or not self.engine._queue
            ) and (self._sched is None or not self._sched.ready()):
                for resolve in drains:
                    self._post(resolve)
                drains = []
            if not progressed:
                time.sleep(self.cfg.idle_poll_s)

    def _enqueue_segment(self, patient, seq, deadline_rel,
                         urgent) -> None:
        from repro.stream.sources import SegmentRef

        now = self._now()
        if urgent:
            self._sched.mark_urgent([patient])
        self._sched.enqueue(SegmentRef(
            patient=patient, seq=seq, arrival_s=now,
            deadline_s=now + deadline_rel,
        ))

    def _flush_stream(self) -> None:
        import jax.numpy as jnp

        tel = obs.get()
        now = self._now()
        batch = self._sched.next_batch(now)
        if batch is None:
            return
        self._c_drv["seg_flushed"] = (
            self._c_drv.get("seg_flushed", 0) + batch.n_valid
        )
        self._c_drv["batches"] = self._c_drv.get("batches", 0) + 1
        if self._runner is None:
            return
        from repro.stream import vote

        tagged = (
            {"request_ids": batch.request_ids}
            if batch.request_ids is not None else {}
        )
        with tel.span("stream/flush", cat="stream",
                      bucket=batch.bucket, n_valid=batch.n_valid,
                      **tagged):
            sigs = self._source.signals(batch.patients, batch.seqs)
            with tel.span("stream/classify", cat="stream",
                          bucket=batch.bucket, **tagged):
                preds = self._runner.classify(sigs["signal"])
                tel.block(preds)
            with tel.span("stream/vote", cat="stream", **tagged):
                self._vstate, _emit, _diag, urgent = vote.update(
                    self._vstate,
                    jnp.asarray(batch.patients),
                    preds,
                    jnp.asarray(batch.valid),
                )
                tel.block(urgent)
        # vote-driven urgency never un-marks a client-pinned patient
        # (dtype pinned: an empty vote result must stay a bool mask,
        # never decay to float64 — the mark_urgent([]) class)
        self._sched.set_urgent(
            np.asarray(urgent, bool) | self._client_urgent
        )


# ---------------------------------------------------------------------------
# clients
# ---------------------------------------------------------------------------


class InProcClient:
    """Same handler, no socket: what the property tests and the
    in-process leg of the transport-delta benchmark drive. Futures
    resolve with the reply payload, stamped with `_t_recv`."""

    def __init__(self, frontend: Frontend):
        self._fe = frontend

    def _future_reply(self):
        loop = asyncio.get_running_loop()
        fut = loop.create_future()

        def reply(payload: dict) -> None:
            if not fut.done():
                payload = dict(payload)
                payload["_t_recv"] = time.perf_counter()
                fut.set_result(payload)

        return fut, reply

    async def send_lm(self, uid: int, prompt, max_new: int = 16,
                      eos=None) -> asyncio.Future:
        fut, reply = self._future_reply()
        self._fe.handle_message(
            {"type": "lm", "uid": uid, "prompt": list(prompt),
             "max_new": max_new, "eos": eos},
            reply, transport="inproc",
        )
        return fut

    async def send_segment(self, patient: int, seq: int, *,
                           deadline_rel_s: Optional[float] = None,
                           urgent: bool = False) -> asyncio.Future:
        fut, reply = self._future_reply()
        msg = {"type": "segment", "patient": patient, "seq": seq,
               "urgent": urgent}
        if deadline_rel_s is not None:
            msg["deadline_rel_s"] = deadline_rel_s
        self._fe.handle_message(msg, reply, transport="inproc")
        return fut

    async def drain(self, timeout: float = 120.0) -> dict:
        fut, reply = self._future_reply()
        self._fe.handle_message({"type": "drain"}, reply)
        return await asyncio.wait_for(fut, timeout)

    async def close(self) -> None:
        pass


class SocketClient:
    """Length-prefixed JSON over TCP; request ids are minted here, on
    the client, and the server carries them through every hop."""

    def __init__(self, reader, writer):
        self._reader = reader
        self._writer = writer
        self._lm: dict[int, asyncio.Future] = {}
        self._seg: dict[tuple, asyncio.Future] = {}
        self._drains: list[asyncio.Future] = []
        self._task = asyncio.get_running_loop().create_task(
            self._read_loop()
        )

    @classmethod
    async def connect(cls, host: str, port: int) -> "SocketClient":
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    async def _read_loop(self) -> None:
        try:
            while True:
                msg = await read_frame(self._reader)
                if msg is None:
                    break
                msg["_t_recv"] = time.perf_counter()
                kind = msg.get("type")
                fut = None
                if kind == "lm_result":
                    fut = self._lm.pop(msg.get("uid"), None)
                elif kind == "segment_ack":
                    fut = self._seg.pop(
                        (msg.get("patient"), msg.get("seq")), None
                    )
                elif kind == "drained" and self._drains:
                    fut = self._drains.pop(0)
                if fut is not None and not fut.done():
                    fut.set_result(msg)
        except asyncio.CancelledError:
            pass

    async def _send(self, msg: dict) -> None:
        self._writer.write(encode_frame(msg))
        # awaiting drain() propagates TCP backpressure to the caller
        await self._writer.drain()

    async def send_lm(self, uid: int, prompt, max_new: int = 16,
                      eos=None) -> asyncio.Future:
        fut = asyncio.get_running_loop().create_future()
        self._lm[uid] = fut
        await self._send({"type": "lm", "uid": uid,
                          "prompt": list(prompt),
                          "max_new": max_new, "eos": eos})
        return fut

    async def send_segment(self, patient: int, seq: int, *,
                           deadline_rel_s: Optional[float] = None,
                           urgent: bool = False) -> asyncio.Future:
        fut = asyncio.get_running_loop().create_future()
        self._seg[(patient, seq)] = fut
        msg = {"type": "segment", "patient": patient, "seq": seq,
               "urgent": urgent}
        if deadline_rel_s is not None:
            msg["deadline_rel_s"] = deadline_rel_s
        await self._send(msg)
        return fut

    async def drain(self, timeout: float = 120.0) -> dict:
        fut = asyncio.get_running_loop().create_future()
        self._drains.append(fut)
        await self._send({"type": "drain"})
        return await asyncio.wait_for(fut, timeout)

    async def close(self) -> None:
        self._task.cancel()
        try:
            await self._task
        except asyncio.CancelledError:
            pass
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


__all__ = [
    "Frontend",
    "FrontendConfig",
    "InProcClient",
    "SocketClient",
    "TokenBucket",
    "encode_frame",
    "read_frame",
    "REASON_ADMISSION",
    "REASON_INVALID",
    "REASON_PAGES",
    "REASON_QUEUE_FULL",
    "STATUS_COMPLETED",
    "STATUS_REJECTED",
]
