"""Fixed-size page pool for the serving cache: config + free-list allocator.

The dense slot pool gives every tenant a full `max_len` cache row, so
resident bytes are `max_len x pool_size` regardless of actual prompt
lengths. Paged mode carves the attention K/V pool into fixed-size pages
behind a host-side slot->page indirection table: a slot only holds pages
for the positions it has actually written, so the same byte budget hosts
more concurrent tenants (the BENCH_decode `paged` section self-asserts
the >= 2x gain on a mixed-length workload).

This module is the pure-Python side: `PagingConfig` (validated against a
model's attention cache layout) and `PageAllocator`, a per-shard LIFO
free-list allocator. Everything device-side (pool leaves, gather-view
decode, page-granular seating) lives in `models/transformer.py` and
`serve/seating.py`; the engines own the numpy indirection table and call
into the allocator at admission, on page-boundary crossings during
decode, and at finish/shed.

Layout invariants the allocator maintains (fuzzed in
tests/test_paged_properties.py):

  * pages are partitioned into `n_shards` contiguous physical ranges so
    a slot's pages always live on the slot's data shard (the pool never
    unshards);
  * the LAST physical page of every shard is a reserved scratch page —
    never allocated.  Indirection entries of unmapped logical pages and
    of inactive slots point at scratch, so the pool-wide decode step
    (which re-feeds inactive slots their last token) can only ever
    scribble on scratch, never on a page that was freed and reallocated
    to a new tenant.  Scratch contents are garbage by design and are
    always masked out by `slot_pos` validity in attention;
  * reservation-then-alloc: `reserve()` at admission claims the
    worst-case page count for a request (prompt + max_new), `alloc()`
    at each page-boundary crossing draws down the reservation, so a
    seated request can never hit exhaustion mid-decode;
  * identical op sequences produce identical physical layouts (LIFO
    free lists, no randomness) — paged runs are bitwise reproducible.
"""

from __future__ import annotations

import dataclasses


class PagesExhaustedError(RuntimeError):
    """Typed page-pool exhaustion: raised instead of corrupting the table.

    Raised by `PageAllocator.reserve/alloc` when a shard's free list
    (net of outstanding reservations) cannot cover the request, and by
    `Engine.submit` for never-satisfiable requests (worst-case page need
    exceeding the whole usable pool). The frontend maps it to the typed
    `pages_exhausted` rejection reason.
    """


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Page-pool geometry for a paged serving engine.

    page_size: positions per page. Must divide every attention block's
        cache capacity (window for local/chunked kinds, max_seq for
        global) so a cache row splits into whole pages and the ring
        arithmetic of windowed kinds is unchanged.
    n_pages: TOTAL physical pages in the pool (across all data shards;
        must divide by the mesh's data-shard count, which also reserves
        one scratch page per shard).
    """

    page_size: int
    n_pages: int

    def __post_init__(self) -> None:
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, got {self.page_size}")
        if self.n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (one scratch + one usable), got {self.n_pages}"
            )


def pages_for_position(last_pos: int, page_size: int, span: int) -> int:
    """Logical pages a slot needs once position `last_pos` is written.

    A slot's logical pages are a contiguous prefix {0..j}: global blocks
    fill them in order, and windowed (ring) blocks re-use prefix pages
    because page_size divides the window. `span` is the per-slot
    indirection width (max cache capacity / page_size over attention
    blocks); ring wrap-around caps the need at span. span == 0 means a
    pure-recurrent model — paging degenerates to the dense pool.
    """
    if span == 0 or last_pos < 0:
        return 0
    return min(last_pos // page_size, span - 1) + 1


class PageAllocator:
    """Per-shard LIFO free-list page allocator with reservations.

    Physical pages [s*per_shard, (s+1)*per_shard) belong to shard s; the
    last page of each range is that shard's scratch page and is never
    handed out. All state is host-side Python — the device only ever
    sees the resulting indirection table.
    """

    def __init__(self, n_pages: int, n_shards: int = 1) -> None:
        if n_shards <= 0:
            raise ValueError(f"n_shards must be positive, got {n_shards}")
        if n_pages % n_shards != 0:
            raise ValueError(
                f"n_pages={n_pages} not divisible by n_shards={n_shards}"
            )
        per_shard = n_pages // n_shards
        if per_shard < 2:
            raise ValueError(
                f"need >= 2 pages per shard (scratch + usable), got {per_shard}"
            )
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.per_shard = per_shard
        self.usable_per_shard = per_shard - 1
        # LIFO: pop() takes the lowest-numbered free page first.
        self._free: list[list[int]] = [
            list(range((s + 1) * per_shard - 2, s * per_shard - 1, -1))
            for s in range(n_shards)
        ]
        self._reserved: list[int] = [0] * n_shards
        # owner -> (shard, outstanding reservation, owned pages)
        self._owners: dict[object, tuple[int, int, list[int]]] = {}

    def scratch(self, shard: int = 0) -> int:
        """The never-allocated scratch page of `shard`."""
        return (shard + 1) * self.per_shard - 1

    def free_pages(self, shard: int = 0) -> int:
        return len(self._free[shard])

    def available(self, shard: int = 0) -> int:
        """Free pages net of outstanding reservations."""
        return len(self._free[shard]) - self._reserved[shard]

    def allocated_pages(self) -> int:
        return sum(len(o[2]) for o in self._owners.values())

    def owned(self, owner: object) -> tuple[int, ...]:
        ent = self._owners.get(owner)
        return tuple(ent[2]) if ent is not None else ()

    def reserve(self, owner: object, n: int, shard: int = 0) -> None:
        """Claim `n` pages of `shard` for `owner` without allocating them."""
        if owner in self._owners:
            raise ValueError(f"owner {owner!r} already holds a reservation")
        if n > self.available(shard):
            raise PagesExhaustedError(
                f"shard {shard}: need {n} pages, "
                f"{self.available(shard)} available "
                f"({len(self._free[shard])} free, "
                f"{self._reserved[shard]} reserved)"
            )
        self._reserved[shard] += n
        self._owners[owner] = (shard, n, [])

    def alloc(self, owner: object) -> int:
        """Draw one physical page from `owner`'s reservation."""
        ent = self._owners.get(owner)
        if ent is None:
            raise ValueError(f"owner {owner!r} has no reservation")
        shard, remaining, pages = ent
        if remaining <= 0:
            # Reservation exhausted: only proceed if the shard has slack
            # beyond everyone else's reservations (never steal).
            if self.available(shard) <= 0:
                raise PagesExhaustedError(
                    f"shard {shard}: owner {owner!r} exceeded its "
                    f"reservation and no unreserved pages remain"
                )
        else:
            self._reserved[shard] -= 1
        page = self._free[shard].pop()
        pages.append(page)
        self._owners[owner] = (shard, max(remaining - 1, 0), pages)
        return page

    def free(self, owner: object) -> int:
        """Release everything `owner` holds; returns the page count freed."""
        ent = self._owners.pop(owner, None)
        if ent is None:
            return 0
        shard, remaining, pages = ent
        self._reserved[shard] -= remaining
        # Push back in reverse so an identical re-run replays the exact
        # same physical layout (LIFO symmetry).
        for p in reversed(pages):
            self._free[shard].append(p)
        return len(pages)

    def check_invariants(self) -> None:
        """Fuzz-harness hook: blow up loudly on any broken invariant."""
        for s in range(self.n_shards):
            lo, hi = s * self.per_shard, (s + 1) * self.per_shard - 1
            owned = [
                p
                for (sh, _, pages) in self._owners.values()
                if sh == s
                for p in pages
            ]
            free = self._free[s]
            assert self._reserved[s] >= 0, "negative reservation count"
            assert self._reserved[s] <= len(free), "reserved beyond free"
            assert len(set(owned)) == len(owned), "double-allocated page"
            assert len(set(free)) == len(free), "duplicate free-list entry"
            assert not (set(owned) & set(free)), "page both owned and free"
            assert sorted(owned + free) == list(range(lo, hi)), (
                "page leak: owned+free != shard range"
            )


def validate_page_size(page_size: int, capacities: tuple[int, ...]) -> int:
    """Check page_size divides every attention cache capacity; return span.

    `capacities` are the attention blocks' cache capacities (empty for a
    pure-recurrent model). Returns the indirection-table width `span`
    (max capacity / page_size; 0 when there is nothing to page).
    """
    for cap in capacities:
        if cap % page_size != 0:
            raise ValueError(
                f"page_size={page_size} does not divide attention cache "
                f"capacity {cap}; pick a page size dividing every block's "
                f"window/max_seq"
            )
    return max((cap // page_size for cap in capacities), default=0)


__all__ = [
    "PageAllocator",
    "PagesExhaustedError",
    "PagingConfig",
    "pages_for_position",
    "validate_page_size",
]
