"""Batched serving engine: prefill + slot-based continuous decode.

A fixed pool of `batch_size` decode slots runs one jitted `decode_step`
per tick for the whole pool (decode is memory-bound: batching the pool
amortizes the weight reads — exactly the roofline term the paper's
compressed weights attack). Requests are admitted into free slots via
per-request prefill; finished slots (EOS or max_tokens) are recycled.

Weight-only quantization (`quantize_for_serving`) converts dense params
to the packed mixed-bit-width format; the model's `linear_apply`
dispatches on the format, so the same jitted decode_step serves both.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models.layers import compile_linear_quant

# param-path leaf dirs that stay dense at serve time (numerically
# sensitive or tiny): embeddings, router, norms, rwkv adapters
_QUANT_TARGETS = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "w_r", "w_k", "w_v", "w_g", "w_o", "cm_k", "cm_v", "cm_r",
    "w_x", "w_out",
)

# block kinds whose decode cache advances on every step (hidden-state
# recurrences): replaying a committed (token, pos) is NOT idempotent
# for them, unlike position-indexed attention KV writes
_RECURRENT_KINDS = ("rglru", "rwkv")


def quantize_for_serving(params: Any, bits: int = 8) -> Any:
    """Dense master params -> packed mixed-bit-width serving params."""

    def visit(tree, name=""):
        if isinstance(tree, dict):
            if "w" in tree and isinstance(tree["w"], jax.Array) and (
                name in _QUANT_TARGETS and tree["w"].ndim in (2, 3)
            ):
                return compile_linear_quant(tree, bits)
            return {k: visit(v, k) for k, v in tree.items()}
        return tree

    return visit(params)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jax.Array  # (S,) int32
    max_new: int = 32
    eos: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based batched decoder around a Model.

    Array placement and decode compilation go through overridable hooks
    (`_place_params` / `_place_cache` / `_place_batch` /
    `_compile_decode`) so `serve.sharded.ShardedEngine` can pin every
    pool array to a device mesh while inheriting the slot semantics —
    admission, EOS-on-first-token, committed-(token,pos) replay —
    unchanged."""

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 greedy: bool = True):
        kinds = tuple(model.cfg.pattern) + tuple(model.cfg.tail or ())
        if batch_size > 1 and any(k in _RECURRENT_KINDS for k in kinds):
            # co-admission prefill replays seated slots' committed
            # (token, pos); recurrent hidden states advance on every
            # step, so the replay would silently corrupt them. A
            # 1-slot pool has no co-seated slots and stays correct;
            # batched recurrent decode goes through `generate` /
            # `sharded.sharded_generate` (no replay) until the engine
            # seats via per-slot cache scatter (see ROADMAP).
            raise ValueError(
                f"slot engine with batch_size={batch_size} does not "
                f"support recurrent-cache models ({model.cfg.name}: "
                f"{kinds}); prefill replay is only idempotent for "
                f"attention caches"
            )
        self.model = model
        self.params = self._place_params(params)
        self.batch = batch_size
        self.greedy = greedy
        self._decode = self._compile_decode()
        self._queue: list[Request] = []
        self._slots: list[Optional[Request]] = [None] * batch_size
        self.cache = self._place_cache(model.init_cache(batch_size))
        zi = lambda: self._place_batch(jnp.zeros((batch_size,), jnp.int32))
        self.pos = zi()
        self.tokens = zi()
        self.active = self._place_batch(jnp.zeros((batch_size,), bool))
        # last (token, pos) actually written into each slot's cache.
        # `tokens`/`pos` hold the *pending* decode input (the generated
        # token not yet in the cache); prefill's pool-wide decode steps
        # must re-feed other slots their committed state, not the
        # pending one, or they would corrupt seated slots' caches.
        self._ctok = zi()
        self._cpos = zi()

    # -- placement / compilation hooks (identity on a single device) --------

    def _place_params(self, params: Any) -> Any:
        return params

    def _place_cache(self, cache: Any) -> Any:
        return cache

    def _place_batch(self, x: jax.Array) -> jax.Array:
        return x

    def _compile_decode(self) -> Callable:
        return jax.jit(self.model.decode_step)

    def submit(self, req: Request) -> None:
        if req.prompt.shape[0] == 0:
            # reject here: an empty prompt has no prefill logits to
            # derive the first token from (admission would crash deep
            # in _admit with an opaque TypeError)
            raise ValueError(f"request {req.uid}: empty prompt")
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.batch):
            # a request finishing at admission frees the slot for the
            # next queued request on the same tick — keep admitting
            while self._slots[slot] is None and self._queue:
                req = self._queue.pop(0)
                # per-request prefill: replay the prompt through the
                # pool cache via decode steps (slot-local; simple and
                # correct — a production engine would batch prefills)
                tok = req.prompt
                logits = None
                for t in range(tok.shape[0]):
                    logits = self._step_single(slot, int(tok[t]), t)
                # the first generated token comes from the prefill's
                # final logits — not from re-feeding the last prompt
                # token (which would write it into the cache twice)
                first = int(jnp.argmax(logits[slot]))
                req.output.append(first)
                if (
                    req.eos is not None and first == req.eos
                ) or len(req.output) >= req.max_new:
                    # EOS-on-first-token guard: the request finishes at
                    # admission and must never occupy the slot — seating
                    # it would leak the slot for requests finishing on
                    # the same tick they were admitted.
                    req.done = True
                    self.active = self.active.at[slot].set(False)
                    continue
                self._slots[slot] = req
                self.pos = self.pos.at[slot].set(tok.shape[0] - 1)
                self.tokens = self.tokens.at[slot].set(first)
                self.active = self.active.at[slot].set(True)
                break

    def _step_single(self, slot: int, token: int, pos: int) -> jax.Array:
        # other slots replay their committed (token, pos) — an
        # idempotent cache rewrite — while `slot` advances
        self._ctok = self._ctok.at[slot].set(token)
        self._cpos = self._cpos.at[slot].set(pos)
        logits, self.cache = self._decode(
            self.params, self.cache, self._ctok, self._cpos
        )
        return logits

    def tick(self) -> int:
        """One decode tick for the whole pool; returns #active slots."""
        self._admit()
        if not any(r is not None for r in self._slots):
            return 0
        # active slots advance with their pending token; inactive slots
        # idempotently replay their committed state (no junk writes)
        pos = jnp.where(self.active, self.pos + 1, self._cpos)
        toks = jnp.where(self.active, self.tokens, self._ctok)
        logits, self.cache = self._decode(
            self.params, self.cache, toks, pos
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # this decode committed (toks, pos) into every slot's cache
        self._ctok = toks
        self._cpos = pos
        self.pos = jnp.where(self.active, pos, self.pos)
        self.tokens = jnp.where(self.active, nxt, self.tokens)
        n_active = 0
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            if (req.eos is not None and tok == req.eos) or len(
                req.output
            ) >= req.max_new:
                req.done = True
                self._slots[slot] = None
                self.active = self.active.at[slot].set(False)
            else:
                n_active += 1
        return n_active

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self._queue:
                break


def generate(
    model: Model,
    params: Any,
    prompts: jax.Array,  # (B, S) int32 — same-length batch
    *,
    max_new: int,
    greedy: bool = True,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Simple batched generate: one prefill + max_new decode steps.
    Returns (B, max_new) int32."""
    b, s = prompts.shape
    if model.cfg.is_enc_dec:
        raise ValueError("use generate_encdec for enc-dec models")
    last_logits, cache = jax.jit(model.prefill)(params, prompts)
    decode = jax.jit(model.decode_step)
    outs = []
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    for t in range(max_new):
        outs.append(tok)
        pos = jnp.full((b,), s + t, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        if greedy or key is None:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    return jnp.stack(outs, axis=1)
