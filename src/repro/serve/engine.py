"""Batched serving engine: batched prefill admission + slot decode.

A fixed pool of `batch_size` decode slots runs one jitted `decode_step`
per tick for the whole pool (decode is memory-bound: batching the pool
amortizes the weight reads — exactly the roofline term the paper's
compressed weights attack). Admission runs the real batched
`model.prefill` over the requests being seated (grouped by prompt
length) and scatters the resulting per-request cache rows into the
placed pool via `serve.seating` — O(prompt) work per request,
independent of the pool size. Because seating overwrites a slot's
entire cache row, it is exact for attention KV *and* step-advancing
recurrent (rg-lru / rwkv) caches alike; finished slots (EOS or
max_tokens) are recycled.

Sampling: greedy argmax by default; with `greedy=False` every request
draws through `sample_tokens` (temperature / top-k) under a per-request
folded PRNG key — token t of request `uid` uses
`fold_in(fold_in(key, uid), t)`, so streams are reproducible across
runs and invariant to seat order and co-tenancy. `generate` follows the
same schedule (row index as uid), making the two paths token-identical
under sampling as well as greedy.

Weight-only quantization (`quantize_for_serving`) converts dense params
to the packed mixed-bit-width format; the model's `linear_apply`
dispatches on the format, so the same jitted decode_step serves both.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.concurrency import driver_thread_only

from repro import obs
from repro.dist import sharding as shd
from repro.models.api import Model
from repro.models.layers import compile_linear_quant
from repro.serve import seating
from repro.serve.paging import (
    PageAllocator,
    PagesExhaustedError,
    PagingConfig,
    pages_for_position,
    validate_page_size,
)

# param-path leaf dirs that stay dense at serve time (numerically
# sensitive or tiny): embeddings, router, norms, rwkv adapters
_QUANT_TARGETS = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "w_r", "w_k", "w_v", "w_g", "w_o", "cm_k", "cm_v", "cm_r",
    "w_x", "w_out",
)


class EncDecUnsupportedError(TypeError):
    """An encoder-decoder (whisper-family) model hit a decoder-only
    serving path. These models need a frames-aware prefill (the open
    ROADMAP "Enc-dec prefill" item); until that lands, drive them
    directly through `model.prefill(params, tokens, frames)` +
    `model.decode_step` (see `tests/test_serve.py::
    test_decode_matches_teacher_forced` for the pattern)."""


def _reject_enc_dec(cfg, where: str) -> None:
    if cfg.is_enc_dec:
        raise EncDecUnsupportedError(
            f"{where} drives the decoder-only path, but {cfg.name!r} is "
            f"an encoder-decoder model: its prefill needs audio frames "
            f"(frames-aware prefill is not wired yet — ROADMAP 'Enc-dec "
            f"prefill'). Run it through model.prefill(params, tokens, "
            f"frames) + model.decode_step directly instead."
        )


def quantize_for_serving(params: Any, bits: int = 8) -> Any:
    """Dense master params -> packed mixed-bit-width serving params."""

    def visit(tree, name=""):
        if isinstance(tree, dict):
            if "w" in tree and isinstance(tree["w"], jax.Array) and (
                name in _QUANT_TARGETS and tree["w"].ndim in (2, 3)
            ):
                return compile_linear_quant(tree, bits)
            return {k: visit(v, k) for k, v in tree.items()}
        return tree

    return visit(params)


def sample_tokens(
    logits: jax.Array,  # (B, V) float
    keys: jax.Array,  # (B, 2) uint32 — one PRNG key per row
    *,
    temperature: float = 1.0,
    top_k: int = 0,
) -> jax.Array:
    """Per-row temperature / top-k sampling. Returns (B,) int32.

    `top_k <= 0` or `top_k >= V` samples the full distribution. The
    top-k mask keeps every logit >= the k-th largest, so ties at the
    threshold are all eligible (deterministic given the key, never
    index-order-dependent). `temperature <= 0` degenerates to greedy
    argmax over the masked logits — identical to plain argmax, since
    masking only removes non-argmax entries.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    if top_k and top_k < v:
        thresh = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < thresh, -jnp.inf, logits)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / float(temperature)
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def request_key(base: jax.Array, uid: int) -> jax.Array:
    """Per-request PRNG key: fold the request uid into the engine/run
    key. Token t then folds t into this — the schedule both the engine
    and `generate` follow, so sampled streams match across paths and
    are invariant to seat order."""
    return jax.random.fold_in(base, uid)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jax.Array  # (S,) int32
    max_new: int = 32
    eos: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _ChunkState:
    """One long prompt mid chunked-prefill: a standalone rows-cache the
    chunk cell advances `chunk_tokens` prompt tokens per tick, so the
    pool's decode ticks (and other admissions) interleave instead of
    stalling behind one O(prompt) prefill. Takes a pool slot only at
    completion."""

    req: Request
    cache: Any  # rows-format cache being built
    done: int  # prompt tokens already fed
    shard: int  # page-pool shard the reservation (and seat) lives on
    logits: Any = None  # (rows, V) final-chunk logits once ready
    ready: bool = False


def _chunk_prefill_fn(model: Model) -> Callable:
    """Chunked-prefill cell body: scan `decode_step` over one chunk of
    prompt tokens. Pad steps (`act[t]` False) re-feed the last real
    (token, position) but their cache writes are masked out, so the
    returned cache is exactly the real prefix's; `last_idx` selects the
    last real step's logits (recurrent blocks advance on pad steps, so
    the final scan slot is not always the right one)."""

    def fn(params, cache, toks, poss, act, last_idx):
        # toks/poss (rows, c) int32; act (c,) bool; last_idx () int32
        def body(cache, xs):
            tok_t, pos_t, a_t = xs
            logits, nc = model.decode_step(params, cache, tok_t, pos_t)
            cache = jax.tree.map(
                lambda old, new: jnp.where(a_t, new, old), cache, nc
            )
            return cache, logits

        cache, logits = jax.lax.scan(
            body, cache, (toks.T, poss.T, act)
        )
        return jnp.take(logits, last_idx, axis=0), cache

    return fn


class Engine:
    """Slot-based batched decoder around a Model.

    Array placement and compilation go through overridable hooks
    (`_place_params` / `_place_cache` / `_place_batch` /
    `_compile_decode` / `_admission_cell` / `_admission_rows`) so
    `serve.sharded.ShardedEngine` can pin every pool array — and the
    admission prefill/seating cells — to a device mesh while inheriting
    the slot semantics (admission, EOS-on-first-token, recycling)
    unchanged.

    Admission is batched: each round takes up to |free slots| queued
    requests, groups them by prompt length, runs one `model.prefill`
    per group, and scatter-seats the resulting cache rows into the
    pool (`serve.seating.scatter_slots`). Work is O(prompt) per
    request, independent of pool size — `admission_rowsteps` counts
    the (row x token) units actually spent, which
    `benchmarks/decode_throughput.py` asserts pool-size-independent.
    """

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, key: Optional[jax.Array] = None,
                 paging: Optional[PagingConfig] = None,
                 chunk_tokens: Optional[int] = None):
        _reject_enc_dec(model.cfg, "the slot engine")
        self.model = model
        self.params = self._place_params(params)
        self.batch = batch_size
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self.key = key if key is not None else jax.random.PRNGKey(0)
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        self.chunk_tokens = chunk_tokens
        self.paging = paging
        self._pg: Optional[PageAllocator] = None
        self._page = 0
        self._span = 0
        self._layouts: dict = {}
        if paging is not None:
            if model.init_cache_paged is None:
                raise TypeError(
                    f"model {model.cfg.name!r} has no paged cache support"
                )
            self._page = paging.page_size
            self._span = validate_page_size(
                paging.page_size, model.attn_capacities()
            )
            if self._span:
                # pure-recurrent models have nothing to page: the paged
                # cache degenerates to the dense pool and no allocator
                # is needed (span == 0 keeps _pg None)
                self._layouts = model.page_layouts(paging.page_size)
                self._pg = PageAllocator(
                    paging.n_pages, self._paging_shards()
                )
                if batch_size % self._paging_shards():
                    raise shd.ShardingGuardError(
                        f"batch_size={batch_size} not divisible by "
                        f"{self._paging_shards()} page-pool shards"
                    )
        # host-authoritative slot->page indirection table + per-slot
        # mirrors (page count, last written position). The device only
        # ever sees a snapshot of _tbl, passed into the decode cell per
        # tick — never stored in the cache pytree.
        if self._pg is not None:
            self._tbl = np.stack([
                np.full((self._span,),
                        self._pg.scratch(self._slot_shard(i)), np.int32)
                for i in range(batch_size)
            ])
            self._npages = [0] * batch_size
            self._hpos = [0] * batch_size
        self._decode = self._compile_decode()
        self._queue: list[Request] = []
        self._slots: list[Optional[Request]] = [None] * batch_size
        self._chunks: list[_ChunkState] = []
        self._chunk_wait: list[Request] = []
        self.cache = self._place_cache(self._init_cache())
        zi = lambda: self._place_batch(jnp.zeros((batch_size,), jnp.int32))
        self.pos = zi()
        self.tokens = zi()
        self.active = self._place_batch(jnp.zeros((batch_size,), bool))
        # compatibility shim: last (token, pos) fed to each slot by the
        # pool decode. `tokens`/`pos` hold the *pending* decode input;
        # inactive slots re-feed their last-fed state each tick (an
        # idempotent rewrite for attention caches, and harmless for
        # recurrent ones — an unseated row's state is dead weight that
        # scatter seating fully overwrites at the next admission).
        self._ctok = zi()
        self._cpos = zi()
        # sampling state: per-slot folded request keys + #tokens already
        # generated (the fold index for the slot's next draw)
        self._slot_keys = self._place_batch(
            jnp.zeros((batch_size, 2), jnp.uint32)
        )
        self._nout = zi()
        # admission accounting: (rows x tokens) pushed through prefill
        # cells, and how many cells ran — the O(prompt·pool) replay this
        # machinery replaced would have counted batch_size x prompt per
        # request here
        self.admission_rowsteps = 0
        self.admission_prefills = 0
        # request latency tracking (wall): submit time per uid until the
        # first token, then last-token time per slot for inter-token
        # gaps — populated only when telemetry is enabled
        self._t_submit: dict[int, float] = {}
        self._t_last_tok: dict[int, float] = {}
        # uids admitted-or-queued but not yet finished: duplicate-uid
        # submissions are rejected while the first is live (they would
        # clobber its TTFT accounting and collide its `request_key`
        # sampling stream); reuse after finish is legal
        self._inflight: set[int] = set()

    # -- placement / compilation hooks (identity on a single device) --------

    def _place_params(self, params: Any) -> Any:
        return params

    def _place_cache(self, cache: Any) -> Any:
        return cache

    def _place_batch(self, x: jax.Array) -> jax.Array:
        return x

    def _place_tbl(self, x: jax.Array) -> jax.Array:
        return x

    def _init_cache(self) -> Any:
        if self.paging is not None:
            return self.model.init_cache_paged(
                self.batch, self.paging.n_pages, self._page
            )
        return self.model.init_cache(self.batch)

    def _paging_shards(self) -> int:
        """Page-pool shard count: the mesh data-axis size for sharded
        engines (a slot's pages live on the slot's shard), 1 here."""
        return 1

    def _slot_shard(self, slot: int) -> int:
        return slot // (self.batch // self._paging_shards())

    def _tbl_device(self) -> jax.Array:
        return self._place_tbl(jnp.asarray(self._tbl))

    def _compile_decode(self) -> Callable:
        probe = obs.get().probe
        if self._pg is not None:
            model, page = self.model, self._page
            cell = probe.track(
                "serve.decode_step",
                jax.jit(lambda p, c, t, pos, tbl: model.decode_step_paged(
                    p, c, t, pos, tbl, page
                )),
            )

            def step(params, cache, tok, pos):
                return cell(params, cache, tok, pos, self._tbl_device())

            return step
        return probe.track(
            "serve.decode_step", jax.jit(self.model.decode_step)
        )

    def _admission_rows(self, n: int) -> int:
        """Prefill-cell row count for `n` admitted prompts (sharded
        engines pad to the mesh data-axis multiple; extra rows repeat
        the last prompt and their outputs are discarded)."""
        return n

    def _admission_cell(self, rows: int):
        """(prefill, seat, place_prompts) callables for one admission
        batch width. The base engine shares two shape-polymorphic jits;
        `ShardedEngine` compiles per-width cells with explicit mesh
        shardings so the pool cache is seated without leaving its
        placement."""
        if not hasattr(self, "_prefill_jit"):
            probe = obs.get().probe
            self._prefill_jit = probe.track(
                "serve.prefill", jax.jit(self.model.prefill)
            )
            seat_fn = (
                functools.partial(
                    seating.scatter_pages, layouts=self._layouts
                )
                if self._pg is not None
                else seating.scatter_slots
            )
            self._seat_jit = probe.track(
                "serve.seat", jax.jit(seat_fn, donate_argnums=0),
                donate=(0,),
            )
        return self._prefill_jit, self._seat_jit, lambda p: p

    def _chunk_cell(self, c: int, rows: int):
        """(step, init_rows_cache, place_toks) for the chunked-prefill
        cell of chunk width `c`. One compiled cell per width (the last
        chunk pads to `c` and selects its real last-step logits), so
        chunked admission obeys the same zero-recompile-after-warmup
        discipline as the other cells."""
        if not hasattr(self, "_chunk_jit"):
            self._chunk_jit = obs.get().probe.track(
                "serve.chunk", jax.jit(_chunk_prefill_fn(self.model))
            )
        return (
            self._chunk_jit,
            lambda: self.model.init_cache(rows),
            lambda x: jnp.asarray(x, jnp.int32),
        )

    # -- queue / admission --------------------------------------------------

    @driver_thread_only
    def submit(self, req: Request) -> None:
        if req.prompt.shape[0] == 0:
            # reject here: an empty prompt has no prefill logits to
            # derive the first token from (admission would crash deep
            # in the prefill cell with an opaque shape error)
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new <= 0:
            # admission derives the first token from the prefill logits
            # unconditionally, so even max_new=0 would emit one token
            # and violate the declared bound — reject at the boundary
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1, "
                f"got {req.max_new}"
            )
        if req.uid in self._inflight:
            raise ValueError(
                f"request {req.uid}: uid already in flight — a "
                f"duplicate would clobber the live request's TTFT "
                f"accounting and collide its sampling stream; wait for "
                f"it to finish or submit under a fresh uid"
            )
        if not self.admissible(int(req.prompt.shape[0]), req.max_new):
            # never satisfiable: its worst-case page need exceeds the
            # whole usable pool of a shard, so no amount of waiting for
            # other tenants to finish can ever seat it — typed rejection
            # at the boundary instead of an eternal queue stall
            raise PagesExhaustedError(
                f"request {req.uid}: prompt {int(req.prompt.shape[0])} + "
                f"max_new {req.max_new} needs "
                f"{self._worst_pages(int(req.prompt.shape[0]), req.max_new)}"
                f" pages, but the pool has only "
                f"{self._pg.usable_per_shard} usable per shard"
            )
        self._inflight.add(req.uid)
        tel = obs.get()
        if tel.enabled:
            self._t_submit[req.uid] = time.perf_counter()
            # lineage root: mints the request id at the queue boundary
            tel.tracer.instant(
                "serve/submit", cat="serve",
                request_id=f"serve:{req.uid}",
                prompt_len=int(req.prompt.shape[0]),
            )
        self._queue.append(req)
        tel.registry.counter("serve.submitted_total").inc()

    def _worst_pages(self, prompt_len: int, max_new: int) -> int:
        """Worst-case pages a request can ever hold: prompt + max_new
        tokens write positions 0..prompt_len+max_new-2 (the last
        generated token is never fed back)."""
        return pages_for_position(
            prompt_len + max_new - 2, self._page, self._span
        )

    def admissible(self, prompt_len: int, max_new: int) -> bool:
        """False when the request can NEVER be seated (worst-case page
        need exceeds a shard's whole usable pool). Host-side arithmetic
        only — safe to call from the frontend's event loop."""
        if self._pg is None:
            return True
        return (
            self._worst_pages(prompt_len, max_new)
            <= self._pg.usable_per_shard
        )

    def _pick_seat(self, req: Request, free: list) -> Optional[int]:
        """Claim a free slot (and, paged, reserve the request's
        worst-case pages on that slot's shard). Returns None when no
        shard can cover the reservation right now — admission defers
        until running tenants free pages."""
        if self._pg is None:
            return free.pop(0)
        worst = self._worst_pages(int(req.prompt.shape[0]), req.max_new)
        tried: set[int] = set()
        for i, slot in enumerate(free):
            shard = self._slot_shard(slot)
            if shard in tried:
                continue
            tried.add(shard)
            try:
                self._pg.reserve(req.uid, worst, shard)
            except PagesExhaustedError:
                continue
            return free.pop(i)
        return None

    def _admit(self) -> None:
        # admission rounds: requests finishing at admission (EOS on
        # their first token) never occupy a slot, so their freed seats
        # go back into the next round on the same tick
        if self.chunk_tokens is not None:
            self._start_chunks()
        while self._queue:
            free = [i for i in range(self.batch) if self._slots[i] is None]
            if not free:
                return
            pairs: list = []
            blocked = False
            while self._queue and free:
                req = self._queue[0]
                slot = self._pick_seat(req, free)
                if slot is None:
                    # page pool can't cover this request yet: hold the
                    # FIFO head (and everything behind it) until pages
                    # free up — deferral, not rejection
                    blocked = True
                    break
                self._queue.pop(0)
                pairs.append((slot, req))
            if not pairs:
                return
            groups: dict[int, list] = {}
            for slot, req in pairs:
                groups.setdefault(int(req.prompt.shape[0]), []).append(
                    (slot, req)
                )
            for s_len, grp in groups.items():
                self._admit_group(s_len, grp)
            if blocked:
                return

    def _admit_group(self, s_len: int, pairs: list) -> None:
        """One batched prefill + scatter-seat for same-length prompts."""
        tel = obs.get()
        tagged = (
            {"request_ids": [f"serve:{r.uid}" for _, r in pairs]}
            if tel.enabled
            else {}
        )
        with tel.span(
            "serve/admit", cat="serve", s_len=s_len, n=len(pairs),
            **self._admit_span_attrs(), **tagged,
        ):
            self._admit_group_inner(tel, s_len, pairs, tagged)

    def _admit_span_attrs(self) -> dict:
        """Extra attrs for the admission span (`ShardedEngine` reports
        its mesh/width placement here)."""
        return {}

    def _admit_group_inner(
        self, tel, s_len: int, pairs: list, tagged: Optional[dict] = None,
    ) -> None:
        tagged = {} if tagged is None else tagged
        reqs = [r for _, r in pairs]
        n = len(reqs)
        rows = self._admission_rows(n)
        prompts = jnp.stack(
            [jnp.asarray(r.prompt, jnp.int32) for r in reqs]
        )
        if rows > n:
            prompts = jnp.concatenate(
                [prompts,
                 jnp.broadcast_to(prompts[-1:], (rows - n, s_len))]
            )
        prefill, seat, place = self._admission_cell(rows)
        with tel.span(
            "serve/prefill", cat="serve", s_len=s_len, rows=rows,
            **tagged,
        ):
            logits, cache_rows = prefill(self.params, place(prompts))
            tel.block(logits)
        self.admission_rowsteps += rows * s_len
        self.admission_prefills += 1
        tel.registry.counter("serve.admission_rowsteps").add(rows * s_len)
        tel.registry.counter("serve.admission_prefills").inc()
        # the first generated token comes from the prefill's final
        # logits — the same source `generate` uses, which is what makes
        # the two paths token-identical
        if self.greedy:
            firsts = jnp.argmax(logits[:n], axis=-1).astype(jnp.int32)
        else:
            keys = jnp.stack(
                [request_key(self.key, r.uid) for r in reqs]
            )
            firsts = sample_tokens(
                logits[:n], jax.vmap(jax.random.fold_in)(
                    keys, jnp.zeros((n,), jnp.int32)
                ),
                temperature=self.temperature, top_k=self.top_k,
            )
        src, dst = [], []
        for j, (slot, req) in enumerate(pairs):
            first = int(firsts[j])
            req.output.append(first)
            if tel.enabled:
                t_now = time.perf_counter()
                t0 = self._t_submit.pop(req.uid, None)
                if t0 is not None:
                    tel.registry.histogram("serve.ttft_s").observe(
                        t_now - t0
                    )
                self._t_last_tok[slot] = t_now
            if (
                req.eos is not None and first == req.eos
            ) or len(req.output) >= req.max_new:
                # EOS-on-first-token guard: the request finishes at
                # admission and must never occupy the slot — seating it
                # would leak the slot for requests finishing on the same
                # tick they were admitted.
                req.done = True
                self._inflight.discard(req.uid)
                if self._pg is not None:
                    self._pg.free(req.uid)  # releases the reservation
                self.active = self.active.at[slot].set(False)
                self._t_last_tok.pop(slot, None)
                if tel.enabled:
                    tel.tracer.instant(
                        "serve/finish", cat="serve",
                        request_id=f"serve:{req.uid}",
                        n_tokens=len(req.output),
                        at_admission=True,
                    )
                continue
            src.append(j)
            dst.append(slot)
            self._slots[slot] = req
            self._seat_slot_state(req, slot, s_len, first)
        if src:
            with tel.span(
                "serve/seat", cat="serve", n=len(src), **tagged,
            ):
                src_a = jnp.asarray(src, jnp.int32)
                dst_a = jnp.asarray(dst, jnp.int32)
                if self._pg is not None:
                    self.cache = seat(
                        self.cache, cache_rows, src_a, dst_a,
                        jnp.asarray(self._tbl[dst], jnp.int32),
                    )
                else:
                    self.cache = seat(self.cache, cache_rows, src_a, dst_a)
                tel.block(self.cache)

    def _seat_slot_state(
        self, req: Request, slot: int, s_len: int, first: int
    ) -> None:
        """Per-slot engine state for a freshly seated request (shared by
        batched admission and chunked-prefill completion). Paged: draw
        the prompt's pages from the request's reservation into the
        indirection table before its cache rows are scattered."""
        if self._pg is not None:
            p0 = pages_for_position(s_len - 1, self._page, self._span)
            for j in range(p0):
                self._tbl[slot, j] = self._pg.alloc(req.uid)
            self._npages[slot] = p0
            self._hpos[slot] = s_len - 1
        self.pos = self.pos.at[slot].set(s_len - 1)
        self.tokens = self.tokens.at[slot].set(first)
        self.active = self.active.at[slot].set(True)
        self._ctok = self._ctok.at[slot].set(int(req.prompt[-1]))
        self._cpos = self._cpos.at[slot].set(s_len - 1)
        self._slot_keys = self._slot_keys.at[slot].set(
            request_key(self.key, req.uid)
        )
        self._nout = self._nout.at[slot].set(1)

    # -- chunked prefill ----------------------------------------------------

    def _reserve_chunk(self, req: Request) -> Optional[int]:
        """Reserve worst-case pages for a chunking request; returns the
        shard the reservation (and the eventual seat) lives on, or None
        to retry next tick."""
        if self._pg is None:
            return 0
        worst = self._worst_pages(int(req.prompt.shape[0]), req.max_new)
        shard = max(
            range(self._pg.n_shards), key=self._pg.available
        )
        try:
            self._pg.reserve(req.uid, worst, shard)
        except PagesExhaustedError:
            return None
        return shard

    def _start_chunks(self) -> None:
        """Move long prompts off the admission queue into chunked
        prefill. Short prompts behind a long one admit normally — the
        starvation the chunk interleave exists to prevent. Requests
        whose page reservation can't be covered yet park in
        `_chunk_wait` and retry each tick."""
        c = self.chunk_tokens
        longs = [
            r for r in self._queue if int(r.prompt.shape[0]) > c
        ]
        if longs:
            self._queue = [
                r for r in self._queue if int(r.prompt.shape[0]) <= c
            ]
        tel = obs.get()
        for req in self._chunk_wait + longs:
            shard = self._reserve_chunk(req)
            if shard is None:
                if req not in self._chunk_wait:
                    self._chunk_wait.append(req)
                continue
            if req in self._chunk_wait:
                self._chunk_wait.remove(req)
            rows = self._admission_rows(1)
            _, init_rows, _ = self._chunk_cell(c, rows)
            self._chunks.append(
                _ChunkState(req=req, cache=init_rows(), done=0,
                            shard=shard)
            )
            if tel.enabled:
                tel.tracer.instant(
                    "serve/chunk_start", cat="serve",
                    request_id=f"serve:{req.uid}",
                    prompt_len=int(req.prompt.shape[0]),
                )

    def _chunk_tick(self, tel) -> int:
        """Advance every chunking request by one chunk; seat the ones
        that completed (free pool slot permitting). Returns the number
        of requests still mid-chunk or waiting — they count as engine
        activity so `run()`/frontend drains don't stop early."""
        if self._chunk_wait:
            self._start_chunks()
        for st in list(self._chunks):
            if not st.ready:
                self._chunk_advance(tel, st)
            if st.ready and self._chunk_seat(tel, st):
                self._chunks.remove(st)
        return len(self._chunks) + len(self._chunk_wait)

    def _chunk_advance(self, tel, st: _ChunkState) -> None:
        c = self.chunk_tokens
        rows = self._admission_rows(1)
        step, _, place = self._chunk_cell(c, rows)
        prompt = np.asarray(st.req.prompt, np.int32)
        s = prompt.shape[0]
        lo = st.done
        hi = min(lo + c, s)
        chunk = np.full((c,), prompt[hi - 1], np.int32)
        chunk[: hi - lo] = prompt[lo:hi]
        poss = np.minimum(np.arange(lo, lo + c), hi - 1).astype(np.int32)
        act = jnp.asarray(np.arange(c) < (hi - lo))
        toks = place(np.broadcast_to(chunk, (rows, c)))
        poss2 = place(np.broadcast_to(poss, (rows, c)))
        with tel.span(
            "serve/chunk", cat="serve", lo=lo, hi=hi,
            **({"request_ids": [f"serve:{st.req.uid}"]}
               if tel.enabled else {}),
        ):
            st.logits, st.cache = step(
                self.params, st.cache, toks, poss2, act,
                jnp.asarray(hi - lo - 1, jnp.int32),
            )
            tel.block(st.logits)
        self.admission_rowsteps += rows * (hi - lo)
        tel.registry.counter("serve.admission_rowsteps").add(
            rows * (hi - lo)
        )
        tel.registry.counter("serve.chunk_steps").inc()
        st.done = hi
        if st.done >= s:
            st.ready = True

    def _chunk_seat(self, tel, st: _ChunkState) -> bool:
        """Seat a completed chunked prefill into a free pool slot (on
        the reservation's shard when paged). First token, TTFT, and the
        EOS-on-first-token guard mirror batched admission exactly."""
        req = st.req
        free = [i for i in range(self.batch) if self._slots[i] is None]
        if self._pg is not None:
            free = [i for i in free if self._slot_shard(i) == st.shard]
        if not free:
            return False
        slot = free[0]
        s_len = int(req.prompt.shape[0])
        if self.greedy:
            first = int(jnp.argmax(st.logits[0]))
        else:
            first = int(sample_tokens(
                st.logits[:1],
                jax.vmap(jax.random.fold_in)(
                    request_key(self.key, req.uid)[None],
                    jnp.zeros((1,), jnp.int32),
                ),
                temperature=self.temperature, top_k=self.top_k,
            )[0])
        req.output.append(first)
        if tel.enabled:
            t_now = time.perf_counter()
            t0 = self._t_submit.pop(req.uid, None)
            if t0 is not None:
                tel.registry.histogram("serve.ttft_s").observe(t_now - t0)
            self._t_last_tok[slot] = t_now
        if (
            req.eos is not None and first == req.eos
        ) or len(req.output) >= req.max_new:
            req.done = True
            self._inflight.discard(req.uid)
            if self._pg is not None:
                self._pg.free(req.uid)
            self._t_last_tok.pop(slot, None)
            if tel.enabled:
                tel.tracer.instant(
                    "serve/finish", cat="serve",
                    request_id=f"serve:{req.uid}",
                    n_tokens=len(req.output),
                    at_admission=True,
                )
            return True
        self._slots[slot] = req
        self._seat_slot_state(req, slot, s_len, first)
        rows = self._admission_rows(1)
        _, seat, _ = self._admission_cell(rows)
        src = jnp.asarray([0], jnp.int32)
        dst = jnp.asarray([slot], jnp.int32)
        with tel.span(
            "serve/seat", cat="serve", n=1, chunked=True,
            **({"request_ids": [f"serve:{req.uid}"]}
               if tel.enabled else {}),
        ):
            if self._pg is not None:
                self.cache = seat(
                    self.cache, st.cache, src, dst,
                    jnp.asarray(self._tbl[[slot]], jnp.int32),
                )
            else:
                self.cache = seat(self.cache, st.cache, src, dst)
            tel.block(self.cache)
        return True

    def _step_single(self, slot: int, token: int, pos: int) -> jax.Array:
        """Compatibility shim (the PR 2/3 replay admission ran prompts
        through this): feed one slot (token, pos) while every other
        slot re-feeds its last-fed state. Retransmitting a slot's
        last-fed (token, pos) is a bitwise no-op for attention caches —
        k/v writes depend only on (token, pos), not on cache contents."""
        self._ctok = self._ctok.at[slot].set(token)
        self._cpos = self._cpos.at[slot].set(pos)
        logits, self.cache = self._decode(
            self.params, self.cache, self._ctok, self._cpos
        )
        return logits

    @driver_thread_only
    def tick(self) -> int:
        """One decode tick for the whole pool; returns #active slots."""
        tel = obs.get()
        with tel.span("serve/tick", cat="serve"):
            n_active = self._tick_inner(tel)
        tel.registry.gauge("serve.active_slots").set(n_active)
        return n_active

    def _tick_inner(self, tel) -> int:
        self._admit()
        n_chunk = (
            self._chunk_tick(tel) if self.chunk_tokens is not None else 0
        )
        if not any(r is not None for r in self._slots):
            return n_chunk
        if self._pg is not None:
            # page-boundary crossings: every occupied slot writes at
            # position _hpos+1 this tick; map any newly needed logical
            # page before the decode cell sees the table (the seated
            # reservation guarantees alloc succeeds)
            for slot, req in enumerate(self._slots):
                if req is None:
                    continue
                nw = self._hpos[slot] + 1
                need = pages_for_position(nw, self._page, self._span)
                while self._npages[slot] < need:
                    self._tbl[slot, self._npages[slot]] = self._pg.alloc(
                        req.uid
                    )
                    self._npages[slot] += 1
                self._hpos[slot] = nw
        # active slots advance with their pending token; inactive slots
        # re-feed their last-fed state (no junk writes into positions a
        # future tenant's scatter-seat wouldn't overwrite anyway)
        pos = jnp.where(self.active, self.pos + 1, self._cpos)
        toks = jnp.where(self.active, self.tokens, self._ctok)
        tagged = (
            {"request_ids": [
                f"serve:{r.uid}" for r in self._slots if r is not None
            ]}
            if tel.enabled
            else {}
        )
        with tel.span("serve/decode", cat="serve", **tagged):
            logits, self.cache = self._decode(
                self.params, self.cache, toks, pos
            )
            if self.greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                step_keys = jax.vmap(jax.random.fold_in)(
                    self._slot_keys, self._nout
                )
                nxt = sample_tokens(
                    logits, step_keys,
                    temperature=self.temperature, top_k=self.top_k,
                )
            tel.block(nxt)
        # this decode fed (toks, pos) into every slot's cache
        self._ctok = toks
        self._cpos = pos
        self.pos = jnp.where(self.active, pos, self.pos)
        self.tokens = jnp.where(self.active, nxt, self.tokens)
        # every occupied (== active, see test_serve_properties) slot
        # produced one token this tick: one vectorized bump, not a
        # per-slot dispatch on the per-token hot loop
        self._nout = self._nout + self.active.astype(jnp.int32)
        n_active = 0
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            if tel.enabled:
                t_now = time.perf_counter()
                t_prev = self._t_last_tok.get(slot)
                if t_prev is not None:
                    tel.registry.histogram(
                        "serve.inter_token_s"
                    ).observe(t_now - t_prev)
                self._t_last_tok[slot] = t_now
            tel.registry.counter("serve.tokens_total").inc()
            if (req.eos is not None and tok == req.eos) or len(
                req.output
            ) >= req.max_new:
                req.done = True
                self._inflight.discard(req.uid)
                self._slots[slot] = None
                self.active = self.active.at[slot].set(False)
                self._t_last_tok.pop(slot, None)
                if self._pg is not None:
                    # return the slot's pages and point its table rows
                    # back at scratch: the pool decode re-feeds inactive
                    # slots every tick, and scratch is the only page
                    # those writes are allowed to scribble on
                    self._pg.free(req.uid)
                    self._tbl[slot, :] = self._pg.scratch(
                        self._slot_shard(slot)
                    )
                    self._npages[slot] = 0
                tel.registry.counter("serve.completed_total").inc()
                if tel.enabled:
                    tel.tracer.instant(
                        "serve/finish", cat="serve",
                        request_id=f"serve:{req.uid}",
                        n_tokens=len(req.output),
                    )
            else:
                n_active += 1
        return n_active + n_chunk

    def cache_bytes_in_use(self) -> int:
        """Logically resident cache bytes: occupied slots' dense
        per-slot state plus (paged) allocated pages. Drains back to the
        post-construction value (0) when every request finishes — the
        reclamation BENCH_decode asserts. The dense pool's in-use bytes
        count full `max_len` rows per tenant; the paged pool counts only
        mapped pages, which is the whole tenancy win."""
        slot_b, page_b = self._cache_byte_model()
        occupied = sum(r is not None for r in self._slots)
        used = occupied * slot_b
        if self._pg is not None:
            used += self._pg.allocated_pages() * page_b
        return used

    def _cache_byte_model(self) -> tuple:
        """(bytes per occupied slot over dense leaves, bytes per page
        over paged pool leaves), derived from the live cache tree."""
        cached = getattr(self, "_byte_model", None)
        if cached is not None:
            return cached
        flat, _ = jax.tree_util.tree_flatten_with_path(self.cache)
        slot_b = 0
        page_b = 0
        for kp, leaf in flat:
            parts = shd._path_str(kp).split("/")
            nbytes = leaf.size * leaf.dtype.itemsize
            if (
                self._pg is not None
                and seating._leaf_layout(parts, self._layouts) is not None
            ):
                page_b += nbytes // self.paging.n_pages
            else:
                ax = shd.cache_batch_axis(parts)
                slot_b += nbytes // leaf.shape[ax]
        self._byte_model = (slot_b, page_b)
        return self._byte_model

    @driver_thread_only
    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self._queue:
                break


def generate(
    model: Model,
    params: Any,
    prompts: jax.Array,  # (B, S) int32 — same-length batch
    *,
    max_new: int,
    greedy: bool = True,
    key: Optional[jax.Array] = None,
    temperature: float = 1.0,
    top_k: int = 0,
) -> jax.Array:
    """Simple batched generate: one prefill + max_new decode steps.
    Returns (B, max_new) int32.

    With `greedy=False` and a `key`, row b's token t is drawn with
    `fold_in(fold_in(key, b), t)` — the engine's per-request schedule
    with the row index as uid, so a request submitted to an `Engine`
    built on the same key (uid == row) produces the same stream."""
    b, s = prompts.shape
    _reject_enc_dec(model.cfg, "generate")
    sampling = not greedy and key is not None
    last_logits, cache = jax.jit(model.prefill)(params, prompts)
    decode = jax.jit(model.decode_step)
    if sampling:
        row_keys = jax.vmap(lambda r: request_key(key, r))(jnp.arange(b))
        draw = lambda lg, t: sample_tokens(
            lg, jax.vmap(jax.random.fold_in)(
                row_keys, jnp.full((b,), t, jnp.int32)
            ),
            temperature=temperature, top_k=top_k,
        )
        tok = draw(last_logits, 0)
    else:
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    outs = []
    for t in range(max_new):
        outs.append(tok)
        pos = jnp.full((b,), s + t, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        if sampling:
            tok = draw(logits, t + 1)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(outs, axis=1)
