"""Serving substrate: batched LM engine (single-device + mesh-sharded,
batched prefill admission with per-slot cache scatter), the async
transport frontend with knee-aware admission control, and the paper's
VA diagnosis service."""

from repro.serve import engine, frontend, seating, sharded, va_service
from repro.serve.engine import (
    EncDecUnsupportedError,
    Engine,
    Request,
    generate,
    request_key,
    sample_tokens,
)
from repro.serve.frontend import (
    Frontend,
    FrontendConfig,
    InProcClient,
    SocketClient,
    TokenBucket,
)
from repro.serve.seating import gather_slots, scatter_slots
from repro.serve.sharded import (
    DecodePlan,
    ShardedEngine,
    compile_decode,
    plan_decode,
    sharded_generate,
)

__all__ = [
    "engine",
    "frontend",
    "seating",
    "sharded",
    "va_service",
    "EncDecUnsupportedError",
    "Engine",
    "Frontend",
    "FrontendConfig",
    "InProcClient",
    "Request",
    "SocketClient",
    "TokenBucket",
    "generate",
    "request_key",
    "sample_tokens",
    "gather_slots",
    "scatter_slots",
    "DecodePlan",
    "ShardedEngine",
    "compile_decode",
    "plan_decode",
    "sharded_generate",
]
