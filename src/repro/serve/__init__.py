"""Serving substrate: batched LM engine (single-device + mesh-sharded,
batched prefill admission with per-slot cache scatter) and the paper's
VA diagnosis service."""

from repro.serve import engine, seating, sharded, va_service
from repro.serve.engine import (
    EncDecUnsupportedError,
    Engine,
    Request,
    generate,
    request_key,
    sample_tokens,
)
from repro.serve.seating import gather_slots, scatter_slots
from repro.serve.sharded import (
    DecodePlan,
    ShardedEngine,
    compile_decode,
    plan_decode,
    sharded_generate,
)

__all__ = [
    "engine",
    "seating",
    "sharded",
    "va_service",
    "EncDecUnsupportedError",
    "Engine",
    "Request",
    "generate",
    "request_key",
    "sample_tokens",
    "gather_slots",
    "scatter_slots",
    "DecodePlan",
    "ShardedEngine",
    "compile_decode",
    "plan_decode",
    "sharded_generate",
]
