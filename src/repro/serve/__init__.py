"""Serving substrate: batched LM engine + the paper's VA diagnosis service."""

from repro.serve import engine, va_service

__all__ = ["engine", "va_service"]
