"""Serving substrate: batched LM engine (single-device + mesh-sharded)
and the paper's VA diagnosis service."""

from repro.serve import engine, sharded, va_service
from repro.serve.engine import Engine, Request, generate
from repro.serve.sharded import (
    DecodePlan,
    ShardedEngine,
    compile_decode,
    plan_decode,
    sharded_generate,
)

__all__ = [
    "engine",
    "sharded",
    "va_service",
    "Engine",
    "Request",
    "generate",
    "DecodePlan",
    "ShardedEngine",
    "compile_decode",
    "plan_decode",
    "sharded_generate",
]
