"""Sharded multi-host LM decode: the serving engine on a device mesh.

`serve.engine` runs prefill + slot-based continuous decode on one
device. This module places the same computation on a `launch.mesh`-style
mesh: parameters with `dist.sharding.param_specs` (FSDP rows / TP
columns), the decode KV/recurrent caches with `cache_specs` (batch over
`data`, KV heads over `model`), and the per-slot token/pos arrays with
`batch_specs` — all under the strict divisibility guard, so per-device
memory really is total/shards and never silently replicated. Prefill
and decode steps are jit-compiled with explicit in/out shardings; the
cache never leaves its placement between steps.

Why this is the throughput story: decode is memory-bound — each token
reads every (placed) parameter byte plus the slot's cache — so the
per-device byte footprint from the sharded avals *is* the modeled step
time, and tokens/s scales with devices exactly as those bytes shrink
(`benchmarks/decode_throughput.py` accounts it; `DecodePlan` exposes
the numbers).

Layers:

  * `plan_decode`     — specs + shardings + per-device byte accounting
                        for one (model, mesh, pool size), no allocation;
  * `compile_decode`  — jitted prefill/decode with explicit shardings;
  * `sharded_generate`— batched generate (one prefill + N decode steps),
                        the multi-device twin of `engine.generate`,
                        greedy or sampled under per-row folded keys;
  * `ShardedEngine`   — `engine.Engine` with every pool array pinned to
                        the mesh; slot admission (batched prefill +
                        scatter seating), EOS-on-first-token recycling
                        and per-request sampling keys are inherited, not
                        reimplemented — this class only compiles the
                        admission prefill/seat cells per admission width
                        with explicit shardings, so seating updates the
                        pool cache without it ever leaving the mesh.

On a data-only mesh the sharded pool is token-for-token identical to
the single-device engine (each device runs whole rows, same reduction
order); with a model axis, row-parallel contractions psum partial
products, so logits agree only to fp tolerance and greedy argmax can
flip on near-uniform (e.g. random-init) logits —
`tests/test_decode_multidevice.py` pins both contracts.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.dist import sharding as shd
from repro.models.api import Model
from repro.serve import seating
from repro.serve.engine import (
    Engine,
    _chunk_prefill_fn,
    _reject_enc_dec,
    request_key,
    sample_tokens,
)
from repro.serve.paging import PagingConfig, validate_page_size


def _decode_comm_budget(model: Model) -> dict:
    """Declared collective budget for this model's serve cells (the
    `repro.analysis` cell audit asserts the compiled inventory stays
    under it). Row/column-parallel TP contractions legitimately psum or
    gather a handful of partials per layer, and the scanned layer stack
    multiplies the loop body by its trip count — so the envelope scales
    with `n_layers`. What it catches is the SPMD blowup class: an
    accidental per-step resharding explodes the count far past
    O(layers)."""
    n = int(model.cfg.n_layers)
    per_layer_cap = 6 * n + 16
    return {
        "all-reduce": per_layer_cap,
        "all-gather": per_layer_cap,
        "reduce-scatter": per_layer_cap,
        "collective-permute": per_layer_cap,
        # XLA lowers some 2D-mesh reshards of the prefill activations
        # to all-to-all (measured: 2 on a 4x2 mesh at n_layers=2)
        "all-to-all": per_layer_cap,
    }


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Placement plan for one (model, mesh, pool size): every sharding
    the decode path needs, plus per-device memory accounted from the
    sharded avals (what an allocator would reserve, with no allocation
    here)."""

    mesh: Mesh
    batch: int
    n_devices: int
    n_data: int  # combined data-axis size (pool rows per device = batch/n_data)
    params: Any  # NamedSharding pytree for the parameters
    cache: Any  # NamedSharding pytree for the decode cache
    token: NamedSharding  # (B,) arrays: tokens, pos, active masks
    logits: NamedSharding  # (B, V) decode/prefill logits
    prompts: NamedSharding  # (B, S) prefill token batch
    param_bytes_per_device: int
    cache_bytes_per_device: int
    param_bytes_total: int
    cache_bytes_total: int

    @property
    def cache_replication_factor(self) -> float:
        """1.0 = perfectly sharded; n_devices = fully replicated."""
        per_dev_if_perfect = self.cache_bytes_total / self.n_devices
        return self.cache_bytes_per_device / max(per_dev_if_perfect, 1)


def plan_decode(
    model: Model, params: Any, mesh: Mesh, *, batch_size: int,
    strict: bool = True, paging: Optional[PagingConfig] = None,
) -> DecodePlan:
    """Build the placement plan. `params` may be the real tree or its
    eval_shape aval tree — only shapes/dtypes are read. `strict=True`
    (the default) refuses a pool whose cache cannot shard its batch dim,
    instead of silently replicating it per device.

    With `paging`, the cache avals come from `model.init_cache_paged`:
    attention K/V leaves become (n_pages, page, ...) pools whose page
    axis sits exactly where the dense slot axis sat, so `cache_specs`
    shards pages over the data axes with the same rule — provided
    `n_pages` divides by the data-axis size (guarded here; the engine's
    `PageAllocator` then hands each slot pages from its own shard's
    contiguous range, which is the same contiguous split NamedSharding
    makes, so a slot's pages physically live with the slot)."""
    cfg = model.cfg
    axes = shd.data_axes(cfg, mesh)
    n_data = shd._axis_size(axes, mesh)
    n_dev = math.prod(mesh.devices.shape)
    if batch_size % max(n_data, 1):
        raise shd.ShardingGuardError(
            f"decode pool batch_size={batch_size} not divisible by the "
            f"mesh data axes {axes} (size {n_data})"
        )
    param_avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    if (
        paging is not None
        and model.init_cache_paged is not None
        and validate_page_size(paging.page_size, model.attn_capacities())
    ):
        if paging.n_pages % max(n_data, 1):
            raise shd.ShardingGuardError(
                f"paged pool n_pages={paging.n_pages} not divisible by "
                f"the mesh data axes {axes} (size {n_data})"
            )
        cache_avals = jax.eval_shape(
            lambda: model.init_cache_paged(
                batch_size, paging.n_pages, paging.page_size
            )
        )
    else:
        cache_avals = jax.eval_shape(lambda: model.init_cache(batch_size))
    pspecs = shd.param_specs(param_avals, cfg, mesh)
    cspecs = shd.cache_specs(cache_avals, cfg, mesh, strict=strict)
    # slot token/pos and (B, V)/(B, S) batches share the batch rules —
    # the divisibility check above already guarantees strict passes
    bspecs = shd.batch_specs(
        {
            "token": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "row": jax.ShapeDtypeStruct((batch_size, 1), jnp.int32),
        },
        cfg, mesh, strict=strict,
    )
    replicated = jax.tree.map(lambda s: P(*([None] * len(s))), cspecs,
                              is_leaf=lambda s: isinstance(s, P))
    return DecodePlan(
        mesh=mesh,
        batch=batch_size,
        n_devices=n_dev,
        n_data=n_data,
        params=shd.named(pspecs, mesh),
        cache=shd.named(cspecs, mesh),
        token=NamedSharding(mesh, bspecs["token"]),
        logits=NamedSharding(mesh, bspecs["row"]),
        prompts=NamedSharding(mesh, bspecs["row"]),
        param_bytes_per_device=shd.bytes_per_device(
            param_avals, pspecs, mesh
        ),
        cache_bytes_per_device=shd.bytes_per_device(
            cache_avals, cspecs, mesh
        ),
        param_bytes_total=shd.bytes_per_device(
            param_avals,
            jax.tree.map(lambda s: P(*([None] * len(s))), pspecs,
                         is_leaf=lambda s: isinstance(s, P)),
            mesh,
        ),
        cache_bytes_total=shd.bytes_per_device(
            cache_avals, replicated, mesh
        ),
    )


def compile_decode(
    model: Model, plan: DecodePlan
) -> Tuple[Callable, Callable]:
    """(prefill, decode_step) jit-compiled with explicit in/out
    shardings from `plan`. The cache argument/result keeps the
    `cache_specs` placement across every step, so decode never migrates
    the pool's persistent state."""
    _reject_enc_dec(model.cfg, "sharded decode (compile_decode)")
    prefill = jax.jit(
        model.prefill,
        in_shardings=(plan.params, plan.prompts),
        out_shardings=(plan.logits, plan.cache),
    )
    decode = jax.jit(
        model.decode_step,
        in_shardings=(plan.params, plan.cache, plan.token, plan.token),
        out_shardings=(plan.logits, plan.cache),
    )
    return prefill, decode


def place_params(params: Any, plan: DecodePlan) -> Any:
    return jax.device_put(params, plan.params)


def sharded_generate(
    model: Model,
    params: Any,
    prompts: jax.Array,  # (B, S) int32 — same-length batch
    *,
    mesh: Mesh,
    max_new: int,
    params_placed: bool = False,
    plan: Optional[DecodePlan] = None,
    greedy: bool = True,
    key: Optional[jax.Array] = None,
    temperature: float = 1.0,
    top_k: int = 0,
) -> jax.Array:
    """Multi-device `engine.generate`: one sharded prefill + `max_new`
    sharded decode steps. Returns (B, max_new) int32.

    Greedy by default; with `greedy=False` and a `key`, row b's token t
    is drawn with `fold_in(fold_in(key, b), t)` — `engine.generate`'s
    schedule, so the two paths stay stream-identical wherever their
    logits do (data-only meshes; a model axis psums partial products,
    which can flip samples only to fp tolerance)."""
    b, s = prompts.shape
    if plan is None:
        plan = plan_decode(model, params, mesh, batch_size=b)
    if plan.batch != b:
        raise ValueError(f"plan batch {plan.batch} != prompts batch {b}")
    prefill, decode = compile_decode(model, plan)
    if not params_placed:
        params = place_params(params, plan)
    prompts = jax.device_put(
        jnp.asarray(prompts, jnp.int32), plan.prompts
    )
    sampling = not greedy and key is not None
    if sampling:
        row_keys = jax.vmap(lambda r: request_key(key, r))(jnp.arange(b))
        draw = lambda lg, t: sample_tokens(
            lg, jax.vmap(jax.random.fold_in)(
                row_keys, jnp.full((b,), t, jnp.int32)
            ),
            temperature=temperature, top_k=top_k,
        )
    last_logits, cache = prefill(params, prompts)
    outs = []
    tok = draw(last_logits, 0) if sampling else jnp.argmax(
        last_logits, axis=-1
    ).astype(jnp.int32)
    for t in range(max_new):
        outs.append(tok)
        pos = jax.device_put(
            jnp.full((b,), s + t, jnp.int32), plan.token
        )
        logits, cache = decode(
            params, cache, jax.device_put(tok, plan.token), pos
        )
        tok = draw(logits, t + 1) if sampling else jnp.argmax(
            logits, axis=-1
        ).astype(jnp.int32)
    return jnp.stack(outs, axis=1)


class ShardedEngine(Engine):
    """The slot engine with its pool pinned to a mesh.

    Everything behavioral — batched prefill admission, scatter seating,
    EOS-on-first-token slot recycling, per-request sampling keys — is
    inherited from `Engine`; this class only overrides *where arrays
    live and how cells compile*: params/cache/slot-state are device_put
    to the plan's shardings at init, the jitted decode carries explicit
    in/out shardings so the cache round-trips without migrating, and
    each admission width gets a (prefill, seat) cell pair compiled with
    explicit shardings — the prefill cell's cache rows come out in the
    admission-plan placement and `seating.scatter_slots` writes them
    into the pool under `out_shardings=plan.cache`, so seating never
    unshards the pool. Admission widths are padded to the mesh data-axis
    multiple (`_admission_rows`); pad rows repeat a real prompt and
    their outputs are discarded. Host-side `.at[].set` slot updates
    preserve the committed sharding; the step wrapper re-pins token/pos
    anyway (jit with explicit in_shardings rejects, rather than
    reshards, mismatched committed arrays)."""

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 mesh: Mesh, greedy: bool = True, strict: bool = True,
                 temperature: float = 1.0, top_k: int = 0,
                 key: Optional[jax.Array] = None,
                 paging: Optional[PagingConfig] = None,
                 chunk_tokens: Optional[int] = None):
        # the plan must exist before Engine.__init__ runs the hooks
        self.mesh = mesh
        self._strict = strict
        self.plan = plan_decode(
            model, params, mesh, batch_size=batch_size, strict=strict,
            paging=paging,
        )
        self._adm_cells: dict[int, tuple] = {}
        self._chunk_cells: dict[int, tuple] = {}
        super().__init__(
            model, params, batch_size=batch_size, greedy=greedy,
            temperature=temperature, top_k=top_k, key=key,
            paging=paging, chunk_tokens=chunk_tokens,
        )

    def _place_params(self, params: Any) -> Any:
        return jax.device_put(params, self.plan.params)

    def _place_cache(self, cache: Any) -> Any:
        return jax.device_put(cache, self.plan.cache)

    def _place_batch(self, x: jax.Array) -> jax.Array:
        return jax.device_put(x, self.plan.token)

    def _place_tbl(self, x: jax.Array) -> jax.Array:
        # (B, span) indirection rows shard with the slots they describe
        return jax.device_put(x, self.plan.prompts)

    def _paging_shards(self) -> int:
        return max(self.plan.n_data, 1)

    def _compile_decode(self) -> Callable:
        plan = self.plan
        if self._pg is not None:
            model, page = self.model, self._page
            cell = obs.get().probe.track(
                "serve.decode_step",
                jax.jit(
                    lambda p, c, t, pos, tbl: model.decode_step_paged(
                        p, c, t, pos, tbl, page
                    ),
                    in_shardings=(
                        plan.params, plan.cache, plan.token, plan.token,
                        plan.prompts,
                    ),
                    out_shardings=(plan.logits, plan.cache),
                ),
                budget=_decode_comm_budget(self.model),
                sharded_outputs=True,
            )

            def pstep(params, cache, tok, pos):
                return cell(
                    params, cache,
                    jax.device_put(tok, plan.token),
                    jax.device_put(pos, plan.token),
                    self._tbl_device(),
                )

            return pstep
        _, decode = compile_decode(self.model, plan)
        decode = obs.get().probe.track(
            "serve.decode_step", decode,
            budget=_decode_comm_budget(self.model),
            sharded_outputs=True,
        )

        def step(params, cache, tok, pos):
            return decode(
                params, cache,
                jax.device_put(tok, plan.token),
                jax.device_put(pos, plan.token),
            )

        return step

    def _admission_rows(self, n: int) -> int:
        # the admission prefill is itself a sharded cell: its batch dim
        # must divide over the mesh data axes (strict guard), so pad up
        return n + (-n) % max(self.plan.n_data, 1)

    def _admit_span_attrs(self) -> dict:
        # seen in the trace: which mesh this admission ran on, so a
        # lineage join can attribute seating cost per (mesh, width)
        return {
            "mesh": "x".join(str(d) for d in self.mesh.devices.shape),
            "n_data": int(self.plan.n_data),
        }

    def _admission_cell(self, rows: int):
        cell = self._adm_cells.get(rows)
        if cell is None:
            rplan = plan_decode(
                self.model, self.params, self.mesh, batch_size=rows,
                strict=self._strict,
            )
            probe = obs.get().probe
            prefill = probe.track(
                f"serve.prefill.w{rows}",
                jax.jit(
                    self.model.prefill,
                    in_shardings=(self.plan.params, rplan.prompts),
                    out_shardings=(rplan.logits, rplan.cache),
                ),
                budget=_decode_comm_budget(self.model),
                sharded_outputs=True,
            )
            if self._pg is not None:
                # admission rows stay a dense cache (what prefill
                # emits); seating splits their K/V rows into pages and
                # lands each on its mapped physical page in the pool
                seat = probe.track(
                    f"serve.seat.w{rows}",
                    jax.jit(
                        functools.partial(
                            seating.scatter_pages, layouts=self._layouts
                        ),
                        in_shardings=(
                            self.plan.cache, rplan.cache, None, None,
                            None,
                        ),
                        out_shardings=self.plan.cache,
                        donate_argnums=0,
                    ),
                    donate=(0,), sharded_outputs=True,
                )
            else:
                seat = probe.track(
                    f"serve.seat.w{rows}",
                    jax.jit(
                        seating.scatter_slots,
                        in_shardings=(
                            self.plan.cache, rplan.cache, None, None
                        ),
                        out_shardings=self.plan.cache,
                        donate_argnums=0,
                    ),
                    donate=(0,), sharded_outputs=True,
                )
            place = lambda p: jax.device_put(
                jnp.asarray(p, jnp.int32), rplan.prompts
            )
            cell = (prefill, seat, place)
            self._adm_cells[rows] = cell
        return cell

    def _chunk_cell(self, c: int, rows: int):
        """Per-chunk-width cell with explicit shardings: the chunk
        cache is a dense rows cache on the admission-width plan; token
        and position chunks shard like prompt batches. One compiled
        cell per width (`serve.chunk.c{c}`), warm after first use."""
        cell = self._chunk_cells.get(c)
        if cell is None:
            rplan = plan_decode(
                self.model, self.params, self.mesh, batch_size=rows,
                strict=self._strict,
            )
            step = obs.get().probe.track(
                f"serve.chunk.c{c}",
                jax.jit(
                    _chunk_prefill_fn(self.model),
                    in_shardings=(
                        self.plan.params, rplan.cache, rplan.prompts,
                        rplan.prompts, None, None,
                    ),
                    out_shardings=(rplan.logits, rplan.cache),
                ),
                budget=_decode_comm_budget(self.model),
                sharded_outputs=True,
            )
            init_rows = lambda: jax.device_put(
                self.model.init_cache(rows), rplan.cache
            )
            place = lambda x: jax.device_put(
                jnp.asarray(x, jnp.int32), rplan.prompts
            )
            cell = (step, init_rows, place)
            self._chunk_cells[c] = cell
        return cell

    @property
    def n_devices(self) -> int:
        return self.plan.n_devices

    @property
    def cache_bytes_per_device(self) -> int:
        return self.plan.cache_bytes_per_device

    @property
    def param_bytes_per_device(self) -> int:
        return self.plan.param_bytes_per_device
