"""Sharded multi-host LM decode: the serving engine on a device mesh.

`serve.engine` runs prefill + slot-based continuous decode on one
device. This module places the same computation on a `launch.mesh`-style
mesh: parameters with `dist.sharding.param_specs` (FSDP rows / TP
columns), the decode KV/recurrent caches with `cache_specs` (batch over
`data`, KV heads over `model`), and the per-slot token/pos arrays with
`batch_specs` — all under the strict divisibility guard, so per-device
memory really is total/shards and never silently replicated. Prefill
and decode steps are jit-compiled with explicit in/out shardings; the
cache never leaves its placement between steps.

Why this is the throughput story: decode is memory-bound — each token
reads every (placed) parameter byte plus the slot's cache — so the
per-device byte footprint from the sharded avals *is* the modeled step
time, and tokens/s scales with devices exactly as those bytes shrink
(`benchmarks/decode_throughput.py` accounts it; `DecodePlan` exposes
the numbers).

Layers:

  * `plan_decode`     — specs + shardings + per-device byte accounting
                        for one (model, mesh, pool size), no allocation;
  * `compile_decode`  — jitted prefill/decode with explicit shardings;
  * `sharded_generate`— batched generate (one prefill + N decode steps),
                        the multi-device twin of `engine.generate`;
  * `ShardedEngine`   — `engine.Engine` with every pool array pinned to
                        the mesh; slot admission, EOS-on-first-token and
                        committed-(token,pos) idempotent prefill replay
                        are inherited, not reimplemented.

On a data-only mesh the sharded pool is token-for-token identical to
the single-device engine (each device runs whole rows, same reduction
order); with a model axis, row-parallel contractions psum partial
products, so logits agree only to fp tolerance and greedy argmax can
flip on near-uniform (e.g. random-init) logits —
`tests/test_decode_multidevice.py` pins both contracts.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models.api import Model
from repro.serve.engine import Engine


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Placement plan for one (model, mesh, pool size): every sharding
    the decode path needs, plus per-device memory accounted from the
    sharded avals (what an allocator would reserve, with no allocation
    here)."""

    mesh: Mesh
    batch: int
    n_devices: int
    n_data: int  # combined data-axis size (pool rows per device = batch/n_data)
    params: Any  # NamedSharding pytree for the parameters
    cache: Any  # NamedSharding pytree for the decode cache
    token: NamedSharding  # (B,) arrays: tokens, pos, active masks
    logits: NamedSharding  # (B, V) decode/prefill logits
    prompts: NamedSharding  # (B, S) prefill token batch
    param_bytes_per_device: int
    cache_bytes_per_device: int
    param_bytes_total: int
    cache_bytes_total: int

    @property
    def cache_replication_factor(self) -> float:
        """1.0 = perfectly sharded; n_devices = fully replicated."""
        per_dev_if_perfect = self.cache_bytes_total / self.n_devices
        return self.cache_bytes_per_device / max(per_dev_if_perfect, 1)


def plan_decode(
    model: Model, params: Any, mesh: Mesh, *, batch_size: int,
    strict: bool = True,
) -> DecodePlan:
    """Build the placement plan. `params` may be the real tree or its
    eval_shape aval tree — only shapes/dtypes are read. `strict=True`
    (the default) refuses a pool whose cache cannot shard its batch dim,
    instead of silently replicating it per device."""
    cfg = model.cfg
    axes = shd.data_axes(cfg, mesh)
    n_data = shd._axis_size(axes, mesh)
    n_dev = math.prod(mesh.devices.shape)
    if batch_size % max(n_data, 1):
        raise shd.ShardingGuardError(
            f"decode pool batch_size={batch_size} not divisible by the "
            f"mesh data axes {axes} (size {n_data})"
        )
    param_avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    cache_avals = jax.eval_shape(lambda: model.init_cache(batch_size))
    pspecs = shd.param_specs(param_avals, cfg, mesh)
    cspecs = shd.cache_specs(cache_avals, cfg, mesh, strict=strict)
    # slot token/pos and (B, V)/(B, S) batches share the batch rules —
    # the divisibility check above already guarantees strict passes
    bspecs = shd.batch_specs(
        {
            "token": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "row": jax.ShapeDtypeStruct((batch_size, 1), jnp.int32),
        },
        cfg, mesh, strict=strict,
    )
    replicated = jax.tree.map(lambda s: P(*([None] * len(s))), cspecs,
                              is_leaf=lambda s: isinstance(s, P))
    return DecodePlan(
        mesh=mesh,
        batch=batch_size,
        n_devices=n_dev,
        n_data=n_data,
        params=shd.named(pspecs, mesh),
        cache=shd.named(cspecs, mesh),
        token=NamedSharding(mesh, bspecs["token"]),
        logits=NamedSharding(mesh, bspecs["row"]),
        prompts=NamedSharding(mesh, bspecs["row"]),
        param_bytes_per_device=shd.bytes_per_device(
            param_avals, pspecs, mesh
        ),
        cache_bytes_per_device=shd.bytes_per_device(
            cache_avals, cspecs, mesh
        ),
        param_bytes_total=shd.bytes_per_device(
            param_avals,
            jax.tree.map(lambda s: P(*([None] * len(s))), pspecs,
                         is_leaf=lambda s: isinstance(s, P)),
            mesh,
        ),
        cache_bytes_total=shd.bytes_per_device(
            cache_avals, replicated, mesh
        ),
    )


def compile_decode(
    model: Model, plan: DecodePlan
) -> Tuple[Callable, Callable]:
    """(prefill, decode_step) jit-compiled with explicit in/out
    shardings from `plan`. The cache argument/result keeps the
    `cache_specs` placement across every step, so decode never migrates
    the pool's persistent state."""
    if model.cfg.is_enc_dec:
        raise ValueError(
            "sharded decode drives the decoder-only path; enc-dec "
            "models need a frames-aware prefill (not wired yet)"
        )
    prefill = jax.jit(
        model.prefill,
        in_shardings=(plan.params, plan.prompts),
        out_shardings=(plan.logits, plan.cache),
    )
    decode = jax.jit(
        model.decode_step,
        in_shardings=(plan.params, plan.cache, plan.token, plan.token),
        out_shardings=(plan.logits, plan.cache),
    )
    return prefill, decode


def place_params(params: Any, plan: DecodePlan) -> Any:
    return jax.device_put(params, plan.params)


def sharded_generate(
    model: Model,
    params: Any,
    prompts: jax.Array,  # (B, S) int32 — same-length batch
    *,
    mesh: Mesh,
    max_new: int,
    params_placed: bool = False,
    plan: Optional[DecodePlan] = None,
) -> jax.Array:
    """Multi-device `engine.generate`: one sharded prefill + `max_new`
    sharded greedy decode steps. Returns (B, max_new) int32."""
    b, s = prompts.shape
    if plan is None:
        plan = plan_decode(model, params, mesh, batch_size=b)
    if plan.batch != b:
        raise ValueError(f"plan batch {plan.batch} != prompts batch {b}")
    prefill, decode = compile_decode(model, plan)
    if not params_placed:
        params = place_params(params, plan)
    prompts = jax.device_put(
        jnp.asarray(prompts, jnp.int32), plan.prompts
    )
    last_logits, cache = prefill(params, prompts)
    outs = []
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    for t in range(max_new):
        outs.append(tok)
        pos = jax.device_put(
            jnp.full((b,), s + t, jnp.int32), plan.token
        )
        logits, cache = decode(
            params, cache, jax.device_put(tok, plan.token), pos
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(outs, axis=1)


class ShardedEngine(Engine):
    """The PR 2 slot engine with its pool pinned to a mesh.

    Everything behavioral — queue admission, per-request prefill replay
    through pool-wide decode steps, EOS-on-first-token slot recycling,
    committed-(token,pos) idempotent rewrites for seated slots — is
    inherited from `Engine`; this class only overrides *where arrays
    live*: params/cache/slot-state are device_put to the plan's
    shardings at init, and the jitted decode carries explicit in/out
    shardings so the cache round-trips without migrating. Host-side
    `.at[].set` slot updates preserve the committed sharding; the step
    wrapper re-pins token/pos anyway (jit with explicit in_shardings
    rejects, rather than reshards, mismatched committed arrays)."""

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 mesh: Mesh, greedy: bool = True,
                 strict: bool = True):
        # the plan must exist before Engine.__init__ runs the hooks
        self.mesh = mesh
        self.plan = plan_decode(
            model, params, mesh, batch_size=batch_size, strict=strict
        )
        super().__init__(
            model, params, batch_size=batch_size, greedy=greedy
        )

    def _place_params(self, params: Any) -> Any:
        return jax.device_put(params, self.plan.params)

    def _place_cache(self, cache: Any) -> Any:
        return jax.device_put(cache, self.plan.cache)

    def _place_batch(self, x: jax.Array) -> jax.Array:
        return jax.device_put(x, self.plan.token)

    def _compile_decode(self) -> Callable:
        plan = self.plan
        _, decode = compile_decode(self.model, plan)

        def step(params, cache, tok, pos):
            return decode(
                params, cache,
                jax.device_put(tok, plan.token),
                jax.device_put(pos, plan.token),
            )

        return step

    @property
    def n_devices(self) -> int:
        return self.plan.n_devices

    @property
    def cache_bytes_per_device(self) -> int:
        return self.plan.cache_bytes_per_device

    @property
    def param_bytes_per_device(self) -> int:
        return self.plan.param_bytes_per_device
