"""RWKV-6 ("Finch") block: data-dependent-decay linear attention.

Time-mix with per-channel data-dependent decay w_t and bonus u:

    out_t = r_t · (diag(u) k_t v_tᵀ + S_{t-1})
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ            (per head, hd×hd state)

Training runs a **chunked** evaluation (the standard parallel form): within
a chunk of C tokens the contributions are einsums over decay ratios
exp(cum_t - cum_s); across chunks a lax.scan carries the state. Decay
exponents are clamped so every in-chunk ratio stays < e^{4C} — with C=16
that bounds all intermediates < e64, safely inside f32 (documented; the
clamp matches RWKV reference kernels' w clipping).

Decode is the exact single-token recurrence on (state, shift) — O(1) per
token, which is why rwkv6 runs the `long_500k` cell.

The projections (r/k/v/g/o + channel-mix) are `layers.linear_apply`, so
the paper's SPE/quant knobs apply to them; the recurrence itself is exact.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spe import SPEConfig
from repro.models.layers import layernorm_apply, layernorm_init, linear_apply

MIX_NAMES = ("w", "k", "v", "r", "g")
LORA_MIX = 32
LORA_DECAY = 64
CHUNK = 16
WW_CLAMP = (-8.0, 1.386)  # exp(ww) <= 4 -> |log w| <= 4


def rwkv_init(key: jax.Array, d: int, d_ff: int, head_dim: int) -> dict:
    h = d // head_dim
    ks = jax.random.split(key, 16)
    s = 1.0 / (d**0.5)
    lin = lambda kk, di, do: {
        "w": jax.random.normal(kk, (di, do), jnp.float32) / (di**0.5)
    }
    return {
        "ln1": layernorm_init(d),
        "ln2": layernorm_init(d),
        "mu_x": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((len(MIX_NAMES), d), jnp.float32),
        "lora_a": jax.random.normal(
            ks[0], (d, len(MIX_NAMES), LORA_MIX), jnp.float32
        ) * s * 0.1,
        "lora_b": jnp.zeros((len(MIX_NAMES), LORA_MIX, d), jnp.float32),
        "w_r": lin(ks[1], d, d),
        "w_k": lin(ks[2], d, d),
        "w_v": lin(ks[3], d, d),
        "w_g": lin(ks[4], d, d),
        "w_o": lin(ks[5], d, d),
        "w0": jnp.full((d,), -0.6, jnp.float32),  # exp(-exp(-0.6))≈0.58
        "w_lora_a": jax.random.normal(ks[6], (d, LORA_DECAY), jnp.float32)
        * s * 0.1,
        "w_lora_b": jnp.zeros((LORA_DECAY, d), jnp.float32),
        "u": jnp.zeros((h, head_dim), jnp.float32),
        "ln_x": layernorm_init(d),  # per-head groupnorm (applied per head)
        "cm_mu_k": jnp.zeros((d,), jnp.float32),
        "cm_mu_r": jnp.zeros((d,), jnp.float32),
        "cm_k": lin(ks[7], d, d_ff),
        "cm_v": lin(ks[8], d_ff, d),
        "cm_r": lin(ks[9], d, d),
    }


def _ddlerp(p, x, x_prev, dtype):
    """Data-dependent token-shift mixes -> (x_w, x_k, x_v, x_r, x_g)."""
    xx = (x_prev - x).astype(dtype)
    base = x + xx * p["mu_x"].astype(dtype)
    lora = jnp.tanh(
        jnp.einsum("bsd,dfm->bsfm", base, p["lora_a"].astype(dtype))
    )
    delta = jnp.einsum("bsfm,fmd->bsfd", lora, p["lora_b"].astype(dtype))
    mixes = p["mu"].astype(dtype)[None, None] + delta  # (B,S,5,D)
    return [x + xx * mixes[:, :, i] for i in range(len(MIX_NAMES))]


def _decay_log_w(p, x_w, dtype):
    """log w_t in [-4, 0): data-dependent per-channel decay."""
    ww = p["w0"].astype(dtype) + jnp.tanh(
        x_w @ p["w_lora_a"].astype(dtype)
    ) @ p["w_lora_b"].astype(dtype)
    ww = jnp.clip(ww.astype(jnp.float32), *WW_CLAMP)
    return -jnp.exp(ww)  # (B,S,D) f32


def _heads(x, h, hd):
    return x.reshape(*x.shape[:-1], h, hd)


def wkv_chunked(
    r, k, v, log_w, u, state
):  # r/k/v (B,S,H,hd) f32; log_w (B,S,H,hd) f32; state (B,H,hd,hd)
    """Chunked WKV. Returns (out (B,S,H,hd), state')."""
    b, s, h, hd = r.shape
    c = min(CHUNK, s)
    assert s % c == 0, (s, c)
    nc = s // c

    def chunk_step(carry, xs):
        rc, kc, vc, lwc = xs  # (B,C,H,hd)
        cum = jnp.cumsum(lwc, axis=1)  # inclusive (B,C,H,hd)
        cumprev = cum - lwc
        r_t = rc * jnp.exp(cumprev)
        k_t = kc * jnp.exp(-cum)
        # intra-chunk: A[t,s] for s < t, plus the u-bonus diagonal
        a = jnp.einsum(
            "bthk,bshk->bhts", r_t, k_t, preferred_element_type=jnp.float32
        )
        tri = jnp.tril(jnp.ones((c, c), jnp.float32), -1)
        a = a * tri[None, None]
        diag = jnp.einsum("bthk,bthk->bth", rc * u[None, None], kc)
        intra = jnp.einsum("bhts,bshv->bthv", a, vc)
        intra += diag[..., None] * vc
        inter = jnp.einsum("bthk,bhkv->bthv", r_t, carry)
        out_c = inter + intra
        # carry update
        decay_all = jnp.exp(cum[:, -1])  # (B,H,hd)
        k_scaled = kc * jnp.exp(cum[:, -1:, :, :] - cum)
        new_carry = carry * decay_all[..., None] + jnp.einsum(
            "bshk,bshv->bhkv", k_scaled, vc
        )
        return new_carry, out_c

    resh = lambda x: jnp.moveaxis(x.reshape(b, nc, c, h, hd), 1, 0)
    state, outs = jax.lax.scan(
        chunk_step, state, (resh(r), resh(k), resh(v), resh(log_w))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out, state


def wkv_step(r, k, v, log_w, u, state):
    """Exact single-token recurrence. r/k/v/log_w (B,H,hd); state (B,H,hd,hd)."""
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum(
        "bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv
    )
    state = jnp.exp(log_w)[..., None] * state + kv
    return out, state


def _group_norm_heads(p, x, h, hd):
    """Per-head layernorm (RWKV's GroupNorm(h)) using ln_x params."""
    b, s, d = x.shape
    xh = x.reshape(b, s, h, hd).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    y = xh.reshape(b, s, d) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    return y


def time_mix(
    p: dict,
    x: jax.Array,  # (B,S,D) — post-ln1
    head_dim: int,
    *,
    x_prev: Optional[jax.Array] = None,  # (B,1,D) carry-in shift state
    state: Optional[jax.Array] = None,  # (B,H,hd,hd)
    spe: Optional[SPEConfig] = None,
    dtype=jnp.bfloat16,
):
    b, s, d = x.shape
    h = d // head_dim
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, shifted, dtype)
    r = _heads(linear_apply(p["w_r"], xr, spe=spe, dtype=dtype), h, head_dim)
    k = _heads(linear_apply(p["w_k"], xk, spe=spe, dtype=dtype), h, head_dim)
    v = _heads(linear_apply(p["w_v"], xv, spe=spe, dtype=dtype), h, head_dim)
    g = linear_apply(p["w_g"], xg, spe=spe, dtype=dtype)
    log_w = _heads(_decay_log_w(p, xw, dtype), h, head_dim)
    if state is None:
        state = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    out, state = wkv_chunked(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), log_w, p["u"], state,
    )
    y = _group_norm_heads(p, out.reshape(b, s, d), h, head_dim)
    y = (y.astype(dtype) * jax.nn.silu(g))
    y = linear_apply(p["w_o"], y, spe=spe, dtype=dtype)
    return y, (x[:, -1:], state)


def channel_mix(
    p: dict,
    x: jax.Array,  # (B,S,D) — post-ln2
    *,
    x_prev: Optional[jax.Array] = None,
    spe: Optional[SPEConfig] = None,
    dtype=jnp.bfloat16,
):
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xx = (shifted - x).astype(dtype)
    xk = x + xx * p["cm_mu_k"].astype(dtype)
    xr = x + xx * p["cm_mu_r"].astype(dtype)
    kk = jnp.square(
        jax.nn.relu(linear_apply(p["cm_k"], xk, spe=spe, dtype=dtype))
    )
    vv = linear_apply(p["cm_v"], kk, spe=spe, dtype=dtype)
    rr = jax.nn.sigmoid(linear_apply(p["cm_r"], xr, spe=spe, dtype=dtype))
    return rr * vv, x[:, -1:]


def block_apply(
    p: dict,
    h: jax.Array,
    head_dim: int,
    *,
    cache: Optional[dict] = None,
    spe: Optional[SPEConfig] = None,
    dtype=jnp.bfloat16,
):
    """One full RWKV-6 residual block. cache carries
    {tm_shift (B,1,D), cm_shift (B,1,D), state (B,H,hd,hd)} for decode."""
    tm_shift = cache["tm_shift"] if cache else None
    cm_shift = cache["cm_shift"] if cache else None
    state = cache["state"] if cache else None
    a_in = layernorm_apply(p["ln1"], h)
    att, (tm_new, state_new) = time_mix(
        p, a_in, head_dim, x_prev=tm_shift, state=state, spe=spe,
        dtype=dtype,
    )
    h = h + att
    c_in = layernorm_apply(p["ln2"], h)
    ffn, cm_new = channel_mix(p, c_in, x_prev=cm_shift, spe=spe, dtype=dtype)
    h = h + ffn
    new_cache = {"tm_shift": tm_new, "cm_shift": cm_new, "state": state_new}
    return h, new_cache
