"""Unified model API: every architecture behind one interface.

`build_model(cfg, tp)` returns a `Model` whose five entry points are what
the trainer, server, and dry-run lower:

    init(key)                      -> params
    loss(params, batch)            -> (loss, metrics)       [train_step]
    prefill(params, **inputs)      -> (last_logits, cache)  [prefill cell]
    decode_step(params, cache, token, pos) -> (logits, cache) [decode cell]
    init_cache(batch, max_seq)     -> cache pytree

`input_specs(cfg, shape_cell, tp)` produces ShapeDtypeStruct stand-ins for
every entry point's inputs (weak-type-correct, no allocation) — the
dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import transformer as T
from repro.models import whisper as W


# block kinds whose decode cache advances on every step (hidden-state
# recurrences), as opposed to position-indexed attention KV writes.
# The serving engine seats both families by per-slot cache scatter
# (`serve.seating`); the distinction still matters for anything that
# relies on replaying a (token, pos) being idempotent — it is for
# attention caches, never for these.
RECURRENT_KINDS = ("rglru", "rwkv")


def block_kinds(cfg: ArchConfig) -> tuple[str, ...]:
    """Every block kind the stack instantiates (pattern + tail)."""
    return tuple(cfg.pattern) + tuple(cfg.tail or ())


def is_recurrent(cfg: ArchConfig) -> bool:
    """True when any block carries a step-advancing recurrent cache."""
    return any(k in RECURRENT_KINDS for k in block_kinds(cfg))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dims: T.Dims
    max_seq: int
    init: Callable[[jax.Array], Any]
    loss: Callable[..., tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    init_cache: Callable[[int], Any]
    # Paged serving cache (None where unsupported, e.g. enc-dec):
    #   init_cache_paged(batch, n_pages, page) -> cache with K/V page pools
    #   decode_step_paged(p, cache, token, pos, page_tbl, page) -> (logits, cache)
    #   page_layouts(page) -> {attn cache path prefix: (pages_per_slot, page)}
    #   attn_capacities() -> per-attention-block cache capacities
    init_cache_paged: Optional[Callable[[int, int, int], Any]] = None
    decode_step_paged: Optional[Callable[..., tuple[jax.Array, Any]]] = None
    page_layouts: Optional[Callable[[int], dict]] = None
    attn_capacities: Optional[Callable[[], tuple[int, ...]]] = None


def build_model(cfg: ArchConfig, *, tp: int = 1, max_seq: int = 4096) -> Model:
    cfg.validate()
    dims = T.Dims.create(cfg, tp)

    if cfg.is_enc_dec:
        return Model(
            cfg=cfg,
            dims=dims,
            max_seq=max_seq,
            init=lambda key: W.whisper_init(key, cfg, dims, max_seq),
            loss=lambda p, batch: W.loss_fn(p, batch, cfg, dims),
            prefill=lambda p, tokens, frames: W.prefill(
                p, tokens, frames, cfg, dims, max_seq=max_seq
            ),
            decode_step=lambda p, cache, token, pos: W.decode_step(
                p, cache, token, pos, cfg, dims
            ),
            init_cache=lambda batch: W.init_cache(cfg, dims, batch, max_seq),
        )

    return Model(
        cfg=cfg,
        dims=dims,
        max_seq=max_seq,
        init=lambda key: T.stack_init(key, cfg, dims),
        loss=lambda p, batch: T.loss_fn(p, batch, cfg, dims),
        prefill=lambda p, tokens: T.prefill(
            p, tokens, cfg, dims, max_seq=max_seq
        ),
        decode_step=lambda p, cache, token, pos: T.decode_step(
            p, cache, token, pos, cfg, dims
        ),
        init_cache=lambda batch: T.init_cache(cfg, dims, batch, max_seq),
        init_cache_paged=lambda batch, n_pages, page: T.init_cache_paged(
            cfg, dims, batch, n_pages, page, max_seq
        ),
        decode_step_paged=lambda p, cache, token, pos, tbl, page: (
            T.decode_step(p, cache, token, pos, cfg, dims,
                          page_tbl=tbl, page=page)
        ),
        page_layouts=lambda page: T.paged_layouts(cfg, page, max_seq),
        attn_capacities=lambda: T.attn_capacities(cfg, max_seq),
    )


# ---------------------------------------------------------------------------
# Abstract inputs for the dry-run
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    cfg: ArchConfig, cell: ShapeCell, *, tp: int = 1
) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train  -> {"batch": {...}}                       for model.loss
    prefill-> {"tokens": ..., ("frames": ...)}       for model.prefill
    decode -> {"cache": ..., "token": ..., "pos": ...} for model.decode_step
    """
    b, s = cell.global_batch, cell.seq_len
    model = build_model(cfg, tp=tp, max_seq=s)
    dt = T.compute_dtype(cfg)
    if cell.kind == "train":
        batch: dict[str, Any] = {
            "tokens": _sds((b, s), jnp.int32),
            "targets": _sds((b, s), jnp.int32),
        }
        if cfg.is_enc_dec:
            batch["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), dt)
        return {"batch": batch}
    if cell.kind == "prefill":
        out: dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.is_enc_dec:
            out["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), dt)
        return out
    # decode: abstract cache via eval_shape of init_cache
    cache = jax.eval_shape(lambda: model.init_cache(b))
    return {
        "cache": cache,
        "token": _sds((b,), jnp.int32),
        "pos": _sds((b,), jnp.int32),
    }
