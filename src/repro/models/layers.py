"""Primitive NN layers — pure init/apply pairs over jnp pytrees.

Conventions (whole substrate):
  * params are pytrees of f32 "master" arrays; applies cast to the compute
    dtype (bf16 on TPU) at use — standard mixed precision;
  * every init takes an explicit PRNG key; every apply is pure;
  * 2-D weights are (in_features, out_features) so `dist.sharding`'s rule
    table can assign (fsdp, tp) / (tp, fsdp) specs by path name;
  * the paper's technique enters through `linear_apply`: an optional
    `SPEConfig` applies co-design prune-STE + fake-quant in training, and
    a *compiled* param dict ({"packed","scale"} or
    {"values_q","select","scale"}) swaps in compressed storage at serve
    time (the memory-roofline optimization measured in §Perf).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core import sparsity as S
from repro.core.spe import SPEConfig, spe_train_weight


# ---------------------------------------------------------------------------
# Linear (+ the SPE/quant entry point)
# ---------------------------------------------------------------------------


def linear_init(
    key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
    scale: Optional[float] = None,
) -> dict:
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * s}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_apply(
    params: dict,
    x: jax.Array,
    *,
    spe: Optional[SPEConfig] = None,
    dtype: jnp.dtype = jnp.bfloat16,
) -> jax.Array:
    """y = x @ W (+ b). Dispatches on the param format:

    {"w"}                      dense master weights (training / baseline);
                               `spe` applies the paper's QAT constraints.
    {"packed","scale"}         compiled mixed-bit-width storage (CMUL):
                               unpack + matmul (XLA path — the Pallas
                               `quant_matmul` kernel is the TPU runtime
                               twin, validated in tests).
    {"values_q","select","scale"}  compiled sparse+quant storage (SPE).
    """
    if "w" in params:
        w = params["w"]
        if spe is not None:
            w = spe_train_weight(w, spe)
        y = x.astype(dtype) @ w.astype(dtype)
    elif "packed" in params:
        # bit width is encoded in the packed shape (keeps the param tree
        # array-only, so stacked layers scan cleanly): rows = ceil(K*b/8)
        k = x.shape[-1]
        bits = (8 * params["packed"].shape[-2]) // k
        w = Q.unpack_planes(params["packed"], bits, k).astype(dtype)
        y = (x.astype(dtype) @ w) * params["scale"].astype(dtype)
    elif "values_q" in params:
        meta = params["meta"]
        cfg = S.SparsityConfig(int(meta["group"]), int(meta["keep"]))
        dense = S.decompress(
            params["values_q"].astype(dtype), params["select"], cfg,
            (params["values_q"].shape[0] // cfg.keep) * cfg.group_size,
        )
        k = x.shape[-1]
        y = (x.astype(dtype) @ dense[:k]) * params["scale"].astype(dtype)
    else:
        raise ValueError(f"unknown linear param format: {list(params)}")
    if "b" in params:
        y = y + params["b"].astype(dtype)
    return y


def compile_linear_quant(params: dict, bits: int) -> dict:
    """Dense {"w"} -> packed mixed-bit-width serving format.

    Handles stacked (n_groups, K, N) block weights by vmapping over the
    leading dim (the scan slices them back to 2-D at apply time). The bit
    width is recoverable from the packed shape, so the output tree stays
    array-only (scan-compatible).
    """
    w = params["w"]

    def one(w2):
        q, scale = Q.quantize(w2, Q.QuantConfig(bits=bits))
        return Q.pack_planes(q, bits), scale.reshape(1, -1)

    if w.ndim == 3:
        packed, scale = jax.vmap(one)(w)
    else:
        packed, scale = one(w)
    out = {"packed": packed, "scale": scale}
    if "b" in params:
        out["b"] = params["b"]
    return out


def compile_linear_sparse_quant(
    params: dict, bits: int, group: int = 16, keep: int = 8
) -> dict:
    """Dense {"w"} -> SPE compressed (values+select) serving format."""
    w = params["w"]
    k = w.shape[0]
    pad = (-k) % group
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    scfg = S.SparsityConfig(group, keep)
    values, select = S.compress(S.apply_prune(w, scfg), scfg)
    q, scale = Q.quantize(values, Q.QuantConfig(bits=bits))
    out = {
        "values_q": q,
        "select": select,
        "scale": scale.reshape(1, -1),
        "meta": {"group": group, "keep": keep, "bits": bits},
    }
    if "b" in params:
        out["b"] = params["b"]
    return out


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(params: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dt)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(params: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dt)


def norm_init(kind: str, d: int) -> dict:
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm_apply(params, x)
    return layernorm_apply(params, x)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(
    x: jax.Array,  # (B, S, H, hd)
    pos: jax.Array,  # (B, S) int — or (B, 3, S) for M-RoPE
    *,
    theta: float,
    sections: Sequence[int] = (),
) -> jax.Array:
    """Rotate half-pairs. With `sections` (M-RoPE), the hd/2 frequency
    slots are split into (t, h, w) groups, each indexed by its own
    position row of `pos` (text positions use identical rows, which
    reduces exactly to standard RoPE)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if sections:
        assert sum(sections) == hd // 2, (sections, hd)
        assert pos.ndim == 3 and pos.shape[1] == len(sections)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            p = pos[:, i, :].astype(jnp.float32)  # (B, S)
            parts.append(p[:, :, None] * freqs[start : start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B, S, hd/2)
    else:
        ang = pos.astype(jnp.float32)[:, :, None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GeGLU / plain GELU MLP)
# ---------------------------------------------------------------------------


def ffn_init(
    key: jax.Array, d: int, f: int, *, act: str, bias: bool = False
) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": linear_init(k1, d, f, bias=bias),
            "w_up": linear_init(k2, d, f, bias=bias),
            "w_down": linear_init(k3, f, d, bias=bias),
        }
    return {
        "w_up": linear_init(k1, d, f, bias=bias),
        "w_down": linear_init(k2, f, d, bias=bias),
    }


def ffn_apply(
    params: dict,
    x: jax.Array,
    *,
    act: str,
    spe: Optional[SPEConfig] = None,
    dtype: jnp.dtype = jnp.bfloat16,
) -> jax.Array:
    if act in ("swiglu", "geglu"):
        g = linear_apply(params["w_gate"], x, spe=spe, dtype=dtype)
        u = linear_apply(params["w_up"], x, spe=spe, dtype=dtype)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return linear_apply(params["w_down"], g * u, spe=spe, dtype=dtype)
    h = linear_apply(params["w_up"], x, spe=spe, dtype=dtype)
    return linear_apply(params["w_down"], jax.nn.gelu(h), spe=spe, dtype=dtype)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_init(key: jax.Array, vocab: int, d: int) -> dict:
    return {"w": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed_apply(
    params: dict, tokens: jax.Array, *, dtype: jnp.dtype = jnp.bfloat16,
    scale: bool = False,
) -> jax.Array:
    h = params["w"].astype(dtype)[tokens]
    if scale:
        h = h * jnp.asarray(
            jnp.sqrt(jnp.float32(params["w"].shape[1])), dtype
        )
    return h


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
