"""Attention: GQA with global / sliding-window / chunked variants.

Training/prefill path is a blockwise (flash-style) implementation in pure
JAX: vmap over query blocks × lax.scan over KV blocks with an online
softmax, so peak memory is O(S · block) instead of O(S²) — mandatory for
the 32k prefill cells. Three structural specializations:

  * global  — online-softmax over all KV blocks (causal or bidirectional);
  * local   — banded: each query block attends only to its window-span of
              KV (FLOPs O(S·W) instead of O(S²));
  * chunked — chunk-diagonal (llama4): fold chunks into the batch and run
              the causal path inside each chunk (FLOPs O(S·C)).

Decode path is a single-token attention over a cache with explicit
`slot_pos` validity (supports ring buffers for local/chunked layers —
that is what makes `long_500k` feasible for hybrid archs).

Known inefficiency (recorded for §Roofline): the global causal path
computes fully-masked upper-diagonal blocks (≈2× the optimal FLOPs);
block-skipping is a hillclimb item, visible in MODEL_FLOPS/HLO_FLOPs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import softcap

NEG = -1e30


def _pos_mask(
    qi: jax.Array,  # (bq,) absolute query positions
    kj: jax.Array,  # (bk,) absolute key positions
    *,
    kind: str,
    window: int,
    causal: bool,
) -> jax.Array:
    m = kj[None, :] >= 0  # left-pad slots carry negative positions
    if causal:
        m &= qi[:, None] >= kj[None, :]
    if kind == "local" and window:
        m &= (qi[:, None] - kj[None, :]) < window
    if kind == "chunked" and window:
        m &= (qi[:, None] // window) == (kj[None, :] // window)
    return m


SCORE_DTYPE = jnp.float32  # set bfloat16 via score_dtype() for §Perf


import contextlib


@contextlib.contextmanager
def score_dtype(dtype):
    """Experiment knob: compute blockwise scores/softmax in `dtype`
    (bf16 halves the score-tensor HBM traffic; accumulators stay f32)."""
    global SCORE_DTYPE
    prev = SCORE_DTYPE
    SCORE_DTYPE = dtype
    try:
        yield
    finally:
        SCORE_DTYPE = prev


def _scores(qb, kb, cap):
    """(B,bq,G,R,hd) x (B,bk,G,hd) -> (B,G,R,bq,bk), scaled+capped."""
    hd = qb.shape[-1]
    s = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qb, kb, preferred_element_type=SCORE_DTYPE
    )
    s = s * (1.0 / jnp.sqrt(jnp.asarray(hd, SCORE_DTYPE)))
    if cap:
        s = cap * jnp.tanh(s / cap)
    return s


def _attend_block(s, vspan, mask):
    """Direct softmax over one contiguous KV span (used by local path).

    s: (B,G,R,bq,span) f32 scores; vspan: (B,span,G,hd); mask: (bq,span).
    Returns (B,bq,G,R,hd) f32.
    """
    s = jnp.where(mask[None, None, None], s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG / 2))
    l = jnp.sum(p, axis=-1)  # (B,G,R,bq)
    o = jnp.einsum(
        "bgrqk,bkgd->bqgrd", p, vspan, preferred_element_type=jnp.float32
    )
    return o / jnp.maximum(jnp.moveaxis(l, 3, 1)[..., None], 1e-20)


def _global_blockwise(
    q, k, v, *, causal, cap, q0, k0, block_q, block_k
) -> jax.Array:
    """Online-softmax over all KV blocks. q (B,Sq,G,R,hd), k/v (B,Sk,G,hd)."""
    b, sq, g, r, hd = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pq = (-sq) % bq
    pk = (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = qp.shape[1] // bq
    nk = kp.shape[1] // bk
    kpos = jnp.arange(kp.shape[1]) + k0
    kpos = jnp.where(jnp.arange(kp.shape[1]) < sk, kpos, -1)  # pad invalid

    def per_qblock(qb, i):
        qi = i * bq + jnp.arange(bq) + q0
        m0 = jnp.full((b, g, r, bq), NEG, jnp.float32)
        l0 = jnp.zeros((b, g, r, bq), jnp.float32)
        a0 = jnp.zeros((b, g, r, bq, hd), jnp.float32)

        # NOTE: the body is checkpointed so the backward pass recomputes
        # the (bq x bk) score/softmax tensors per KV step instead of
        # saving all nk of them (flash-attention-style memory behavior;
        # without this the saved p tensors dominate training temp memory).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, j * bk, bk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, j * bk, bk, axis=1)
            kj = jax.lax.dynamic_slice_in_dim(kpos, j * bk, bk, axis=0)
            s = _scores(qb, kb, cap)  # (B,G,R,bq,bk)
            mask = _pos_mask(qi, kj, kind="global", window=0, causal=causal)
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
            p = jnp.exp(s.astype(SCORE_DTYPE)
                        - m_new[..., None].astype(SCORE_DTYPE))
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1,
                                    dtype=jnp.float32)
            pv = jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vb,
                preferred_element_type=jnp.float32,
            )
            acc = acc * alpha[..., None] + pv
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l[..., None], 1e-20)  # (B,G,R,bq,hd)
        return jnp.moveaxis(out, 3, 1)  # (B,bq,G,R,hd)

    # Internal layout constraints: keep batch on the data axes and KV
    # heads on the model axis through the blocked layout — without these
    # XLA's propagation can pick conflicting shardings between the fwd
    # and transpose passes and fall back to "involuntary full
    # rematerialization" (replicate + repartition), observed as multi-GiB
    # copies in the bwd loop.
    kp = constrain(kp, "dp", None, "tp", None)
    vp = constrain(vp, "dp", None, "tp", None)
    qbs = jnp.moveaxis(
        qp.reshape(b, nq, bq, g, r, hd), 1, 0
    )  # (nq,B,bq,G,R,hd)
    qbs = constrain(qbs, None, "dp", None, "tp", None, None)
    outs = jax.vmap(per_qblock)(qbs, jnp.arange(nq))  # (nq,B,bq,G,R,hd)
    outs = constrain(outs, None, "dp", None, "tp", None, None)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * bq, g, r, hd)
    return out[:, :sq].astype(q.dtype)


def _local_banded(q, k, v, *, window, cap, block) -> jax.Array:
    """Sliding-window: query block i sees KV span [i*b - Wb, i*b + b)."""
    b, sq, g, r, hd = q.shape
    sk = k.shape[1]
    bq = min(block, sq)
    wb = -(-window // bq) * bq  # window rounded up to whole blocks
    pq = (-sq) % bq
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    nq = qp.shape[1] // bq
    # left-pad KV by wb; right-pad to cover the last query block
    rpad = max(nq * bq - sk, 0)
    kp = jnp.pad(k, ((0, 0), (wb, rpad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (wb, rpad), (0, 0), (0, 0)))
    kpos = jnp.arange(kp.shape[1]) - wb
    kpos = jnp.where((kpos >= 0) & (kpos < sk), kpos, -1)
    span = wb + bq

    def per_qblock(qb, i):
        qi = i * bq + jnp.arange(bq)
        ks = jax.lax.dynamic_slice_in_dim(kp, i * bq, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, i * bq, span, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(kpos, i * bq, span, axis=0)
        s = _scores(qb, ks, cap)
        mask = _pos_mask(qi, kj, kind="local", window=window, causal=True)
        return _attend_block(s, vs, mask)  # (B,bq,G,R,hd)

    qbs = jnp.moveaxis(qp.reshape(b, nq, bq, g, r, hd), 1, 0)
    outs = jax.vmap(per_qblock)(qbs, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * bq, g, r, hd)
    return out[:, :sq].astype(q.dtype)


def attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Kv, hd)
    v: jax.Array,  # (B, Sk, Kv, hd)
    *,
    kind: str = "global",  # global | local | chunked
    window: int = 0,
    cap: float = 0.0,
    causal: bool = True,
    q_offset: int = 0,  # absolute position of q[0] (cross-attn: 0)
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Multi-head attention with GQA; returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    qg = q.reshape(b, sq, kv, h // kv, hd)
    sk = k.shape[1]

    if kind == "chunked" and window and window < sq:
        assert sq == sk and q_offset == 0, (
            "chunked train path expects self-attention"
        )
        pad = (-sq) % window  # right-pad to whole chunks (causal-safe)
        qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nc = (sq + pad) // window
        qc = qp.reshape(b * nc, window, kv, h // kv, hd)
        kc = kp.reshape(b * nc, window, kv, hd)
        vc = vp.reshape(b * nc, window, kv, hd)
        out = _global_blockwise(
            qc, kc, vc, causal=causal, cap=cap, q0=0, k0=0,
            block_q=block_q, block_k=block_k,
        )
        return out.reshape(b, sq + pad, h, hd)[:, :sq]

    if kind == "local" and window and window < sk:
        assert sq == sk and q_offset == 0, "banded path is self-attention"
        out = _local_banded(qg, k, v, window=window, cap=cap, block=block_q)
        return out.reshape(b, sq, h, hd)

    out = _global_blockwise(
        qg, k, v, causal=causal, cap=cap, q0=q_offset, k0=0,
        block_q=block_q, block_k=block_k,
    )
    return out.reshape(b, sq, h, hd)


def attention_reference(
    q, k, v, *, kind="global", window=0, cap=0.0, causal=True, q_offset=0
) -> jax.Array:
    """Naive O(S²) oracle for tests."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, sq, kv, h // kv, hd)
    s = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(hd))
    if cap:
        s = cap * jnp.tanh(s / cap)
    qi = jnp.arange(sq) + q_offset
    kj = jnp.arange(k.shape[1])
    mask = _pos_mask(qi, kj, kind=kind, window=window, causal=causal)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgrqk,bkgd->bqgrd", p, v, preferred_element_type=jnp.float32
    )
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def _decode_valid(slot_pos, pos, kind, window):
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if kind == "local" and window:
        valid &= (pos[:, None] - slot_pos) < window
    if kind == "chunked" and window:
        valid &= (slot_pos // window) == (pos[:, None] // window)
    return valid


def attention_decode(
    q: jax.Array,  # (B, H, hd) — one new token per sequence
    k_cache: jax.Array,  # (B, S_cache, Kv, hd) — bf16/f32 or int8
    v_cache: jax.Array,  # (B, S_cache, Kv, hd)
    slot_pos: jax.Array,  # (B, S_cache) int32 absolute positions, -1 empty
    pos: jax.Array,  # (B,) absolute position of the new token
    *,
    kind: str = "global",
    window: int = 0,
    cap: float = 0.0,
    block_k: int = 8192,
    k_scale: Optional[jax.Array] = None,  # (B, S_cache, Kv) for int8 KV
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention over a (ring) cache.

    Caches longer than `block_k` are processed by an online-softmax scan
    over KV blocks so the f32 score buffer is O(block_k), not O(S_cache)
    — at 32k/500k caches the direct path's temps would rival the cache
    itself. int8 KV caches (with per-slot-per-head scales) dequantize
    per BLOCK inside the scan, so HBM moves int8 — the memory-roofline
    optimization for decode.
    """
    b, h, hd = q.shape
    kv = k_cache.shape[2]
    qg = q.reshape(b, kv, h // kv, hd)
    sc = k_cache.shape[1]
    dt = q.dtype

    def block(kb, vb, sp_b, ksb, vsb):
        if ksb is not None:  # dequantize the block (fused, VMEM-sized)
            kb = kb.astype(jnp.float32) * ksb[..., None]
            vb = (vb.astype(jnp.float32) * vsb[..., None]).astype(dt)
            kb = kb.astype(dt)
        s = jnp.einsum(
            "bgrd,bkgd->bgrk", qg, kb, preferred_element_type=jnp.float32
        ) / jnp.sqrt(jnp.float32(hd))
        if cap:
            s = cap * jnp.tanh(s / cap)
        valid = _decode_valid(sp_b, pos, kind, window)
        return jnp.where(valid[:, None, None, :], s, NEG), vb

    if sc <= block_k:
        s, vd = block(k_cache, v_cache, slot_pos, k_scale, v_scale)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bgrk,bkgd->bgrd", p, vd,
            preferred_element_type=jnp.float32,
        )
        return o.reshape(b, h, hd).astype(dt)

    nb = -(-sc // block_k)
    pad = nb * block_k - sc
    kp = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    spp = jnp.pad(slot_pos, ((0, 0), (0, pad)), constant_values=-1)
    ksp = vsp = None
    if k_scale is not None:
        ksp = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        vsp = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))

    def kv_step(carry, j):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kp, j * block_k, block_k, 1)
        vb = jax.lax.dynamic_slice_in_dim(vp, j * block_k, block_k, 1)
        sp_b = jax.lax.dynamic_slice_in_dim(spp, j * block_k, block_k, 1)
        ksb = vsb = None
        if ksp is not None:
            ksb = jax.lax.dynamic_slice_in_dim(ksp, j * block_k, block_k, 1)
            vsb = jax.lax.dynamic_slice_in_dim(vsp, j * block_k, block_k, 1)
        s, vb = block(kb, vb, sp_b, ksb, vsb)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bgrk,bkgd->bgrd", p, vb, preferred_element_type=jnp.float32
        )
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, h // kv), NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, h // kv), jnp.float32)
    a0 = jnp.zeros((b, kv, h // kv, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nb))
    o = acc / jnp.maximum(l[..., None], 1e-20)
    return o.reshape(b, h, hd).astype(dt)
