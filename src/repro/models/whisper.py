"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, enc_seq, D) — the encoder here
is the transformer stack that consumes them (sinusoidal positions,
bidirectional attention), and the decoder is a standard cross-attending
causal LM with learned positional embeddings.

Whisper's true decoder context is 448 tokens; the assigned decode_32k
cell exercises a 32k KV cache anyway (the pos-emb table is sized to the
requested sequence — an explicitly recorded architectural extension).
`long_500k` is skipped for this arch (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import attention as A
from repro.models.layers import (
    embed_init,
    ffn_apply,
    ffn_init,
    layernorm_apply,
    layernorm_init,
    linear_apply,
    linear_init,
)
from repro.models.transformer import (
    Dims,
    attn_cache_from_prefill,
    attn_cache_init,
    compute_dtype,
)


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha_init(key, d, heads, hd):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": linear_init(k1, d, heads * hd, bias=True),
        "wk": linear_init(k2, d, heads * hd, bias=False),
        "wv": linear_init(k3, d, heads * hd, bias=True),
        "wo": linear_init(k4, heads * hd, d, bias=True),
    }


def _enc_block_init(key, cfg: ArchConfig, dims: Dims):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "attn": _mha_init(k1, cfg.d_model, dims.n_heads, cfg.hd),
        "ln2": layernorm_init(cfg.d_model),
        "ffn": ffn_init(k2, cfg.d_model, dims.d_ff, act="gelu", bias=True),
    }


def _dec_block_init(key, cfg: ArchConfig, dims: Dims):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "self_attn": _mha_init(k1, cfg.d_model, dims.n_heads, cfg.hd),
        "ln_x": layernorm_init(cfg.d_model),
        "cross_attn": _mha_init(k2, cfg.d_model, dims.n_heads, cfg.hd),
        "ln2": layernorm_init(cfg.d_model),
        "ffn": ffn_init(k3, cfg.d_model, dims.d_ff, act="gelu", bias=True),
    }


def whisper_init(key: jax.Array, cfg: ArchConfig, dims: Dims,
                 max_dec_seq: int) -> dict:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": embed_init(ks[2], dims.vocab, cfg.d_model),
        "pos_emb": jax.random.normal(
            ks[3], (max_dec_seq, cfg.d_model), jnp.float32
        ) * 0.01,
        "enc_blocks": jax.vmap(
            lambda kk: _enc_block_init(kk, cfg, dims)
        )(enc_keys),
        "enc_ln": layernorm_init(cfg.d_model),
        "dec_blocks": jax.vmap(
            lambda kk: _dec_block_init(kk, cfg, dims)
        )(dec_keys),
        "dec_ln": layernorm_init(cfg.d_model),
    }


def _mha(p, xq, xkv, *, heads, hd, causal, dtype, q_offset=0, block=512):
    b, sq = xq.shape[:2]
    q = linear_apply(p["wq"], xq, dtype=dtype).reshape(b, sq, heads, hd)
    k = linear_apply(p["wk"], xkv, dtype=dtype).reshape(
        b, xkv.shape[1], heads, hd
    )
    v = linear_apply(p["wv"], xkv, dtype=dtype).reshape(
        b, xkv.shape[1], heads, hd
    )
    out = A.attention(q, k, v, causal=causal, q_offset=q_offset,
                      block_q=block, block_k=block)
    return linear_apply(
        p["wo"], out.reshape(b, sq, heads * hd), dtype=dtype
    ), k, v


def encode(params, frames: jax.Array, cfg: ArchConfig, dims: Dims):
    """frames (B, T_enc, D) — stub-frontend embeddings."""
    dtype = compute_dtype(cfg)
    h = frames.astype(dtype) + _sinusoid(
        frames.shape[1], cfg.d_model
    ).astype(dtype)

    def body(h, bp):
        x_in = layernorm_apply(bp["ln1"], h)
        att, _, _ = _mha(
            bp["attn"], x_in, x_in,
            heads=dims.n_heads, hd=cfg.hd, causal=False, dtype=dtype,
            block=cfg.attn_block,
        )
        h = h + att
        h = h + ffn_apply(
            bp["ffn"], layernorm_apply(bp["ln2"], h), act="gelu",
            dtype=dtype,
        )
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layernorm_apply(params["enc_ln"], h)


def decode_train(
    params, tokens: jax.Array, enc_out: jax.Array, cfg: ArchConfig,
    dims: Dims,
):
    """Teacher-forced decoder: (B,S) tokens -> (B,S,V) f32 logits."""
    dtype = compute_dtype(cfg)
    b, s = tokens.shape
    h = params["embed"]["w"].astype(dtype)[tokens]
    h = h + params["pos_emb"][:s].astype(dtype)
    h = constrain(h, "dp", None, None)

    def body(h, bp):
        h = constrain(h, "dp", None, None)
        sa, _, _ = _mha(
            bp["self_attn"], layernorm_apply(bp["ln1"], h),
            layernorm_apply(bp["ln1"], h),
            heads=dims.n_heads, hd=cfg.hd, causal=True, dtype=dtype,
            block=cfg.attn_block,
        )
        h = h + sa
        ca, _, _ = _mha(
            bp["cross_attn"], layernorm_apply(bp["ln_x"], h), enc_out,
            heads=dims.n_heads, hd=cfg.hd, causal=False, dtype=dtype,
        )
        h = h + ca
        h = h + ffn_apply(
            bp["ffn"], layernorm_apply(bp["ln2"], h), act="gelu",
            dtype=dtype,
        )
        return h, None

    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    h = layernorm_apply(params["dec_ln"], h)
    logits = h @ params["embed"]["w"].astype(dtype).T  # tied
    logits = constrain(logits, "dp", None, None)
    return logits.astype(jnp.float32)


def loss_fn(params, batch, cfg: ArchConfig, dims: Dims):
    enc_out = encode(params, batch["frames"], cfg, dims)
    logits = decode_train(params, batch["tokens"], enc_out, cfg, dims)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, batch["targets"][..., None], axis=-1
    ).mean()
    return nll, {"loss": nll, "nll": nll}


# --- serving ---------------------------------------------------------------


def init_cache(cfg: ArchConfig, dims: Dims, batch: int, max_seq: int):
    dtype = compute_dtype(cfg)
    enc_s = cfg.enc_seq
    per_layer = {
        "self": attn_cache_init(
            # whisper decoder: kv heads == heads
            cfg, Dims(dims.tp, dims.n_heads, dims.n_heads, dims.vocab,
                      dims.d_ff),
            "global", batch, max_seq, dtype,
        ),
        "cross_k": jnp.zeros((batch, enc_s, dims.n_heads, cfg.hd), dtype),
        "cross_v": jnp.zeros((batch, enc_s, dims.n_heads, cfg.hd), dtype),
    }
    return {
        "dec": jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_layers, *x.shape)
            ).copy(),
            per_layer,
        )
    }


def prefill(params, tokens, frames, cfg: ArchConfig, dims: Dims, *,
            max_seq: int):
    """Encode audio + teacher-force the prompt; returns (logits, cache)."""
    dtype = compute_dtype(cfg)
    enc_out = encode(params, frames, cfg, dims)
    b, s = tokens.shape
    h = params["embed"]["w"].astype(dtype)[tokens]
    h = h + params["pos_emb"][:s].astype(dtype)

    def body(h, bp):
        x_in = layernorm_apply(bp["ln1"], h)
        sa, k, v = _mha(
            bp["self_attn"], x_in, x_in,
            heads=dims.n_heads, hd=cfg.hd, causal=True, dtype=dtype,
            block=cfg.attn_block,
        )
        h = h + sa
        cx = layernorm_apply(bp["ln_x"], h)
        ck = linear_apply(bp["cross_attn"]["wk"], enc_out, dtype=dtype)
        cv = linear_apply(bp["cross_attn"]["wv"], enc_out, dtype=dtype)
        ck = ck.reshape(b, -1, dims.n_heads, cfg.hd)
        cv = cv.reshape(b, -1, dims.n_heads, cfg.hd)
        q = linear_apply(bp["cross_attn"]["wq"], cx, dtype=dtype).reshape(
            b, s, dims.n_heads, cfg.hd
        )
        ca = A.attention(q, ck, cv, causal=False)
        ca = linear_apply(
            bp["cross_attn"]["wo"], ca.reshape(b, s, -1), dtype=dtype
        )
        h = h + ca
        h = h + ffn_apply(
            bp["ffn"], layernorm_apply(bp["ln2"], h), act="gelu",
            dtype=dtype,
        )
        cache = {
            "self": attn_cache_from_prefill(
                k, v, cfg, "global", max_seq
            ),
            "cross_k": ck,
            "cross_v": cv,
        }
        return h, cache

    h, cache = jax.lax.scan(body, h, params["dec_blocks"])
    h = layernorm_apply(params["dec_ln"], h[:, -1:])
    logits = (h @ params["embed"]["w"].astype(dtype).T).astype(jnp.float32)
    return logits[:, 0], {"dec": cache}


def decode_step(params, cache, token, pos, cfg: ArchConfig, dims: Dims):
    dtype = compute_dtype(cfg)
    b = token.shape[0]
    h = params["embed"]["w"].astype(dtype)[token[:, None]]
    h = h + params["pos_emb"][pos][:, None].astype(dtype)

    def body(h, xs):
        bp, c = xs
        x_in = layernorm_apply(bp["ln1"], h)
        q = linear_apply(bp["self_attn"]["wq"], x_in, dtype=dtype).reshape(
            b, 1, dims.n_heads, cfg.hd
        )
        k = linear_apply(bp["self_attn"]["wk"], x_in, dtype=dtype).reshape(
            b, 1, dims.n_heads, cfg.hd
        )
        v = linear_apply(bp["self_attn"]["wv"], x_in, dtype=dtype).reshape(
            b, 1, dims.n_heads, cfg.hd
        )
        sc = c["self"]
        cap = sc["k"].shape[1]
        slot = (pos % cap).astype(jnp.int32)
        bidx = jnp.arange(b)
        kc = sc["k"].at[bidx, slot].set(k[:, 0])
        vc = sc["v"].at[bidx, slot].set(v[:, 0])
        sp = sc["slot_pos"].at[bidx, slot].set(pos.astype(jnp.int32))
        sa = A.attention_decode(q[:, 0], kc, vc, sp, pos)
        sa = linear_apply(
            bp["self_attn"]["wo"], sa.reshape(b, 1, -1), dtype=dtype
        )
        h = h + sa
        cx = layernorm_apply(bp["ln_x"], h)
        qx = linear_apply(bp["cross_attn"]["wq"], cx, dtype=dtype).reshape(
            b, 1, dims.n_heads, cfg.hd
        )
        enc_pos = jnp.broadcast_to(
            jnp.arange(c["cross_k"].shape[1]), (b, c["cross_k"].shape[1])
        ).astype(jnp.int32)
        ca = A.attention_decode(
            qx[:, 0], c["cross_k"], c["cross_v"], enc_pos,
            jnp.full((b,), c["cross_k"].shape[1], jnp.int32),
        )
        ca = linear_apply(
            bp["cross_attn"]["wo"], ca.reshape(b, 1, -1), dtype=dtype
        )
        h = h + ca
        h = h + ffn_apply(
            bp["ffn"], layernorm_apply(bp["ln2"], h), act="gelu",
            dtype=dtype,
        )
        new_c = {
            "self": {"k": kc, "v": vc, "slot_pos": sp},
            "cross_k": c["cross_k"],
            "cross_v": c["cross_v"],
        }
        return h, new_c

    h, new_dec = jax.lax.scan(body, h, (params["dec_blocks"], cache["dec"]))
    h = layernorm_apply(params["dec_ln"], h)
    logits = (h @ params["embed"]["w"].astype(dtype).T).astype(jnp.float32)
    return logits[:, 0], {"dec": new_dec}
